"""AOT compile path: lower the Layer-2 graphs to HLO text + metadata.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (via ``make
artifacts``). Emits:

- ``match.hlo.txt`` — the state-match graph (Pallas distance kernel + top-k)
- ``score.hlo.txt`` — the Alg. 1 score kernel
- ``meta.json`` — static shapes the Rust runtime pads its inputs to

HLO **text** is the interchange format, not ``.serialize()``: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)

    lowered_match = jax.jit(model.state_match).lower(*model.match_example_args())
    match_path = os.path.join(out_dir, "match.hlo.txt")
    with open(match_path, "w") as f:
        f.write(to_hlo_text(lowered_match))
    print(f"wrote {match_path}")

    lowered_score = jax.jit(model.oracle_scores).lower(*model.score_example_args())
    score_path = os.path.join(out_dir, "score.hlo.txt")
    with open(score_path, "w") as f:
        f.write(to_hlo_text(lowered_score))
    print(f"wrote {score_path}")

    meta = {
        "match": {
            "cases": model.MATCH_CASES,
            "features": model.MATCH_FEATURES,
            "k": model.MATCH_K,
        },
        "score": {"jk": model.SCORE_JK, "t": model.SCORE_T},
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    args = parser.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
