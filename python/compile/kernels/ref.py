"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: ``test_kernels.py`` asserts the
Pallas implementations (run under ``interpret=True``) match these references
across shapes and dtypes (hypothesis sweeps). Keep them boring and obviously
right.
"""

import jax.numpy as jnp


def pairwise_sq_dists_ref(queries, cases):
    """Squared Euclidean distances.

    Args:
        queries: [B, F] float array.
        cases: [C, F] float array.

    Returns:
        [B, C] squared distances ``d2[b, c] = sum_f (q[b,f] - x[c,f])**2``.
    """
    diff = queries[:, None, :].astype(jnp.float32) - cases[None, :, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def score_matrix_ref(marginals, ci, window):
    """Algorithm 1 score tensor.

    Args:
        marginals: [R] marginal throughput per (job, scale) row.
        ci: [T] carbon intensity per slot.
        window: [R, T] 1.0 where slot t lies inside row r's job window.

    Returns:
        [R, T] scores ``window * marginals[:, None] / max(ci, eps)[None, :]``.
    """
    marginals = marginals.astype(jnp.float32)
    ci = ci.astype(jnp.float32)
    window = window.astype(jnp.float32)
    return window * marginals[:, None] / jnp.maximum(ci, 1e-9)[None, :]
