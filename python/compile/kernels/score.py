"""Layer-1 Pallas kernel: the Algorithm 1 score tensor.

The learning phase's ``O(N·K·T)`` inner loop (paper Alg. 1 lines 2–5):
``score[r, t] = p_r / CI_t`` for every (job, scale) row r and slot t, masked
by each job's arrival/deadline window. The Rust oracle consumes the matrix
through ``runtime::ScoreKernel``.

TPU mapping: rows are tiled (BLOCK_R × T per block); each block holds
BLOCK_R·T f32 in VMEM (256·168·4 ≈ 168 KiB), streaming the window mask once
— the op is bandwidth-bound, so the BlockSpec simply keeps tiles resident.
Lowered with ``interpret=True`` (see dist.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256


def _score_kernel(m_ref, ci_ref, w_ref, o_ref):
    m = m_ref[...]  # [R_blk]
    ci = ci_ref[...]  # [T]
    w = w_ref[...]  # [R_blk, T]
    o_ref[...] = w * m[:, None] / jnp.maximum(ci, 1e-9)[None, :]


@functools.partial(jax.jit, static_argnames=("block_r",))
def score_matrix(marginals, ci, window, *, block_r=BLOCK_R):
    """Tiled [R, T] score matrix via the Pallas kernel.

    ``R`` must be a multiple of ``block_r`` (AOT shapes guarantee it; tests
    use :func:`score_matrix_padded`).
    """
    (r,) = marginals.shape
    (t,) = ci.shape
    assert window.shape == (r, t), f"window shape {window.shape} != {(r, t)}"
    assert r % block_r == 0, f"R={r} not a multiple of block_r={block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((block_r, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, t), jnp.float32),
        interpret=True,
    )(
        marginals.astype(jnp.float32),
        ci.astype(jnp.float32),
        window.astype(jnp.float32),
    )


def score_matrix_padded(marginals, ci, window, *, block_r=BLOCK_R):
    """Arbitrary-R wrapper: zero-pads rows to a block multiple (marginal 0 ⇒
    score 0 everywhere, never selected) and slices back."""
    r = marginals.shape[0]
    block_r = min(block_r, max(8, 1 << (r - 1).bit_length()))
    padded_r = ((r + block_r - 1) // block_r) * block_r
    if padded_r != r:
        marginals = jnp.concatenate([marginals, jnp.zeros(padded_r - r, marginals.dtype)])
        window = jnp.concatenate(
            [window, jnp.zeros((padded_r - r, window.shape[1]), window.dtype)], axis=0
        )
    return score_matrix(marginals, ci, window, block_r=block_r)[:r]
