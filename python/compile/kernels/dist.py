"""Layer-1 Pallas kernel: tiled squared-Euclidean distance.

The runtime hot path of CarbonFlex's case-based reasoning match (paper §5):
each slot, the current system state (``[B, F]``, B=1 in production) is
compared against the knowledge base (``[C, F]``) and the top-k closest
historical oracle decisions are mimicked.

TPU mapping (DESIGN.md §Hardware-Adaptation): the case dimension C is tiled
with a BlockSpec so each block of case rows is VMEM-resident, and the
distance is computed in the MXU-friendly expansion

    ||q - x||^2 = ||q||^2 - 2 q @ x^T + ||x||^2

where the ``q @ x^T`` term is a [B, F] x [F, C_blk] matmul. Kernels are
lowered with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated from the VMEM footprint in
DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Case rows per block: 512 * 8 features * 4 B = 16 KiB of VMEM per block —
# far below the ~16 MiB budget; bumping it buys nothing because the op is
# bandwidth-bound on the case matrix stream.
BLOCK_C = 512


def _dist_kernel(q_ref, c_ref, o_ref):
    """One block: distances from all queries to BLOCK_C cases."""
    q = q_ref[...]  # [B, F]
    c = c_ref[...]  # [C_blk, F]
    # MXU term: -2 q @ c^T, plus the two squared-norm rank-1 corrections.
    cross = jnp.dot(q, c.T, preferred_element_type=jnp.float32)  # [B, C_blk]
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # [B, 1]
    c2 = jnp.sum(c * c, axis=-1)[None, :]  # [1, C_blk]
    o_ref[...] = q2 - 2.0 * cross + c2


@functools.partial(jax.jit, static_argnames=("block_c",))
def pairwise_sq_dists(queries, cases, *, block_c=BLOCK_C):
    """Tiled [B, C] squared distances via the Pallas kernel.

    ``C`` must be a multiple of ``block_c``; the AOT shapes are chosen so it
    is (tests pad explicitly via :func:`pairwise_sq_dists_padded`).
    """
    b, f = queries.shape
    c, f2 = cases.shape
    assert f == f2, f"feature dims differ: {f} vs {f2}"
    assert c % block_c == 0, f"C={c} not a multiple of block_c={block_c}"
    grid = (c // block_c,)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, f), lambda i: (0, 0)),  # queries: replicated
            pl.BlockSpec((block_c, f), lambda i: (i, 0)),  # case tile i
        ],
        out_specs=pl.BlockSpec((b, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(queries.astype(jnp.float32), cases.astype(jnp.float32))


def pairwise_sq_dists_padded(queries, cases, *, block_c=BLOCK_C, pad_value=1e3):
    """Arbitrary-C wrapper: pads cases up to a block multiple and slices the
    result back. Padding rows sit at ``pad_value`` per coordinate so their
    distances are astronomically large (they can never pollute a top-k)."""
    c = cases.shape[0]
    block_c = min(block_c, max(8, 1 << (c - 1).bit_length()))
    padded_c = ((c + block_c - 1) // block_c) * block_c
    if padded_c != c:
        pad = jnp.full((padded_c - c, cases.shape[1]), pad_value, cases.dtype)
        cases = jnp.concatenate([cases, pad], axis=0)
    return pairwise_sq_dists(queries, cases, block_c=block_c)[:, :c]
