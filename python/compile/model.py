"""Layer-2 JAX compute graphs, calling the Layer-1 Pallas kernels.

Two graphs are AOT-lowered by ``aot.py`` and executed from Rust via PJRT:

- :func:`state_match` — the per-slot CBR match (paper §5): squared distances
  from the current state to every knowledge-base case (Pallas kernel), then
  ``lax.top_k`` and gathers of the matched decisions. Rust feeds z-space
  states and padded tensors (see ``rust/src/runtime/matcher.rs``).
- :func:`oracle_scores` — the Alg. 1 score tensor (Pallas kernel), used by
  the learning-phase offload bench.

Python runs only at build time; the lowered HLO text is the interchange.
"""

import jax
import jax.numpy as jnp

from compile.kernels.dist import pairwise_sq_dists
from compile.kernels.score import score_matrix

# AOT shapes (must match artifacts/meta.json and the Rust runtime).
MATCH_CASES = 4096
MATCH_FEATURES = 8
MATCH_K = 5
SCORE_JK = 1024
SCORE_T = 336


def state_match(query, states, caps, rhos, pressures):
    """Top-k nearest knowledge-base cases and their decisions.

    Args:
        query: [1, F] current state (z-space).
        states: [C, F] knowledge-base states (z-space; padding rows at 1e3).
        caps: [C] recorded capacities m_t.
        rhos: [C] recorded thresholds ρ.
        pressures: [C] recorded queue-pressure feature.

    Returns:
        Tuple of [1, K] arrays: (squared distances, capacities, rhos,
        pressures) of the K nearest cases, ascending by distance.
    """
    d2 = pairwise_sq_dists(query, states)[0]  # [C]
    # Sort-based top-k: `lax.top_k` lowers to a `topk` HLO op that the
    # xla_extension 0.5.1 text parser rejects; `argsort` lowers to plain
    # `sort`, which round-trips fine.
    idx = jnp.argsort(d2)[:MATCH_K]
    take = lambda v: jnp.take(v, idx, axis=0)[None, :]
    return (d2[idx][None, :], take(caps), take(rhos), take(pressures))


def oracle_scores(marginals, ci, window):
    """Alg. 1 score tensor ``p_r / CI_t`` with window masking; [R, T]."""
    return (score_matrix(marginals, ci, window),)


def match_example_args():
    """ShapeDtypeStructs for lowering state_match."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((1, MATCH_FEATURES), f32),
        jax.ShapeDtypeStruct((MATCH_CASES, MATCH_FEATURES), f32),
        jax.ShapeDtypeStruct((MATCH_CASES,), f32),
        jax.ShapeDtypeStruct((MATCH_CASES,), f32),
        jax.ShapeDtypeStruct((MATCH_CASES,), f32),
    )


def score_example_args():
    """ShapeDtypeStructs for lowering oracle_scores."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((SCORE_JK,), f32),
        jax.ShapeDtypeStruct((SCORE_T,), f32),
        jax.ShapeDtypeStruct((SCORE_JK, SCORE_T), f32),
    )
