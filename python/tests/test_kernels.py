"""Pallas kernels vs pure-jnp references — the core correctness signal.

Hypothesis sweeps shapes and value ranges; fixed cases cover the AOT shapes
exactly as compiled.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dist import pairwise_sq_dists, pairwise_sq_dists_padded
from compile.kernels.ref import pairwise_sq_dists_ref, score_matrix_ref
from compile.kernels.score import score_matrix, score_matrix_padded

RNG = np.random.default_rng(42)


# ---------- distance kernel ----------


def test_dist_matches_ref_at_aot_shape():
    q = RNG.normal(size=(1, 8)).astype(np.float32)
    c = RNG.normal(size=(4096, 8)).astype(np.float32)
    got = np.asarray(pairwise_sq_dists(jnp.asarray(q), jnp.asarray(c)))
    want = np.asarray(pairwise_sq_dists_ref(jnp.asarray(q), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 4),
    c=st.integers(1, 300),
    f=st.integers(1, 16),
    scale=st.floats(0.1, 100.0),
)
def test_dist_padded_matches_ref_random_shapes(b, c, f, scale):
    rng = np.random.default_rng(b * 10007 + c * 101 + f)
    q = (rng.normal(size=(b, f)) * scale).astype(np.float32)
    x = (rng.normal(size=(c, f)) * scale).astype(np.float32)
    got = np.asarray(pairwise_sq_dists_padded(jnp.asarray(q), jnp.asarray(x)))
    want = np.asarray(pairwise_sq_dists_ref(jnp.asarray(q), jnp.asarray(x)))
    assert got.shape == (b, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3 * scale * scale)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(2, 200), f=st.integers(1, 12))
def test_dist_zero_iff_identical(c, f):
    rng = np.random.default_rng(c * 31 + f)
    x = rng.normal(size=(c, f)).astype(np.float32)
    # query = case 0 exactly
    q = x[0:1]
    d = np.asarray(pairwise_sq_dists_padded(jnp.asarray(q), jnp.asarray(x)))
    # The MXU-form expansion ||q||^2 - 2 q.x + ||x||^2 carries f32
    # cancellation error of O(f * x^2 * eps) at the self-distance.
    assert d[0, 0] == pytest.approx(0.0, abs=1e-4)
    assert (d >= -1e-4).all(), "distances must be non-negative (mod f32 cancellation)"


def test_dist_dtype_f64_inputs_coerced():
    q = RNG.normal(size=(1, 8)).astype(np.float64)
    c = RNG.normal(size=(64, 8)).astype(np.float64)
    got = np.asarray(pairwise_sq_dists_padded(jnp.asarray(q), jnp.asarray(c)))
    want = np.asarray(pairwise_sq_dists_ref(jnp.asarray(q), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got.dtype == np.float32


def test_dist_padding_rows_are_huge():
    # 100 real cases padded to a block multiple: padded-row distances (not
    # returned) must not disturb real results; pad value puts them ~8e6 away.
    q = np.zeros((1, 8), dtype=np.float32)
    x = RNG.normal(size=(100, 8)).astype(np.float32)
    d = np.asarray(pairwise_sq_dists_padded(jnp.asarray(q), jnp.asarray(x)))
    assert d.shape == (1, 100)
    assert d.max() < 1e5  # only real rows returned


# ---------- score kernel ----------


def test_score_matches_ref_at_aot_shape():
    r, t = 1024, 336
    m = RNG.uniform(0.0, 1.0, size=r).astype(np.float32)
    ci = RNG.uniform(10.0, 700.0, size=t).astype(np.float32)
    w = (RNG.uniform(size=(r, t)) < 0.3).astype(np.float32)
    got = np.asarray(score_matrix(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    want = np.asarray(score_matrix_ref(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(r=st.integers(1, 300), t=st.integers(1, 64))
def test_score_padded_matches_ref_random_shapes(r, t):
    rng = np.random.default_rng(r * 7919 + t)
    m = rng.uniform(0.0, 1.0, size=r).astype(np.float32)
    ci = rng.uniform(5.0, 800.0, size=t).astype(np.float32)
    w = (rng.uniform(size=(r, t)) < 0.5).astype(np.float32)
    got = np.asarray(score_matrix_padded(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    want = np.asarray(score_matrix_ref(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    assert got.shape == (r, t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


def test_score_masked_slots_are_zero():
    m = np.ones(16, dtype=np.float32)
    ci = np.full(8, 100.0, dtype=np.float32)
    w = np.zeros((16, 8), dtype=np.float32)
    w[3, 4] = 1.0
    got = np.array(score_matrix_padded(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    assert got[3, 4] == pytest.approx(0.01)
    got[3, 4] = 0.0
    assert (got == 0.0).all()


def test_score_zero_ci_guarded():
    m = np.ones(8, dtype=np.float32)
    ci = np.zeros(4, dtype=np.float32)
    w = np.ones((8, 4), dtype=np.float32)
    got = np.asarray(score_matrix_padded(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w)))
    assert np.isfinite(got).all()
