"""Layer-2 model graphs: top-k semantics, gather alignment, AOT lowering."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _random_kb(rng, valid):
    states = np.full((model.MATCH_CASES, model.MATCH_FEATURES), 1e3, dtype=np.float32)
    states[:valid] = rng.normal(size=(valid, model.MATCH_FEATURES)).astype(np.float32)
    caps = np.zeros(model.MATCH_CASES, dtype=np.float32)
    caps[:valid] = rng.integers(0, 150, size=valid)
    rhos = np.full(model.MATCH_CASES, 1.01, dtype=np.float32)
    rhos[:valid] = rng.uniform(0.2, 1.01, size=valid).astype(np.float32)
    press = np.zeros(model.MATCH_CASES, dtype=np.float32)
    press[:valid] = rng.uniform(0.0, 2.0, size=valid).astype(np.float32)
    return states, caps, rhos, press


def _numpy_topk(q, states, k):
    d2 = ((states - q[0]) ** 2).sum(axis=1)
    idx = np.argsort(d2, kind="stable")[:k]
    return d2, idx


def test_state_match_agrees_with_numpy():
    rng = np.random.default_rng(7)
    states, caps, rhos, press = _random_kb(rng, valid=1000)
    q = rng.normal(size=(1, model.MATCH_FEATURES)).astype(np.float32)
    d2_top, caps_top, rhos_top, press_top = model.state_match(
        jnp.asarray(q), jnp.asarray(states), jnp.asarray(caps), jnp.asarray(rhos), jnp.asarray(press)
    )
    d2, idx = _numpy_topk(q, states, model.MATCH_K)
    np.testing.assert_allclose(np.asarray(d2_top)[0], d2[idx], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(caps_top)[0], caps[idx])
    np.testing.assert_allclose(np.asarray(rhos_top)[0], rhos[idx], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(press_top)[0], press[idx], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(valid=st.integers(model.MATCH_K, 512), seed=st.integers(0, 2**31 - 1))
def test_state_match_never_returns_padding(valid, seed):
    rng = np.random.default_rng(seed)
    states, caps, rhos, press = _random_kb(rng, valid=valid)
    q = rng.normal(size=(1, model.MATCH_FEATURES)).astype(np.float32)
    d2_top, _, _, _ = model.state_match(
        jnp.asarray(q), jnp.asarray(states), jnp.asarray(caps), jnp.asarray(rhos), jnp.asarray(press)
    )
    # With ≥ K real cases, no padding row (distance ~8e6) may win.
    assert np.asarray(d2_top).max() < 1e6


def test_state_match_distances_ascending():
    rng = np.random.default_rng(11)
    states, caps, rhos, press = _random_kb(rng, valid=500)
    q = rng.normal(size=(1, model.MATCH_FEATURES)).astype(np.float32)
    d2_top, _, _, _ = model.state_match(
        jnp.asarray(q), jnp.asarray(states), jnp.asarray(caps), jnp.asarray(rhos), jnp.asarray(press)
    )
    d = np.asarray(d2_top)[0]
    assert (np.diff(d) >= -1e-6).all()


def test_oracle_scores_shape_and_value():
    rng = np.random.default_rng(13)
    m = rng.uniform(0, 1, model.SCORE_JK).astype(np.float32)
    ci = rng.uniform(10, 700, model.SCORE_T).astype(np.float32)
    w = (rng.uniform(size=(model.SCORE_JK, model.SCORE_T)) < 0.4).astype(np.float32)
    (scores,) = model.oracle_scores(jnp.asarray(m), jnp.asarray(ci), jnp.asarray(w))
    assert scores.shape == (model.SCORE_JK, model.SCORE_T)
    want = w * m[:, None] / ci[None, :]
    np.testing.assert_allclose(np.asarray(scores), want, rtol=1e-5)


def test_lowering_produces_hlo_text(tmp_path):
    # The full AOT path (minus disk layout assumptions).
    from compile import aot

    aot.build(str(tmp_path))
    match_txt = (tmp_path / "match.hlo.txt").read_text()
    score_txt = (tmp_path / "score.hlo.txt").read_text()
    assert "HloModule" in match_txt
    assert "HloModule" in score_txt
    meta = (tmp_path / "meta.json").read_text()
    assert '"cases": 4096' in meta


def test_match_graph_jit_compiles():
    args = [jnp.zeros(s.shape, s.dtype) for s in model.match_example_args()]
    out = jax.jit(model.state_match)(*args)
    assert len(out) == 4
    assert out[0].shape == (1, model.MATCH_K)
