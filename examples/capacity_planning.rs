//! Carbon-aware capacity provisioning interop (paper §6.7, Fig. 14).
//!
//! CarbonFlex separates provisioning (φ) from scheduling (ψ), so it can be
//! compared against — and composed with — Google's Variable Capacity Curve:
//! `VCC` water-fills daily demand into the cleanest forecast hours and
//! schedules FCFS; `VCC (Scaling)` keeps the same capacity curve but fills
//! it elastically by marginal throughput; `CarbonFlex` learns both
//! decisions from the oracle.
//!
//! Run with: `cargo run --release --example capacity_planning`

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::run_policies;
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::Table;

fn main() {
    // §6.7 levels the queues at 24 h slack for a fair comparison.
    let mut cfg = ExperimentConfig::default();
    cfg.uniform_delay_hours = Some(24.0);

    println!("== Carbon-aware provisioning (uniform 24 h slack) ==\n");
    let rows = run_policies(
        &cfg,
        &[PolicyKind::Vcc, PolicyKind::VccScaling, PolicyKind::CarbonFlex, PolicyKind::Oracle],
    );
    let mut t =
        Table::new(&["policy", "carbon (kg)", "savings %", "mean wait (h)", "peak servers"]);
    for row in &rows {
        let m = &row.result.metrics;
        t.row(&[
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", m.mean_delay_hours),
            format!("{}", m.peak_allocated),
        ]);
    }
    t.print();

    let vcc = &rows[0];
    let vcc_scaling = &rows[1];
    println!(
        "\nAdding elastic scheduling to VCC: {:+.1} pp carbon, {:+.0}% waiting time",
        vcc_scaling.savings_pct - vcc.savings_pct,
        (vcc_scaling.result.metrics.mean_delay_hours / vcc.result.metrics.mean_delay_hours - 1.0)
            * 100.0,
    );
}
