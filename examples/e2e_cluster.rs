//! End-to-end driver (EXPERIMENTS.md §E2E): the full CarbonFlex pipeline on
//! the paper's primary setting, with the **PJRT-executed Pallas kernel on
//! the runtime hot path**.
//!
//! 1. Synthesize a South Australia carbon year and an Azure-like workload
//!    (150-server CPU cluster, ~50% utilization).
//! 2. Learning phase: replay the offline oracle (Alg. 1) over the two-week
//!    historical window with multiple start offsets → knowledge base.
//! 3. Execution phase: run the evaluation week with Algorithms 2+3, state
//!    matching via the AOT-compiled `match.hlo.txt` artifact (Python never
//!    runs here — `make artifacts` must have been run once).
//! 4. Report carbon/savings/delay against all baselines (paper Fig. 6).
//!
//! Run with: `make artifacts && cargo run --release --example e2e_cluster`

use std::time::Instant;

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::cluster::energy::EnergyModel;
use carbonflex::cluster::sim::Simulator;
use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::runtime::engine::Engine;
use carbonflex::runtime::matcher::PjrtMatcher;
use carbonflex::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::Table;

fn main() {
    let cfg = ExperimentConfig::default(); // the paper's §6.1 CPU setting
    println!("== CarbonFlex end-to-end: {} servers, {} ({}h eval / {}h history) ==\n",
        cfg.capacity, cfg.region, cfg.horizon_hours, cfg.history_hours);

    // --- Phase 0: traces + workload ---
    let t0 = Instant::now();
    let prep = PreparedExperiment::prepare(&cfg);
    println!(
        "traces ready in {:.2?}: {} eval jobs ({:.0} server-hours), trace mean {:.0} g/kWh",
        t0.elapsed(),
        prep.eval_jobs.len(),
        prep.eval_jobs.iter().map(|j| j.length_hours).sum::<f64>(),
        prep.eval_trace.mean(),
    );

    // --- Phase 1: learning (oracle replay) ---
    let t1 = Instant::now();
    let kb_len = prep.knowledge_base().cases().len();
    println!("learning phase: {} cases in {:.2?}", kb_len, t1.elapsed());

    // --- Phase 2: execution with the PJRT matcher on the hot path ---
    let engine = match Engine::cpu(Engine::default_artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load AOT artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {} (artifacts: match kernel, {} cases compiled)\n",
        engine.platform(), engine.meta().match_cases);

    let t2 = Instant::now();
    let matcher = PjrtMatcher::from_kb(&engine, prep.knowledge_base()).expect("matcher");
    let mut policy = CarbonFlex::new(matcher, CarbonFlexParams::default());
    let sim = Simulator::new(
        cfg.capacity,
        EnergyModel::for_hardware(cfg.hardware),
        cfg.queues.len(),
        cfg.horizon_hours,
    );
    let forecaster = Forecaster::perfect(prep.eval_trace.clone());
    let flex = sim.run(&prep.eval_jobs, &forecaster, &mut policy);
    let exec_time = t2.elapsed();
    let slots_run = flex.slots.len();
    println!(
        "execution phase: {} slots in {:.2?} ({:.2?}/slot incl. PJRT match)",
        slots_run,
        exec_time,
        exec_time / slots_run.max(1) as u32
    );

    // --- Baselines for context ---
    let mut table = Table::new(&["policy", "carbon (kg)", "savings %", "mean delay (h)"]);
    let baseline = prep.run(PolicyKind::CarbonAgnostic);
    let base_carbon = baseline.metrics.carbon_g;
    let mut push = |m: &carbonflex::cluster::metrics::RunMetrics| {
        table.row(&[
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", (1.0 - m.carbon_g / base_carbon) * 100.0),
            format!("{:.2}", m.mean_delay_hours),
        ]);
    };
    push(&baseline.metrics);
    for kind in [PolicyKind::Gaia, PolicyKind::WaitAwhile, PolicyKind::CarbonScaler] {
        push(&prep.run(kind).metrics);
    }
    push(&flex.metrics); // CarbonFlex w/ PJRT matcher
    push(&prep.run(PolicyKind::Oracle).metrics);
    println!();
    table.print();

    assert_eq!(flex.metrics.unfinished, 0, "e2e run must drain all jobs");
    let savings = (1.0 - flex.metrics.carbon_g / base_carbon) * 100.0;
    println!(
        "\nCarbonFlex (PJRT hot path): {:.1}% carbon savings, {} jobs, {} SLO violations",
        savings, flex.metrics.completed, flex.metrics.violations
    );
}
