#!/usr/bin/env sh
# Minimal shell client for the coordinator's JSON-lines protocol (v2).
#
# Part 1 pipes a scripted session into `carbonflex serve`: a correlated
# batch submission, a few ticks with status polls, a stats snapshot, and a
# final drain. Responses come back one JSON line per request, each echoing
# the request's "id" when one was given.
#
# Part 2 demonstrates the persistent-connection session protocol: a
# `serve --tcp` server on localhost, driven by the bundled `client`
# subcommand with one forced mid-stream disconnect — the client must
# reconnect, resume the same session by token, and finish with every
# submission accounted exactly once.
#
# Usage:
#   sh examples/serve_client.sh [path-to-carbonflex-binary]
#
# From the rust/ directory the default resolves via cargo:
#   cargo build --release && sh ../examples/serve_client.sh
set -eu

BIN="${1:-rust/target/release/carbonflex}"
if [ ! -x "$BIN" ]; then
    BIN="target/release/carbonflex"
fi
if [ ! -x "$BIN" ]; then
    echo "carbonflex binary not found; build with: cargo build --release" >&2
    exit 1
fi
CFG="rust/configs/serve.toml"
if [ ! -f "$CFG" ]; then
    CFG="configs/serve.toml"
fi

{
    # One envelope, three jobs, one admission round.
    printf '%s\n' '{"v": 2, "id": "batch-1", "op": "submit_batch", "jobs": [
        {"workload": "N-body(N=100k)", "length_hours": 4.0, "queue": 1},
        {"workload": "Heat(N=1k)", "length_hours": 1.0, "queue": 0},
        {"workload": "Jacobi(N=4k)", "length_hours": 9.0, "queue": 2}]}' | tr -d '\n'
    printf '\n'
    # Single submit with a correlation id.
    printf '%s\n' '{"v": 2, "id": "s-1", "op": "submit", "workload": "N-body(N=2k)", "length_hours": 1.5, "queue": 0}'
    # Advance virtual time, polling status.
    for i in 1 2 3; do
        printf '%s\n' '{"v": 2, "op": "tick"}'
        printf '%s\n' "{\"v\": 2, \"id\": \"st-$i\", \"op\": \"status\"}"
    done
    # Service counters and decision-latency percentiles.
    printf '%s\n' '{"v": 2, "id": "stats-1", "op": "stats"}'
    # A legacy v1 line (no "v") still works during the deprecation window.
    printf '%s\n' '{"op": "status"}'
    # Finish everything and get the final report.
    printf '%s\n' '{"v": 2, "id": "final", "op": "drain"}'
} | "$BIN" serve --config "$CFG" --shards 1

# --- Part 2: TCP session with one forced reconnect. ---------------------
# Fixed localhost port for portability (no lsof/ss dependency); override
# with SERVE_PORT if 47611 is taken.
PORT="${SERVE_PORT:-47611}"
echo "--- session demo: serve --tcp 127.0.0.1:$PORT ---" >&2
"$BIN" serve --config "$CFG" --shards 1 --tcp "127.0.0.1:$PORT" &
SERVER_PID=$!
# The listener binds before serving; give the spawned process a moment.
sleep 1
# Submit 8 generated jobs, dropping the connection after the 4th: the
# client reconnects with its resume token, replays what went unanswered,
# and exits non-zero if the reconnect did not survive. --drain shuts the
# server down and prints the final report.
if ! "$BIN" client --config "$CFG" --tcp "127.0.0.1:$PORT" \
        --jobs 8 --drop-after 4 --drain; then
    kill "$SERVER_PID" 2>/dev/null || true
    echo "session demo failed" >&2
    exit 1
fi
wait "$SERVER_PID"
echo "session demo ok: reconnect survived, session resumed, drain clean" >&2
