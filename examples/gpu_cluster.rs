//! GPU-cluster scenario (paper §6.2, Fig. 7): 15 G6-class GPUs serving an
//! Alibaba-PAI-like ML training workload with heterogeneous per-workload
//! power draw. Demonstrates the §6.2 effect: scaling-based policies gain
//! extra savings on GPUs because high-marginal-throughput (compute-dense)
//! jobs also draw the most power, so steering them into clean slots pays
//! double.
//!
//! Run with: `cargo run --release --example gpu_cluster`

use carbonflex::config::{ExperimentConfig, Hardware, TraceFamily};
use carbonflex::experiments::runner::run_policies;
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::Table;
use carbonflex::workload::profile;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.hardware = Hardware::Gpu;
    cfg.capacity = 15;
    cfg.trace = TraceFamily::AlibabaLike;

    println!(
        "== GPU cluster: {} GPUs, {} trace, {} ==\n",
        cfg.capacity,
        cfg.trace.as_str(),
        cfg.region
    );
    println!("GPU workload catalog (heterogeneous power):");
    let mut cat = Table::new(&["workload", "comm (MB)", "scalability", "W/GPU"]);
    for w in profile::catalog_for(Hardware::Gpu) {
        cat.row(&[
            w.name.to_string(),
            format!("{:.1}", w.comm_mb),
            w.scalability.as_str().to_string(),
            format!("{:.0}", w.watts_per_unit),
        ]);
    }
    cat.print();

    let rows = run_policies(&cfg, &PolicyKind::HEADLINE);
    println!();
    let mut t =
        Table::new(&["policy", "carbon (kg)", "savings %", "energy (kWh)", "mean delay (h)"]);
    for row in &rows {
        let m = &row.result.metrics;
        t.row(&[
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", row.savings_pct),
            format!("{:.1}", m.energy_kwh),
            format!("{:.2}", m.mean_delay_hours),
        ]);
    }
    t.print();

    let flex = rows.iter().find(|r| r.kind == PolicyKind::CarbonFlex).unwrap();
    let scaler = rows.iter().find(|r| r.kind == PolicyKind::CarbonScaler).unwrap();
    println!(
        "\nCarbonFlex saves {:.1}% on the GPU cluster ({:+.1} pp over CarbonScaler).",
        flex.savings_pct,
        flex.savings_pct - scaler.savings_pct
    );
}
