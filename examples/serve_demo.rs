//! Coordinator service demo: run the leader thread, submit jobs over the
//! channel API, tick virtual slots, and drain — the deployment shape of the
//! paper's AWS ParallelCluster prototype (§5) with our cluster engine as
//! the Slurm substrate.
//!
//! Run with: `cargo run --release --example serve_demo`

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::synth::{synthesize, Region};
use carbonflex::config::{Hardware, ServiceConfig};
use carbonflex::coordinator::{Coordinator, CoordinatorConfig};
use carbonflex::sched::carbon_agnostic::CarbonAgnostic;

fn main() {
    let trace = synthesize(Region::California, 400, 7);
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_capacity: 16,
            hardware: Hardware::Cpu,
            num_queues: 3,
            queue_slack_hours: vec![6.0, 24.0, 48.0],
            horizon: 200,
            service: ServiceConfig::default(),
        },
        Forecaster::perfect(trace),
        Box::new(CarbonAgnostic),
    );
    let h = coord.handle();

    // A morning burst of MPI jobs across queues.
    let submissions = [
        ("N-body(N=100k)", 4.0, 1),
        ("N-body(N=2k)", 1.5, 0),
        ("Jacobi(N=4k)", 9.0, 1),
        ("Heat(N=1k)", 1.0, 0),
        ("Jacobi(N=1k)", 14.0, 2),
    ];
    for (workload, hours, queue) in submissions {
        let id = h.submit(workload, hours, queue).expect("submit");
        println!("submitted job {id}: {workload} ({hours} h, queue {queue})");
    }

    // Advance virtual time, watching the cluster.
    for _ in 0..6 {
        let slot = h.tick().expect("tick");
        let s = h.status().expect("status");
        println!(
            "slot {slot:>2}: {} active, {} done, {}/{} servers, {:.1} g CO2",
            s.active_jobs, s.completed, s.used, s.provisioned, s.carbon_g
        );
    }

    // Late submission mid-run, then drain everything.
    let id = h.submit("EffNet-S", 2.0, 0);
    println!("late submission: {id:?} (rejected — GPU workload on a CPU cluster)");
    let id = h.submit("N-body(N=10k)", 2.0, 0).expect("submit");
    println!("late submission: job {id}");

    let metrics = coord.shutdown();
    println!(
        "\ndrained: {} jobs, {:.3} kg CO2, mean delay {:.2} h, {} violations",
        metrics.completed,
        metrics.carbon_kg(),
        metrics.mean_delay_hours,
        metrics.violations
    );
    assert_eq!(metrics.unfinished, 0);
}
