//! Quickstart: build a small cluster, learn from history, and compare
//! CarbonFlex against the carbon-agnostic baseline on three days of work.
//!
//! Run with: `cargo run --release --example quickstart`

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::{run_policies, PreparedExperiment};
use carbonflex::sched::PolicyKind;

fn main() {
    // A small cluster: 24 servers, ~50% utilization, South Australia grid.
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 24;
    cfg.horizon_hours = 72; // three evaluation days
    cfg.history_hours = 168; // one week of history to learn from
    cfg.replay_offsets = 4;

    // Peek at what the learning phase produces.
    let prep = PreparedExperiment::prepare(&cfg);
    println!(
        "workload: {} jobs over {} h (mean length {:.1} h); history: {} jobs",
        prep.eval_jobs.len(),
        cfg.horizon_hours,
        prep.eval_jobs.iter().map(|j| j.length_hours).sum::<f64>() / prep.eval_jobs.len() as f64,
        prep.hist_jobs.len(),
    );
    println!("knowledge base: {} oracle cases\n", prep.knowledge_base().cases().len());

    // Run the comparison.
    let rows = run_policies(
        &cfg,
        &[PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle],
    );
    for row in &rows {
        let m = &row.result.metrics;
        println!(
            "{:<20} {:>8.2} kg CO2  ({:>5.1}% savings)  mean delay {:>5.2} h",
            m.policy,
            m.carbon_kg(),
            row.savings_pct,
            m.mean_delay_hours
        );
    }
    let flex = rows.iter().find(|r| r.kind == PolicyKind::CarbonFlex).unwrap();
    let oracle = rows.iter().find(|r| r.kind == PolicyKind::Oracle).unwrap();
    println!(
        "\nCarbonFlex is within {:.1} percentage points of the offline oracle.",
        oracle.savings_pct - flex.savings_pct
    );
}
