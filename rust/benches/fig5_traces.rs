//! Bench: regenerate Fig. 5: carbon-trace diversity across ten regions.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::fig5_traces;

fn main() {
    let t0 = Instant::now();
    fig5_traces(42);
    println!("\n[bench fig5_traces] wall time: {:.2?}", t0.elapsed());
}
