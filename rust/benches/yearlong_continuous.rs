//! Bench: year-long continuous-learning evaluation (paper §5's
//! CarbonFlex-Simulator mode) — 8 consecutive weeks with weekly relearning
//! and knowledge-base aging (4-week rolling window).
//!
//! Since PR 5 the weeks are first-class sweep cells on the sweep engine's
//! `weeks` axis: the sequential learning chain runs once during sweep
//! preparation and each week's three policy runs execute in parallel
//! (`run_yearlong` is a thin adapter over that grid).

use std::time::Instant;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::yearlong::run_yearlong;
use carbonflex::util::bench::Table;

fn main() {
    let t0 = Instant::now();
    let cfg = ExperimentConfig::default();
    let r = run_yearlong(&cfg, 8, 24 * 28);
    println!("\n== Continuous learning over {} weeks (aging window 4 weeks) ==", r.weeks.len());
    let mut t =
        Table::new(&["week", "mean CI", "CarbonFlex %", "Oracle %", "KB cases", "violations"]);
    for w in &r.weeks {
        t.row(&[
            format!("{}", w.week),
            format!("{:.0}", w.mean_ci),
            format!("{:.1}", w.savings_pct),
            format!("{:.1}", w.oracle_savings_pct),
            format!("{}", w.kb_cases),
            format!("{}", w.violations),
        ]);
    }
    t.print();
    println!(
        "\nmean savings {:.1}% (oracle {:.1}%), worst week {:.1}%",
        r.mean_savings(),
        r.mean_oracle_savings(),
        r.min_savings()
    );
    println!("\n[bench yearlong_continuous] wall time: {:.2?}", t0.elapsed());
}
