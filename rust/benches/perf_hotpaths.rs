//! Perf bench: the hot paths of EXPERIMENTS.md §Perf.
//!
//! - L3 oracle (Alg. 1) over a week-long trace — the learning-phase loop
//!   (paper §6.8: 2–10 **minutes** in the Python prototype).
//! - State match: native flat KD-tree (single + batched) vs PJRT/Pallas
//!   round trip (paper §6.8: 1–2 ms with scikit-learn).
//! - Knowledge-base index build + amortized sliding-window maintenance.
//! - Cluster-engine stepping throughput per policy.
//!
//! The shared cells live in `experiments::perf` (also behind the
//! `carbonflex bench` CLI subcommand); this binary additionally measures
//! the PJRT backends and records everything to `BENCH_hotpaths.json`.

use std::time::Duration;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::perf::bench_hotpaths;
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::learning::kb::{KnowledgeBase, Matcher};
use carbonflex::learning::state::StateVector;
use carbonflex::runtime::engine::Engine;
use carbonflex::runtime::matcher::PjrtMatcher;
use carbonflex::runtime::score::{score_native, ScoreKernel};
use carbonflex::util::bench::bench;
use carbonflex::util::rng::Rng;

fn main() {
    let t0 = std::time::Instant::now();
    let cfg = ExperimentConfig::default();

    println!("== perf: hot paths (oracle / state match / engine stepping) ==");
    let report = bench_hotpaths(&cfg, Duration::from_secs(5));
    for cell in &report.cells {
        match cell.slots_per_second {
            Some(sps) => println!("{}  ({sps:.0} slots/s)", cell.result),
            None => println!("{}", cell.result),
        }
    }
    println!("(paper prototype: oracle 2–10 min, state match 1–2 ms)");

    let doc = report.to_json(t0.elapsed().as_secs_f64());
    match std::fs::write("BENCH_hotpaths.json", format!("{doc}\n")) {
        Ok(()) => println!("timings recorded to BENCH_hotpaths.json"),
        Err(e) => eprintln!("could not write BENCH_hotpaths.json: {e}"),
    }

    println!("\n== perf: PJRT/Pallas backends ==");
    let prep = PreparedExperiment::prepare(&cfg);
    let kb = KnowledgeBase::from_cases(prep.knowledge_base().cases().to_vec());
    let mut rng = Rng::new(1);
    let mut queries = Vec::new();
    for _ in 0..256 {
        queries.push(StateVector::from_raw(
            rng.range(10.0, 700.0),
            rng.range(-80.0, 80.0),
            rng.f64(),
            &[rng.below(40), rng.below(40), rng.below(40)],
            rng.f64(),
        ));
    }
    let mut qi = 0usize;

    // Native single-query vs batched matching on the same query stream —
    // the batch path amortizes scratch and output reservations.
    {
        let mut kb = KnowledgeBase::from_cases(prep.knowledge_base().cases().to_vec());
        let mut single_out = Vec::new();
        let r = bench("match/native-kdtree (into)", 200, 2000, || {
            qi = (qi + 1) % queries.len();
            kb.top_k_into(&queries[qi], 5, &mut single_out);
            std::hint::black_box(single_out.len());
        });
        println!("{r}");
        let mut batch_out = Vec::new();
        let mut batch_offsets = Vec::new();
        let r = bench("match/native-kdtree (batch x256)", 5, 50, || {
            kb.top_k_batch_into(&queries, 5, &mut batch_out, &mut batch_offsets);
            std::hint::black_box(batch_out.len());
        });
        println!("{r}  ({} queries per iteration)", queries.len());
    }

    match Engine::cpu(Engine::default_artifacts_dir()) {
        Ok(engine) => {
            let matcher = PjrtMatcher::from_kb(&engine, &kb).expect("matcher");
            let r = bench("match/pjrt-pallas", 20, 200, || {
                qi = (qi + 1) % queries.len();
                std::hint::black_box(matcher.top_k(&queries[qi], 5));
            });
            println!("{r}");

            println!("\n== perf: score kernel (Alg. 1 inner loop) ==");
            let kernel = ScoreKernel::load(&engine).expect("score kernel");
            let (jk, t) = kernel.shape();
            let marginals: Vec<f32> = (0..jk).map(|i| 1.0 / (1 + i % 16) as f32).collect();
            let ci: Vec<f32> = (0..t).map(|i| 100.0 + (i % 24) as f32 * 10.0).collect();
            let window: Vec<f32> = (0..jk * t).map(|i| (i % 3 == 0) as u8 as f32).collect();
            let r = bench("score/native", 5, 50, || {
                std::hint::black_box(score_native(&marginals, &ci, &window));
            });
            println!("{r}");
            let r = bench("score/pjrt-pallas", 5, 50, || {
                std::hint::black_box(kernel.run(&marginals, &ci, &window).unwrap());
            });
            println!("{r}");
        }
        Err(e) => println!("SKIP pjrt benches: {e}"),
    }
}
