//! Perf bench: the three hot paths of EXPERIMENTS.md §Perf.
//!
//! - L3 oracle (Alg. 1) over a week-long trace — the learning-phase loop
//!   (paper §6.8: 2–10 **minutes** in the Python prototype).
//! - State match: native KD-tree vs brute force vs PJRT/Pallas round trip
//!   (paper §6.8: 1–2 ms with scikit-learn).
//! - Cluster-engine stepping throughput.

use std::time::{Duration, Instant};

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::learning::kb::{KnowledgeBase, Matcher};
use carbonflex::learning::state::StateVector;
use carbonflex::runtime::engine::Engine;
use carbonflex::runtime::matcher::PjrtMatcher;
use carbonflex::runtime::score::{score_native, ScoreKernel};
use carbonflex::sched::oracle::compute_schedule;
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::{bench, bench_for, fmt_duration};
use carbonflex::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::default();
    let prep = PreparedExperiment::prepare(&cfg);
    println!("== perf: L3 oracle (Alg. 1), {} jobs, week trace ==", prep.eval_jobs.len());
    let jobs = prep.eval_jobs.clone();
    let trace = prep.eval_trace.clone();
    let r = bench_for("oracle/week-trace", Duration::from_secs(5), || {
        std::hint::black_box(compute_schedule(&jobs, &trace, cfg.capacity, 24.0, 8));
    });
    println!("{r}");
    println!("(paper prototype: 2–10 min)");

    println!("\n== perf: state match (k = 5) ==");
    let kb = KnowledgeBase::from_cases(prep.knowledge_base().cases().to_vec());
    let mut rng = Rng::new(1);
    let mut queries = Vec::new();
    for _ in 0..256 {
        queries.push(StateVector::from_raw(
            rng.range(10.0, 700.0),
            rng.range(-80.0, 80.0),
            rng.f64(),
            &[rng.below(40), rng.below(40), rng.below(40)],
            rng.f64(),
        ));
    }
    let mut qi = 0usize;
    let r = bench("match/native-kdtree", 100, 2000, || {
        qi = (qi + 1) % queries.len();
        std::hint::black_box(kb.top_k(&queries[qi], 5));
    });
    println!("{r}");

    match Engine::cpu(Engine::default_artifacts_dir()) {
        Ok(engine) => {
            let matcher = PjrtMatcher::from_kb(&engine, &kb).expect("matcher");
            let r = bench("match/pjrt-pallas", 20, 200, || {
                qi = (qi + 1) % queries.len();
                std::hint::black_box(matcher.top_k(&queries[qi], 5));
            });
            println!("{r}");
            println!("(paper prototype: 1–2 ms)");

            println!("\n== perf: score kernel (Alg. 1 inner loop) ==");
            let kernel = ScoreKernel::load(&engine).expect("score kernel");
            let (jk, t) = kernel.shape();
            let marginals: Vec<f32> = (0..jk).map(|i| 1.0 / (1 + i % 16) as f32).collect();
            let ci: Vec<f32> = (0..t).map(|i| 100.0 + (i % 24) as f32 * 10.0).collect();
            let window: Vec<f32> = (0..jk * t).map(|i| (i % 3 == 0) as u8 as f32).collect();
            let r = bench("score/native", 5, 50, || {
                std::hint::black_box(score_native(&marginals, &ci, &window));
            });
            println!("{r}");
            let r = bench("score/pjrt-pallas", 5, 50, || {
                std::hint::black_box(kernel.run(&marginals, &ci, &window).unwrap());
            });
            println!("{r}");
        }
        Err(e) => println!("SKIP pjrt benches: {e}"),
    }

    println!("\n== perf: end-to-end policy runs (week, M=150) ==");
    for kind in [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle] {
        let t0 = Instant::now();
        let res = prep.run(kind);
        let dt = t0.elapsed();
        println!(
            "{:<22} {:>10}  ({} slots, {:.0} slots/s)",
            kind.as_str(),
            fmt_duration(dt),
            res.slots.len(),
            res.slots.len() as f64 / dt.as_secs_f64()
        );
    }
}
