//! Bench: regenerate Fig. 10: elasticity scenarios.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig10_elasticity};

fn main() {
    let t0 = Instant::now();
    fig10_elasticity(&figures::paper_default());
    println!("\n[bench fig10_elasticity] wall time: {:.2?}", t0.elapsed());
}
