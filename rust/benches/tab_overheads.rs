//! Bench: regenerate §6.8: system overheads.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, overheads};

fn main() {
    let t0 = Instant::now();
    overheads(&figures::paper_default());
    println!("\n[bench tab_overheads] wall time: {:.2?}", t0.elapsed());
}
