//! Bench: regenerate Fig. 14: VCC provisioning interop.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig14_vcc};

fn main() {
    let t0 = Instant::now();
    fig14_vcc(&figures::paper_default());
    println!("\n[bench fig14_vcc] wall time: {:.2?}", t0.elapsed());
}
