//! Bench: regenerate Fig. 8: capacity sweep.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig8_capacity};

fn main() {
    let t0 = Instant::now();
    fig8_capacity(&figures::paper_default());
    println!("\n[bench fig8_capacity] wall time: {:.2?}", t0.elapsed());
}
