//! Bench: regenerate Fig. 9: delay sweep.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig9_delay};

fn main() {
    let t0 = Instant::now();
    fig9_delay(&figures::paper_default());
    println!("\n[bench fig9_delay] wall time: {:.2?}", t0.elapsed());
}
