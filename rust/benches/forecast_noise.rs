//! Bench: forecast-error sensitivity extension (README/EXPERIMENTS.md) —
//! how savings degrade as day-ahead forecast noise grows past the
//! CarbonCast-level ~5% the paper assumes.

use std::time::Instant;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::forecast_noise::print_noise_sweep;

fn main() {
    let t0 = Instant::now();
    print_noise_sweep(&ExperimentConfig::default());
    println!("\n[bench forecast_noise] wall time: {:.2?}", t0.elapsed());
}
