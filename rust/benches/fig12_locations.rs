//! Bench: regenerate Fig. 12: ten locations.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig12_locations};

fn main() {
    let t0 = Instant::now();
    fig12_locations(&figures::paper_default());
    println!("\n[bench fig12_locations] wall time: {:.2?}", t0.elapsed());
}
