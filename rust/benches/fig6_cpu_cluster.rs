//! Bench: regenerate Fig. 6: CPU-cluster headline comparison.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig6_cpu};

fn main() {
    let t0 = Instant::now();
    fig6_cpu(&figures::paper_default());
    println!("\n[bench fig6_cpu_cluster] wall time: {:.2?}", t0.elapsed());
}
