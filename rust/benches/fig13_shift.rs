//! Bench: regenerate Fig. 13: distribution shifts.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig13_shift};

fn main() {
    let t0 = Instant::now();
    fig13_shift(&figures::paper_default());
    println!("\n[bench fig13_shift] wall time: {:.2?}", t0.elapsed());
}
