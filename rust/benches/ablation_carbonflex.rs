//! Ablation bench: CarbonFlex design choices (DESIGN.md §7).
//!
//! Sweeps the Alg. 2/3 aggregation knobs (capacity aggregator, ρ
//! aggregator, urgency window, k) on the paper-default setting and prints
//! the savings each variant achieves — the evidence behind the defaults.

use std::time::Instant;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::run_policies;
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::Table;

fn run_variant(cfg: &ExperimentConfig, agg: &str, rho: &str) -> (f64, usize) {
    std::env::set_var("CARBONFLEX_AGG", agg);
    std::env::set_var("CARBONFLEX_RHO", rho);
    let rows = run_policies(cfg, &[PolicyKind::CarbonFlex]);
    std::env::remove_var("CARBONFLEX_AGG");
    std::env::remove_var("CARBONFLEX_RHO");
    (rows[0].savings_pct, rows[0].result.metrics.violations)
}

fn main() {
    let t0 = Instant::now();
    let cfg = ExperimentConfig::default();

    println!("\n== Ablation: CarbonFlex aggregation choices (paper-default setting) ==");
    let mut t = Table::new(&["capacity agg", "rho agg", "savings %", "violations"]);
    for agg in ["wmean", "min", "median", "max"] {
        for rho in ["min", "median"] {
            let (savings, violations) = run_variant(&cfg, agg, rho);
            t.row(&[
                agg.to_string(),
                rho.to_string(),
                format!("{savings:.1}"),
                format!("{violations}"),
            ]);
        }
    }
    t.print();

    println!("\n== Ablation: k (neighbours) and replay offsets ==");
    let mut t2 = Table::new(&["knn k", "offsets", "savings %"]);
    for k in [1usize, 3, 5, 9] {
        let mut c = cfg.clone();
        c.knn_k = k;
        let rows = run_policies(&c, &[PolicyKind::CarbonFlex]);
        t2.row(&[
            format!("{k}"),
            format!("{}", c.replay_offsets),
            format!("{:.1}", rows[0].savings_pct),
        ]);
    }
    for offsets in [1usize, 3, 6] {
        let mut c = cfg.clone();
        c.replay_offsets = offsets;
        let rows = run_policies(&c, &[PolicyKind::CarbonFlex]);
        t2.row(&["5".into(), format!("{offsets}"), format!("{:.1}", rows[0].savings_pct)]);
    }
    t2.print();

    println!("\n[bench ablation_carbonflex] wall time: {:.2?}", t0.elapsed());
}
