//! Bench: spatial-shifting extension — geo-dispatch across three regions,
//! alone and composed with CarbonFlex's temporal/elastic scheduling.

use std::time::Instant;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::spatial::print_spatial;

fn main() {
    let t0 = Instant::now();
    print_spatial(&ExperimentConfig::default());
    println!("\n[bench spatial_shifting] wall time: {:.2?}", t0.elapsed());
}
