//! Bench: spatial-shifting extension — geo-dispatch across three regions,
//! alone and composed with CarbonFlex's temporal/elastic scheduling.
//!
//! Since PR 5 multi-region deployments are first-class sweep cells: the
//! comparison table is one `SweepSpec` grid over a `+`-joined region set ×
//! the dispatch axis × local policies (`print_spatial`), and the second
//! grid below sweeps the same set across seeds to show run-to-run spread —
//! all on the parallel sweep engine.

use std::time::Instant;

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::spatial::print_spatial;
use carbonflex::experiments::sweep::{self, SweepRunner, SweepSpec};
use carbonflex::experiments::DispatchStrategy;
use carbonflex::sched::PolicyKind;

fn main() {
    let t0 = Instant::now();
    let cfg = ExperimentConfig::default();
    print_spatial(&cfg);

    // The same deployment as a seeds × dispatch grid, straight on the
    // sweep axes (every dispatch strategy shares one set of regional
    // preparations per seed).
    println!("\n== Spatial cells on the sweep grid (2 seeds x 2 dispatchers) ==");
    let mut spec = SweepSpec::new(cfg);
    spec.regions = vec!["south-australia+california+great-britain".into()];
    spec.dispatchers = vec![DispatchStrategy::RoundRobin, DispatchStrategy::LowestWindowCi];
    spec.seeds = vec![42, 43];
    spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
    let rows = SweepRunner::auto().run(&spec);
    sweep::print_table(&rows);

    println!("\n[bench spatial_shifting] wall time: {:.2?}", t0.elapsed());
}
