//! Bench: regenerate Fig. 11: workload trace families.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::{self, fig11_traces};

fn main() {
    let t0 = Instant::now();
    fig11_traces(&figures::paper_default());
    println!("\n[bench fig11_traces] wall time: {:.2?}", t0.elapsed());
}
