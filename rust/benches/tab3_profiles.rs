//! Bench: regenerate Fig. 2 / Table 3: scaling-profile catalog.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::fig2_profiles;

fn main() {
    let t0 = Instant::now();
    fig2_profiles();
    println!("\n[bench tab3_profiles] wall time: {:.2?}", t0.elapsed());
}
