//! Bench: regenerate Fig. 7: GPU-cluster comparison.
//!
//! `harness = false`: prints the paper-shaped table and reports wall time
//! (criterion is unavailable offline; see `util::bench`).

use std::time::Instant;

use carbonflex::experiments::figures::fig7_gpu;

fn main() {
    let t0 = Instant::now();
    fig7_gpu();
    println!("\n[bench fig7_gpu_cluster] wall time: {:.2?}", t0.elapsed());
}
