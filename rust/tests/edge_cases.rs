//! Edge-case and failure-injection tests: degenerate workloads, hostile
//! configs, and coordinator misuse must degrade cleanly, never panic or
//! wedge the engine.

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::trace::CarbonTrace;
use carbonflex::cluster::energy::EnergyModel;
use carbonflex::cluster::sim::Simulator;
use carbonflex::config::{ExperimentConfig, Hardware, ServiceConfig};
use carbonflex::coordinator::{Coordinator, CoordinatorConfig, Request, Response};
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::sched::carbon_agnostic::CarbonAgnostic;
use carbonflex::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
use carbonflex::sched::{Decision, Policy, PolicyKind, SlotCtx};
use carbonflex::workload::job::Job;
use carbonflex::workload::profile::ScalingProfile;

fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
    Job {
        id,
        workload: "t",
        workload_idx: 0,
        arrival,
        length_hours: length,
        queue: 0,
        slack_hours: slack,
        k_min: 1,
        k_max: 4,
        profile: ScalingProfile::from_comm_ratio(0.05, 4),
        watts_per_unit: 40.0,
        deps: Vec::new(),
    }
}

fn sim(cap: usize) -> Simulator {
    Simulator::new(cap, EnergyModel::for_hardware(Hardware::Cpu), 3, 96)
}

fn flat(hours: usize) -> Forecaster {
    Forecaster::perfect(CarbonTrace::new("flat", vec![100.0; hours]))
}

/// A policy that emits garbage decisions: unknown job ids, absurd scales,
/// capacity over M. The engine must sanitize all of it.
struct HostilePolicy;
impl Policy for HostilePolicy {
    fn name(&self) -> &'static str {
        "hostile"
    }
    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let mut alloc: Vec<(usize, usize)> = vec![(usize::MAX, 3), (9999, 1)];
        for v in ctx.jobs {
            alloc.push((v.job.id, 1000)); // far beyond k_max
        }
        Decision { capacity: usize::MAX, alloc }
    }
}

#[test]
fn hostile_policy_is_sanitized() {
    let jobs: Vec<Job> = (0..4).map(|i| job(i, i, 3.0, 12.0)).collect();
    let r = sim(6).run(&jobs, &flat(200), &mut HostilePolicy);
    assert_eq!(r.metrics.completed, 4);
    assert!(r.slots.iter().all(|s| s.used <= 6));
    assert!(r.slots.iter().all(|s| s.provisioned <= 6));
}

/// A policy that flip-flops between all and nothing every slot.
struct Thrash(bool);
impl Policy for Thrash {
    fn name(&self) -> &'static str {
        "thrash"
    }
    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        self.0 = !self.0;
        if self.0 {
            Decision { capacity: ctx.max_capacity, alloc: vec![] }
        } else {
            Decision {
                capacity: ctx.max_capacity,
                alloc: ctx.jobs.iter().map(|v| (v.job.id, v.job.k_max)).collect(),
            }
        }
    }
}

#[test]
fn thrashing_policy_still_completes_with_bounded_rescales() {
    let jobs: Vec<Job> = (0..3).map(|i| job(i, 0, 4.0, 12.0)).collect();
    let r = sim(16).run(&jobs, &flat(300), &mut Thrash(false));
    assert_eq!(r.metrics.completed, 3);
    // Each run/suspend transition is a checkpoint event; bounded by slots.
    assert!(r.metrics.total_rescales > 0);
    assert!(r.metrics.total_rescales < 200);
}

#[test]
fn zero_length_trace_and_empty_jobs() {
    let r = sim(4).run(&[], &flat(10), &mut CarbonAgnostic);
    assert_eq!(r.metrics.completed, 0);
    assert_eq!(r.metrics.carbon_g, 0.0);
    assert!(r.slots.is_empty());
}

#[test]
fn single_slot_jobs_at_every_arrival() {
    let jobs: Vec<Job> = (0..24).map(|i| job(i, i, 1.0, 0.0)).collect();
    let r = sim(2).run(&jobs, &flat(200), &mut CarbonAgnostic);
    assert_eq!(r.metrics.completed, 24);
    assert_eq!(r.metrics.violations, 0);
}

#[test]
fn carbonflex_with_empty_kb_behaves_like_agnostic_capacity() {
    let kb = carbonflex::learning::kb::KnowledgeBase::new();
    let mut cf = CarbonFlex::new(kb, CarbonFlexParams::default());
    let jobs: Vec<Job> = (0..5).map(|i| job(i, 0, 2.0, 6.0)).collect();
    let r = sim(8).run(&jobs, &flat(100), &mut cf);
    assert_eq!(r.metrics.completed, 5);
    // Empty KB → full capacity provisioning, everything runs promptly.
    assert!(r.metrics.mean_delay_hours < 4.0, "delay {}", r.metrics.mean_delay_hours);
}

#[test]
fn coordinator_rejects_bad_wire_input_without_dying() {
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_capacity: 4,
            hardware: Hardware::Cpu,
            num_queues: 3,
            queue_slack_hours: vec![6.0, 24.0, 48.0],
            horizon: 50,
            service: ServiceConfig::default(),
        },
        flat(200),
        Box::new(CarbonAgnostic),
    );
    let h = coord.handle();
    // Bad requests at the protocol layer.
    assert!(Request::from_json_line("{\"op\": 5}").is_err());
    assert!(Request::from_json_line("").is_err());
    // Bad requests at the semantic layer.
    assert!(h.submit("NoSuchWorkload", 1.0, 0).is_err());
    assert!(h.submit("Heat(N=1k)", 0.0, 0).is_err());
    assert!(h.submit("Heat(N=1k)", -3.0, 99).is_err());
    // Queue index is clamped, not rejected.
    assert!(h.submit("Heat(N=1k)", 1.0, 99).is_ok());
    // The coordinator still works.
    assert!(h.tick().is_ok());
    let m = coord.shutdown();
    assert_eq!(m.completed, 1);
}

#[test]
fn coordinator_handle_survives_shutdown() {
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_capacity: 4,
            hardware: Hardware::Cpu,
            num_queues: 3,
            queue_slack_hours: vec![6.0],
            horizon: 50,
            service: ServiceConfig::default(),
        },
        flat(100),
        Box::new(CarbonAgnostic),
    );
    let h = coord.handle();
    coord.shutdown();
    // Requests after shutdown fail cleanly instead of hanging.
    match h.request(Request::Status) {
        Response::Error { .. } => {}
        other => panic!("expected error after shutdown, got {other:?}"),
    }
}

#[test]
fn config_fuzz_never_panics() {
    // Random byte soup through the TOML parser + schema: errors only.
    use carbonflex::util::rng::Rng;
    let mut rng = Rng::new(0xF422);
    let fragments = [
        "[experiment]", "[cluster]", "capacity = ", "= 5", "\"", "[[queue]]",
        "name", "delay_hours = 6.0", "#", "[", "]", "=", "1e999", "-",
        "true", "nested = [[1,", "max_len_hours = 2.0",
    ];
    for _ in 0..500 {
        let n = 1 + rng.below(8);
        let src: Vec<&str> = (0..n).map(|_| *rng.choose(&fragments)).collect();
        let doc = src.join("\n");
        let _ = ExperimentConfig::from_toml_str(&doc); // must not panic
    }
}

#[test]
fn extreme_utilization_configs_still_drain() {
    for util in [0.05, 0.9] {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 16;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        cfg.target_utilization = util;
        let prep = PreparedExperiment::prepare(&cfg);
        for kind in [PolicyKind::CarbonFlex, PolicyKind::Oracle] {
            let r = prep.run(kind);
            assert_eq!(r.metrics.unfinished, 0, "util {util} {kind:?}");
        }
    }
}

#[test]
fn inelastic_only_cluster_suspends_but_never_scales() {
    // k_min == k_max jobs: scaling requests must clamp to 1.
    let jobs: Vec<Job> = (0..3)
        .map(|i| Job {
            k_max: 1,
            profile: ScalingProfile::inelastic(),
            ..job(i, 0, 3.0, 12.0)
        })
        .collect();
    let r = sim(8).run(&jobs, &flat(100), &mut Thrash(false));
    assert_eq!(r.metrics.completed, 3);
    assert!(r.slots.iter().all(|s| s.rho >= 1.0));
}
