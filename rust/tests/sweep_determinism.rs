//! Integration: the parallel sweep engine must be bitwise deterministic —
//! thread count and grid ordering may change the schedule of work, never
//! the results. Per-cell seeds derive from cell content, and rows come back
//! in grid order regardless of completion order.

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::run_policies;
use carbonflex::experiments::sweep::{SweepRunner, SweepSpec};
use carbonflex::sched::PolicyKind;

mod common;

fn tiny_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 12;
    cfg.horizon_hours = 48;
    cfg.history_hours = 72;
    cfg.replay_offsets = 1;
    cfg
}

fn grid_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(tiny_base());
    spec.regions = vec!["south-australia".into(), "ontario".into()];
    spec.seeds = vec![1, 2];
    spec.policies = vec![
        PolicyKind::CarbonAgnostic,
        PolicyKind::WaitAwhile,
        PolicyKind::Gaia,
        PolicyKind::CarbonFlex,
    ];
    spec
}

#[test]
fn thread_count_does_not_change_results() {
    let serial = SweepRunner::new(1).run(&grid_spec());
    let parallel = SweepRunner::new(8).run(&grid_spec());
    assert_eq!(serial.len(), 16);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.kind, b.kind);
        let (ma, mb) = (&a.result.metrics, &b.result.metrics);
        let cell = format!("{}/{}/{:?}", a.point.region, a.point.seed, a.kind);
        assert_eq!(ma.carbon_g.to_bits(), mb.carbon_g.to_bits(), "carbon differs: {cell}");
        assert_eq!(ma.energy_kwh.to_bits(), mb.energy_kwh.to_bits(), "energy differs: {cell}");
        assert_eq!(ma.completed, mb.completed, "completed differs: {cell}");
        assert_eq!(ma.unfinished, mb.unfinished, "unfinished differs: {cell}");
        assert_eq!(ma.violations, mb.violations, "violations differs: {cell}");
        assert_eq!(
            ma.mean_delay_hours.to_bits(),
            mb.mean_delay_hours.to_bits(),
            "delay differs: {cell}"
        );
        assert_eq!(a.savings_pct.to_bits(), b.savings_pct.to_bits(), "savings differs: {cell}");
        // Every cell must also be sane.
        assert_eq!(ma.unfinished, 0, "{cell} left jobs unfinished");
        assert!(ma.carbon_g > 0.0, "{cell} reported non-positive carbon");
    }
}

#[test]
fn rows_come_back_in_grid_order() {
    let spec = grid_spec();
    let rows = SweepRunner::new(8).run(&spec);
    let points = spec.points();
    let policies = spec.policies();
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.point, points[i / policies.len()], "row {i} out of grid order");
        assert_eq!(row.kind, policies[i % policies.len()], "row {i} policy out of order");
    }
}

#[test]
fn cell_configs_are_stable_across_grid_reorderings() {
    // A setting's materialized config depends only on its content, never on
    // its grid coordinates: reversing every axis must yield the same
    // (region, seed) → config mapping.
    let original = grid_spec();
    let mut reordered = grid_spec();
    reordered.regions.reverse();
    reordered.seeds.reverse();
    let by_key: std::collections::BTreeMap<_, _> = original
        .points()
        .into_iter()
        .map(|p| ((p.region.clone(), p.seed), original.config_for(&p)))
        .collect();
    for p in reordered.points() {
        let cfg = reordered.config_for(&p);
        let orig = &by_key[&(p.region.clone(), p.seed)];
        assert_eq!(cfg.seed, orig.seed, "seed moved with grid position: {p:?}");
        assert_eq!(cfg.region, orig.region);
        assert_eq!(cfg.capacity, orig.capacity);
        assert_eq!(cfg.horizon_hours, orig.horizon_hours);
    }
}

/// The optimized engine must reproduce the pre-change per-cell output bit
/// for bit. Fingerprints are blessed into `tests/golden/sweep_fingerprints.txt`
/// on first run (commit the file to pin them); afterwards any divergence —
/// e.g. an engine optimization that is not output-preserving — fails here
/// with the offending cell named.
#[test]
fn optimized_engine_reproduces_sweep_fingerprints() {
    let rows = SweepRunner::new(4).run(&grid_spec());
    let lines: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{}/{}/{}\t{}",
                r.point.region,
                r.point.seed,
                r.kind.as_str(),
                r.result.fingerprint()
            )
        })
        .collect();
    common::check_or_bless("sweep_fingerprints.txt", &lines);
}

#[test]
fn single_cell_sweep_matches_run_policies() {
    // The sweep engine must not change what a cell *means*: a one-point
    // grid reproduces the serial `run_policies` path bitwise (same seed,
    // same prepared experiment, same baseline).
    let kinds = [PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile, PolicyKind::Gaia];
    let direct = run_policies(&tiny_base(), &kinds);
    let mut spec = SweepSpec::new(tiny_base());
    spec.policies = kinds.to_vec();
    let rows = SweepRunner::new(2).run(&spec);
    assert_eq!(direct.len(), rows.len());
    for (d, r) in direct.iter().zip(&rows) {
        assert_eq!(d.kind, r.kind);
        assert_eq!(
            d.result.metrics.carbon_g.to_bits(),
            r.result.metrics.carbon_g.to_bits(),
            "{:?} diverged between compare and sweep",
            d.kind
        );
        assert_eq!(d.savings_pct.to_bits(), r.savings_pct.to_bits());
    }
}
