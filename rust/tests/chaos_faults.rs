//! Fault-injection properties: the cardinal invariants of the chaos
//! subsystem (see `carbonflex::faults`) over randomized instances.
//!
//! 1. A plan with only zero-length outages (and an outright empty plan) is
//!    bitwise indistinguishable from a clean run.
//! 2. A full-horizon outage pushes CarbonFlex all the way down its
//!    degradation ladder: every decision is the carbon-agnostic fallback's,
//!    so the whole run is bitwise the carbon-agnostic run.
//! 3. Shard-kill failover loses nothing silently: killed-incarnation
//!    completions + failover sheds + the fleet drain account for every
//!    accepted submission exactly once.
//! 4. The same `(seed, spec)` always expands to the same plan, and the same
//!    plan always replays the same run.

use carbonflex::config::{DagShape, ExperimentConfig, ServiceConfig};
use carbonflex::coordinator::api::{Response, SubmitRequest};
use carbonflex::coordinator::{shard_regions, ShardedCoordinator};
use carbonflex::experiments::cells::DispatchStrategy;
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::faults::{FaultPlan, FaultSpec, ShardKill, SignalOutage, SlotCrash};
use carbonflex::sched::PolicyKind;
use carbonflex::util::proptest_lite::{check, Config};
use carbonflex::util::rng::Rng;

#[derive(Debug)]
struct Instance {
    cfg: ExperimentConfig,
    seed: u64,
}

fn random_instance(rng: &mut Rng) -> Instance {
    let mut cfg = ExperimentConfig::default();
    cfg.seed = rng.next_u64();
    cfg.capacity = 6 + rng.below(12);
    cfg.horizon_hours = 48 + 24 * rng.below(2);
    cfg.history_hours = cfg.horizon_hours + 24;
    cfg.replay_offsets = 1;
    let seed = rng.next_u64();
    Instance { cfg, seed }
}

#[test]
fn zero_length_outages_are_bitwise_clean() {
    check(
        "zero-length outage ≡ no faults",
        Config { cases: 6, seed: 0xC1EA_0001 },
        random_instance,
        |inst| {
            let prep = PreparedExperiment::prepare(&inst.cfg);
            // Non-empty plan whose every event is a no-op: zero-length
            // outages force the full fault-handling path through the
            // engine and the forecaster mask.
            let plan = FaultPlan {
                crashes: Vec::new(),
                outages: vec![
                    SignalOutage { start: 0, len: 0 },
                    SignalOutage { start: inst.cfg.horizon_hours / 2, len: 0 },
                ],
                shard_kills: Vec::new(),
                max_stale_slots: 4,
            };
            assert!(!plan.is_empty(), "zero-length outages still populate the plan");
            for kind in [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex] {
                let clean = prep.run(kind);
                let faulted = prep.run_with_plan(kind, &plan);
                if clean.fingerprint() != faulted.fingerprint() {
                    return Err(format!("{kind:?}: zero-length outage changed the run"));
                }
                // The empty plan short-circuits to the same place.
                let empty = prep.run_with_plan(kind, &FaultPlan::none());
                if clean.fingerprint() != empty.fingerprint() {
                    return Err(format!("{kind:?}: empty plan changed the run"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn full_horizon_outage_is_bitwise_carbon_agnostic() {
    check(
        "dark signal ≡ carbon-agnostic",
        Config { cases: 6, seed: 0xC1EA_0002 },
        random_instance,
        |inst| {
            let prep = PreparedExperiment::prepare(&inst.cfg);
            // Signal dark for the whole horizon with a tight staleness
            // bound: no slot can find a last-known-good forecast, so every
            // decision lands on the bottom rung of the ladder.
            let plan = FaultPlan {
                crashes: Vec::new(),
                outages: vec![SignalOutage { start: 0, len: inst.cfg.horizon_hours }],
                shard_kills: Vec::new(),
                max_stale_slots: 3,
            };
            let flex_dark = prep.run_with_plan(PolicyKind::CarbonFlex, &plan);
            let agnostic = prep.run(PolicyKind::CarbonAgnostic);
            if flex_dark.fingerprint() != agnostic.fingerprint() {
                return Err("dark CarbonFlex diverged from CarbonAgnostic".into());
            }
            if flex_dark.metrics.degraded_fallback == 0 {
                return Err("fallback counter never incremented under a dark signal".into());
            }
            if flex_dark.metrics.degraded_stale != 0 {
                return Err("stale rung reached with no last-known-good slot".into());
            }
            Ok(())
        },
    );
}

#[test]
fn shard_kill_failover_accounts_for_every_accepted_job() {
    // Fewer cases: each one prepares 2 shards plus a restarted incarnation.
    check(
        "failover exactly-once",
        Config { cases: 4, seed: 0xC1EA_0003 },
        |rng| {
            let mut cfg = ExperimentConfig::default();
            cfg.capacity = 8;
            cfg.horizon_hours = 48;
            cfg.history_hours = 72;
            cfg.replay_offsets = 1;
            let jobs = 6 + rng.below(8);
            let kill_at = 1 + rng.below(jobs) as u64;
            (cfg, jobs, kill_at)
        },
        |(cfg, jobs, kill_at)| {
            let regions = shard_regions("2", &cfg.region).map_err(|e| e.to_string())?;
            let mut cluster = ShardedCoordinator::start(
                cfg,
                &ServiceConfig::default(),
                PolicyKind::CarbonAgnostic,
                &regions,
                DispatchStrategy::RoundRobin,
            );
            cluster.set_kill_plan(&[ShardKill { shard: 0, at_submission: *kill_at }]);
            let mut accepted = 0u64;
            for i in 0..*jobs {
                let r = cluster.submit(&SubmitRequest {
                    workload: "N-body(N=100k)".to_string(),
                    length_hours: 1.0 + (i % 3) as f64,
                    queue: i % 3,
                });
                if matches!(r, Response::Submitted { .. }) {
                    accepted += 1;
                }
                if i % 3 == 2 {
                    cluster.tick();
                }
            }
            let (failovers, _rerouted, failover_shed) = cluster.failover_counters();
            if failovers != 1 {
                return Err(format!("expected exactly one failover, saw {failovers}"));
            }
            let killed_completed: u64 =
                cluster.killed_metrics().iter().map(|m| m.completed as u64).sum();
            let drained = match cluster.drain() {
                Response::Drained { completed, .. } => completed as u64,
                other => return Err(format!("expected drained, got {other:?}")),
            };
            cluster.shutdown();
            if killed_completed + drained + failover_shed != accepted {
                return Err(format!(
                    "exactly-once violated: killed {killed_completed} + drained {drained} \
                     + shed {failover_shed} != accepted {accepted}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn crashed_parents_keep_dag_children_gated() {
    // A whole-cluster crash suspends every running job — including chain
    // parents mid-run — and the rework penalty pushes their completions
    // later. Dependency gating must hold through that detour: a child may
    // only ever complete strictly after its last parent, because a crashed
    // (suspended, not DONE) parent keeps its children out of the eligible
    // set until the rework actually finishes.
    use std::cell::Cell;
    let crashed_runs = Cell::new(0usize);
    check(
        "crashed parent gates children",
        Config { cases: 6, seed: 0xC1EA_0005 },
        |rng| {
            let mut cfg = ExperimentConfig::default();
            cfg.seed = rng.next_u64();
            cfg.capacity = 6 + rng.below(12);
            cfg.horizon_hours = 48;
            cfg.history_hours = 72;
            cfg.replay_offsets = 1;
            cfg.dag_shape = DagShape::Chains;
            let crash_at = 2 + rng.below(20);
            (cfg, crash_at)
        },
        |(cfg, crash_at)| {
            let prep = PreparedExperiment::prepare(cfg);
            if !prep.eval_jobs.iter().any(|j| !j.deps.is_empty()) {
                return Err("chains shape generated no dependency edges".into());
            }
            let plan = FaultPlan {
                crashes: vec![SlotCrash {
                    at: *crash_at,
                    down: cfg.capacity,
                    repair_slots: 3,
                    rework_hours: 2.0,
                }],
                outages: Vec::new(),
                shard_kills: Vec::new(),
                max_stale_slots: 4,
            };
            for kind in [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex] {
                let res = prep.run_with_plan(kind, &plan);
                if res.metrics.unfinished != 0 {
                    return Err(format!(
                        "{kind:?}: {} jobs never finished after the crash",
                        res.metrics.unfinished
                    ));
                }
                if res.metrics.restarts > 0 {
                    crashed_runs.set(crashed_runs.get() + 1);
                }
                let mut completion = vec![usize::MAX; prep.eval_jobs.len()];
                for o in &res.outcomes {
                    completion[o.id] = o.completion;
                }
                for j in &prep.eval_jobs {
                    for &p in &j.deps {
                        if completion[j.id] <= completion[p] {
                            return Err(format!(
                                "{kind:?}: child {} completed in slot {} but parent {} \
                                 only in slot {}",
                                j.id, completion[j.id], p, completion[p]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
    // The crash plan must actually have displaced running work somewhere,
    // or the property above never exercised the suspended-parent path.
    assert!(crashed_runs.get() > 0, "no case saw a restart; crash plan was a no-op");
}

#[test]
fn seeded_plans_and_runs_are_deterministic() {
    check(
        "same (seed, spec) ⇒ same plan ⇒ same run",
        Config { cases: 6, seed: 0xC1EA_0004 },
        random_instance,
        |inst| {
            let spec = FaultSpec::preset("heavy").unwrap();
            let mk = || {
                FaultPlan::generate(
                    inst.seed,
                    &spec,
                    inst.cfg.horizon_hours,
                    inst.cfg.capacity,
                    3,
                )
            };
            let (a, b) = (mk(), mk());
            if a != b {
                return Err("plan generation is not deterministic".into());
            }
            let prep = PreparedExperiment::prepare(&inst.cfg);
            let r1 = prep.run_with_plan(PolicyKind::CarbonFlex, &a);
            let r2 = prep.run_with_plan(PolicyKind::CarbonFlex, &b);
            if r1.fingerprint() != r2.fingerprint() {
                return Err("same plan replayed to a different run".into());
            }
            Ok(())
        },
    );
}
