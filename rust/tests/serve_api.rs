//! Wire-protocol and serving-path integration tests: codec round-trip
//! properties, adversarial malformed lines, and the ingest-shape determinism
//! guarantee (single vs batched vs sharded drains).

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::synth::Region;
use carbonflex::config::{ExperimentConfig, ServiceConfig};
use carbonflex::coordinator::{
    drive, shard_regions, submissions_of, Coordinator, CoordinatorConfig, ErrorCode, Request,
    Response, ShardedCoordinator, StatsResponse, StatusResponse, SubmitOutcome, SubmitRequest,
    WireRequest, WireResponse, PROTOCOL_VERSION,
};
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::experiments::DispatchStrategy;
use carbonflex::sched::PolicyKind;
use carbonflex::util::proptest_lite::{check, Config};
use carbonflex::util::rng::Rng;
use carbonflex::workload::tracegen;

const WORKLOADS: [&str; 6] = [
    "ResNet18",
    "N-body(N=2k)",
    "with \"quotes\"",
    "back\\slash",
    "unicode-λ-⚡",
    "",
];

fn arb_submit(r: &mut Rng) -> SubmitRequest {
    SubmitRequest {
        workload: (*r.choose(&WORKLOADS)).to_string(),
        length_hours: r.range(0.01, 500.0),
        queue: r.below(4),
    }
}

fn arb_id(r: &mut Rng) -> Option<String> {
    match r.below(4) {
        0 => None,
        1 => Some(format!("req-{}", r.below(10_000))),
        2 => Some("id with \"quotes\" and \\slashes\\".to_string()),
        _ => Some("λ-⚡".to_string()),
    }
}

fn arb_request(r: &mut Rng) -> Request {
    match r.below(6) {
        0 => Request::Submit(arb_submit(r)),
        1 => {
            let n = r.below(4);
            Request::SubmitBatch((0..n.max(1)).map(|_| arb_submit(r)).collect())
        }
        2 => Request::Tick,
        3 => Request::Status,
        4 => Request::Stats,
        _ => Request::Drain,
    }
}

fn arb_status(r: &mut Rng) -> StatusResponse {
    StatusResponse {
        slot: r.below(1000),
        active_jobs: r.below(500),
        completed: r.below(500),
        provisioned: r.below(200),
        used: r.below(200),
        carbon_g: r.range(0.0, 1e6),
        energy_kwh: r.range(0.0, 1e4),
    }
}

fn arb_response(r: &mut Rng) -> Response {
    match r.below(7) {
        0 => Response::Submitted { job_id: r.below(100_000) },
        1 => {
            let n = r.below(4);
            let results = (0..n.max(1))
                .map(|_| {
                    if r.below(2) == 0 {
                        SubmitOutcome::Accepted { job_id: r.below(100_000) }
                    } else {
                        SubmitOutcome::Rejected {
                            code: *r.choose(&ErrorCode::ALL),
                            message: "queue full".to_string(),
                        }
                    }
                })
                .collect();
            Response::Batch { results }
        }
        2 => Response::Ticked { slot: r.below(10_000) },
        3 => Response::Status(arb_status(r)),
        4 => Response::Stats(StatsResponse {
            slot: r.below(1000),
            requests: r.below(100_000) as u64,
            accepted: r.below(100_000) as u64,
            shed: r.below(1000) as u64,
            batches: r.below(1000) as u64,
            pending: r.below(5000),
            max_pending: 4096,
            queue_depths: (0..3).map(|_| r.below(100)).collect(),
            p50_decision_ms: r.range(0.0, 50.0),
            p99_decision_ms: r.range(0.0, 500.0),
            carbon_g: r.range(0.0, 1e6),
        }),
        5 => Response::Drained {
            completed: r.below(10_000),
            carbon_g: r.range(0.0, 1e7),
            mean_delay_hours: r.range(0.0, 100.0),
        },
        _ => Response::Error {
            code: *r.choose(&ErrorCode::ALL),
            message: "something broke".to_string(),
        },
    }
}

#[test]
fn wire_request_v2_roundtrip_property() {
    check(
        "v2 request envelope round-trips",
        Config { cases: 256, seed: 0x5E21E },
        |r| WireRequest { v: PROTOCOL_VERSION, id: arb_id(r), req: arb_request(r) },
        |w| {
            let line = w.to_json_line();
            let parsed = WireRequest::from_json_line(&line)
                .map_err(|p| format!("parse failed on {line}: {}", p.message))?;
            if &parsed == w {
                Ok(())
            } else {
                Err(format!("mismatch:\n  sent {w:?}\n  got  {parsed:?}\n  line {line}"))
            }
        },
    );
}

#[test]
fn wire_request_v1_roundtrip_property() {
    // v1 has no envelope: only the legacy ops, no correlation id.
    check(
        "legacy v1 request lines round-trip",
        Config { cases: 128, seed: 0xB0A7 },
        |r| {
            let req = match r.below(4) {
                0 => Request::Submit(arb_submit(r)),
                1 => Request::Tick,
                2 => Request::Status,
                _ => Request::Drain,
            };
            WireRequest { v: 1, id: None, req }
        },
        |w| {
            let line = w.to_json_line();
            if line.contains("\"v\"") {
                return Err(format!("legacy line leaked an envelope: {line}"));
            }
            let parsed =
                WireRequest::from_json_line(&line).map_err(|p| p.message)?;
            if &parsed == w {
                Ok(())
            } else {
                Err(format!("mismatch: sent {w:?} got {parsed:?}"))
            }
        },
    );
}

#[test]
fn wire_response_roundtrip_property() {
    check(
        "response envelope round-trips in both versions",
        Config { cases: 256, seed: 0xD00DAD },
        |r| {
            let resp = arb_response(r);
            // v1 pairs only with legacy-shaped kinds and carries no id.
            let legacy_ok = !matches!(resp, Response::Batch { .. } | Response::Stats(_));
            if legacy_ok && r.below(3) == 0 {
                WireResponse { v: 1, id: None, resp }
            } else {
                WireResponse { v: PROTOCOL_VERSION, id: arb_id(r), resp }
            }
        },
        |w| {
            let line = w.to_json_line();
            let parsed = WireResponse::from_json_line(&line)
                .map_err(|e| format!("parse failed on {line}: {e}"))?;
            if &parsed == w {
                Ok(())
            } else {
                Err(format!("mismatch:\n  sent {w:?}\n  got  {parsed:?}\n  line {line}"))
            }
        },
    );
}

#[test]
fn malformed_lines_all_answer_bad_request() {
    let cases: [&str; 15] = [
        "",
        "not json",
        "{",
        "[]",
        "{\"op\": 5}",
        "{\"v\": 0, \"op\": \"tick\"}",
        "{\"v\": 1.5, \"op\": \"tick\"}",
        "{\"v\": -3, \"op\": \"tick\"}",
        "{\"v\": 99, \"op\": \"tick\"}",
        "{\"v\": 2}",
        "{\"v\": 2, \"op\": \"submit\"}",
        "{\"v\": 2, \"op\": \"submit\", \"workload\": \"X\"}",
        "{\"v\": 2, \"op\": \"submit_batch\"}",
        "{\"v\": 2, \"op\": \"submit_batch\", \"jobs\": [{\"workload\": \"X\"}]}",
        "{\"v\": 2, \"op\": \"fly\"}",
    ];
    for line in cases {
        let err = WireRequest::from_json_line(line)
            .expect_err(&format!("line should be rejected: {line}"));
        assert_eq!(err.code, ErrorCode::BadRequest, "{line}");
        assert!(!err.message.is_empty(), "{line}");
    }
    // The client id is recovered from bad-but-parseable lines so the error
    // response can still be correlated.
    let err = WireRequest::from_json_line("{\"v\": 2, \"id\": \"abc\", \"op\": \"fly\"}")
        .unwrap_err();
    assert_eq!(err.id.as_deref(), Some("abc"));
    let err = WireRequest::from_json_line("{\"v\": 99, \"id\": \"zz\", \"op\": \"tick\"}")
        .unwrap_err();
    assert_eq!(err.id.as_deref(), Some("zz"));
}

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 12;
    cfg.horizon_hours = 48;
    cfg.history_hours = 48;
    cfg.replay_offsets = 1;
    cfg
}

/// Drive the same submissions through a bare (unsharded) coordinator with
/// the same submit/tick cadence the load generator uses.
fn drive_plain(cfg: &ExperimentConfig, arrivals: &[(usize, SubmitRequest)]) -> (usize, u64, u64) {
    let prep = PreparedExperiment::prepare(cfg);
    let coord = Coordinator::start(
        CoordinatorConfig::from_experiment(cfg, ServiceConfig::default()),
        Forecaster::perfect(prep.eval_trace.clone()),
        prep.build_policy(PolicyKind::CarbonAgnostic),
    );
    let h = coord.handle();
    let last = arrivals.iter().map(|(t, _)| *t).max().unwrap_or(0);
    let mut i = 0;
    for t in 0..=last {
        while i < arrivals.len() && arrivals[i].0 == t {
            let resp = h.request(Request::Submit(arrivals[i].1.clone()));
            assert!(matches!(resp, Response::Submitted { .. }), "{resp:?}");
            i += 1;
        }
        h.request(Request::Tick);
    }
    let drained = h.request(Request::Drain);
    let Response::Drained { completed, carbon_g, mean_delay_hours } = drained else {
        panic!("expected drained, got {drained:?}");
    };
    coord.shutdown();
    (completed, carbon_g.to_bits(), mean_delay_hours.to_bits())
}

#[test]
fn drain_reports_identical_across_ingest_shapes() {
    let cfg = small_cfg();
    let service = ServiceConfig::default();
    let jobs = tracegen::generate_n(&cfg, 48, 13, 50);
    let arrivals = submissions_of(&jobs);
    let region = Region::parse(&cfg.region).expect("default region parses");

    // Shape 1: bare coordinator, one submit per request.
    let plain = drive_plain(&cfg, &arrivals);

    // Shape 2: sharded frontend with a single shard, batched ingest.
    let mut one = ShardedCoordinator::start(
        &cfg,
        &service,
        PolicyKind::CarbonAgnostic,
        &[region],
        DispatchStrategy::RoundRobin,
    );
    let r_one = drive(&mut one, &arrivals, 16, "batch");
    one.shutdown();
    assert_eq!(
        plain,
        (r_one.completed, r_one.carbon_g.to_bits(), r_one.mean_delay_hours.to_bits()),
        "bare coordinator vs sharded(1) batched"
    );

    // Shape 3: two shards — topology differs from shape 1/2, but single and
    // batched ingest over the SAME topology must still match bitwise.
    let regions = shard_regions("2", &cfg.region).unwrap();
    let mut a = ShardedCoordinator::start(
        &cfg,
        &service,
        PolicyKind::CarbonAgnostic,
        &regions,
        DispatchStrategy::RoundRobin,
    );
    let r_single = drive(&mut a, &arrivals, 1, "single");
    a.shutdown();
    let mut b = ShardedCoordinator::start(
        &cfg,
        &service,
        PolicyKind::CarbonAgnostic,
        &regions,
        DispatchStrategy::RoundRobin,
    );
    let r_batch = drive(&mut b, &arrivals, 16, "batch");
    b.shutdown();
    assert_eq!(r_single.accepted, r_batch.accepted);
    assert!(
        r_single.drain_matches(&r_batch),
        "sharded(2) single {r_single:?} vs batched {r_batch:?}"
    );
}

#[test]
fn backpressure_shapes_are_visible_on_the_wire() {
    let mut cfg = small_cfg();
    cfg.capacity = 4;
    let mut service = ServiceConfig::default();
    service.max_pending = 2;
    let region = Region::parse(&cfg.region).unwrap();
    let mut cluster = ShardedCoordinator::start(
        &cfg,
        &service,
        PolicyKind::CarbonAgnostic,
        &[region],
        DispatchStrategy::RoundRobin,
    );

    let mut line = |s: &str| {
        let w = WireRequest::from_json_line(s).expect("parses");
        let v = w.v;
        let id = w.id.clone();
        let resp = cluster.handle_request(w.req);
        WireResponse { v, id, resp }
    };

    // Fill the queue via a batch, then watch the third member shed.
    let out = line(
        "{\"v\": 2, \"id\": \"b1\", \"op\": \"submit_batch\", \"jobs\": [\
         {\"workload\": \"Heat(N=1k)\", \"length_hours\": 2.0, \"queue\": 0},\
         {\"workload\": \"Heat(N=1k)\", \"length_hours\": 2.0, \"queue\": 1},\
         {\"workload\": \"Heat(N=1k)\", \"length_hours\": 2.0, \"queue\": 2}]}",
    );
    assert_eq!(out.id.as_deref(), Some("b1"));
    let Response::Batch { results } = &out.resp else {
        panic!("expected batch, got {:?}", out.resp);
    };
    assert_eq!(results.len(), 3);
    assert!(matches!(results[0], SubmitOutcome::Accepted { .. }));
    assert!(matches!(results[1], SubmitOutcome::Accepted { .. }));
    assert!(matches!(
        results[2],
        SubmitOutcome::Rejected { code: ErrorCode::QueueFull, .. }
    ));
    let encoded = out.to_json_line();
    assert!(encoded.contains("\"queue_full\""), "{encoded}");

    // Stats reflect the shed decision and queue depths.
    let out = line("{\"v\": 2, \"op\": \"stats\"}");
    let Response::Stats(stats) = &out.resp else {
        panic!("expected stats, got {:?}", out.resp);
    };
    assert_eq!(stats.accepted, 2);
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.pending, 2);

    // Legacy (no "v") lines still work and answer in the flat v1 shape.
    let out = line("{\"op\": \"status\"}");
    assert_eq!(out.v, 1);
    let encoded = out.to_json_line();
    assert!(encoded.contains("\"active_jobs\""), "{encoded}");
    assert!(!encoded.contains("\"kind\""), "{encoded}");

    let out = line("{\"v\": 2, \"op\": \"drain\"}");
    assert!(matches!(out.resp, Response::Drained { .. }), "{:?}", out.resp);
    // Post-drain requests answer with a typed draining error.
    let out = line("{\"v\": 2, \"op\": \"status\"}");
    assert!(
        matches!(out.resp, Response::Error { code: ErrorCode::Draining, .. }),
        "{:?}",
        out.resp
    );
    cluster.shutdown();
}
