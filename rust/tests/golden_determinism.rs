//! Golden determinism: the optimized engine must reproduce the recorded
//! metric fingerprints for every policy on the smoke-sized config, bit for
//! bit. Fingerprints cover headline metrics (raw f64 bits) plus an FNV-1a
//! digest of every slot record and job outcome (`SimResult::fingerprint`).
//!
//! Blessing: when `tests/golden/metric_fingerprints.txt` does not exist the
//! test writes it and passes — run once and commit the file to pin the
//! current engine output. Any later divergence (an optimization that is not
//! output-preserving) fails with a per-policy diff. Re-bless deliberately
//! by deleting the file.

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::runner::run_policies;
use carbonflex::sched::PolicyKind;

mod common;

/// Same shape as the sweep-determinism tiny config: small but exercises
/// learning, matching, oracle planning, and drain.
fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 12;
    cfg.horizon_hours = 48;
    cfg.history_hours = 72;
    cfg.replay_offsets = 1;
    cfg
}

/// The four policies of the golden set: the FCFS baseline, a planning
/// baseline, the CarbonFlex runtime (engine + KD-tree match), and the
/// oracle (engine + Alg. 1 + repair).
const GOLDEN_POLICIES: [PolicyKind; 4] =
    [PolicyKind::CarbonAgnostic, PolicyKind::Gaia, PolicyKind::CarbonFlex, PolicyKind::Oracle];

fn compute_fingerprints() -> Vec<String> {
    run_policies(&tiny_cfg(), &GOLDEN_POLICIES)
        .iter()
        .map(|row| format!("{}\t{}", row.kind.as_str(), row.result.fingerprint()))
        .collect()
}

#[test]
fn engine_reproduces_checked_in_fingerprints() {
    common::check_or_bless("metric_fingerprints.txt", &compute_fingerprints());
}

#[test]
fn repeated_runs_are_bitwise_identical() {
    // Independent of the golden file: two full pipeline runs (synthesis,
    // learning, matching, simulation) must agree on every bit.
    let a = compute_fingerprints();
    let b = compute_fingerprints();
    assert_eq!(a, b, "re-running the same config changed the output bits");
}
