//! Golden fingerprints for the composite sweep cells: one spatial
//! (multi-region + geo-dispatch) cell, one yearlong (week-window +
//! continuous learning) cell, and one DAG (precedence-gated workload)
//! cell, on smoke-sized configs.
//!
//! Blessing works like the other golden guards (see `common::check_or_bless`):
//! the first local run writes `tests/golden/scenario_fingerprints.txt` —
//! commit it to pin the cells bit for bit. On CI the `golden-fixtures` job
//! generates the file with `CARBONFLEX_BLESS=1` and uploads it as an
//! artifact, and warns while it remains uncommitted.

use carbonflex::config::ExperimentConfig;
use carbonflex::experiments::sweep::{SweepRunner, SweepSpec};
use carbonflex::experiments::DispatchStrategy;
use carbonflex::sched::PolicyKind;

mod common;

fn spatial_lines() -> Vec<String> {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 18; // 9 per region
    cfg.horizon_hours = 48;
    cfg.history_hours = 96;
    cfg.replay_offsets = 1;
    let mut spec = SweepSpec::new(cfg);
    spec.regions = vec!["south-australia+ontario".into()];
    spec.dispatchers = vec![DispatchStrategy::LowestWindowCi];
    spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
    SweepRunner::new(2)
        .run(&spec)
        .iter()
        .map(|r| {
            format!(
                "spatial/{}/{}/{}\t{}\tjobs={:?}",
                r.point.region,
                r.point.dispatch,
                r.kind.as_str(),
                r.result.fingerprint(),
                r.jobs_per_region.as_ref().expect("spatial row")
            )
        })
        .collect()
}

fn yearlong_lines() -> Vec<String> {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 12;
    cfg.history_hours = 168;
    cfg.replay_offsets = 1;
    let mut spec = SweepSpec::new(cfg);
    spec.weeks = vec![1]; // the chain still learns week 0 first
    spec.policies =
        vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];
    SweepRunner::new(2)
        .run(&spec)
        .iter()
        .map(|r| {
            format!(
                "yearlong/week{}/{}\t{}\tkb={}",
                r.point.week.expect("week cell"),
                r.kind.as_str(),
                r.result.fingerprint(),
                r.kb_live.expect("week row")
            )
        })
        .collect()
}

fn dag_lines() -> Vec<String> {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 12;
    cfg.horizon_hours = 48;
    cfg.history_hours = 96;
    cfg.replay_offsets = 1;
    let mut spec = SweepSpec::new(cfg);
    spec.dag_shapes = vec!["chains".into(), "mapreduce".into()];
    spec.policies =
        vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];
    SweepRunner::new(2)
        .run(&spec)
        .iter()
        .map(|r| {
            format!(
                "dag/{}/{}\t{}\tcompleted={}",
                r.point.dag_shape,
                r.kind.as_str(),
                r.result.fingerprint(),
                r.result.metrics.completed
            )
        })
        .collect()
}

#[test]
fn scenario_cells_reproduce_checked_in_fingerprints() {
    let mut lines = spatial_lines();
    lines.extend(yearlong_lines());
    lines.extend(dag_lines());
    common::check_or_bless("scenario_fingerprints.txt", &lines);
}

#[test]
fn scenario_cells_are_bitwise_repeatable() {
    // Independent of the golden file: two full runs of each composite cell
    // (synthesis, chained learning, dispatch, simulation) agree on every
    // bit, so the fingerprints above are stable things to pin.
    assert_eq!(spatial_lines(), spatial_lines(), "spatial cell not reproducible");
    assert_eq!(yearlong_lines(), yearlong_lines(), "yearlong cell not reproducible");
    assert_eq!(dag_lines(), dag_lines(), "dag cell not reproducible");
}
