//! The §Perf invariant: steady-state `ClusterEngine::step` performs no heap
//! allocation — first with a minimal base-scale policy (the engine floor),
//! then with the full CarbonFlex policy over a learned knowledge base, so
//! the flat KD-tree match, the neighbour/entry/ρ buffers, and the Alg. 2/3
//! loop are all inside the measured window. A counting global allocator
//! (this test binary only) snapshots the allocation count after a warmup
//! phase and asserts it does not move while the engine keeps stepping a
//! live cluster.
//!
//! Kept as a single `#[test]` so no concurrent test thread can allocate
//! inside the measured windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::trace::CarbonTrace;
use carbonflex::cluster::energy::EnergyModel;
use carbonflex::cluster::sim::{ClusterEngine, Simulator};
use carbonflex::config::Hardware;
use carbonflex::learning::kb::{Case, KnowledgeBase};
use carbonflex::learning::state::StateVector;
use carbonflex::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
use carbonflex::sched::{Decision, Policy, SlotCtx};
use carbonflex::workload::job::Job;
use carbonflex::workload::profile::ScalingProfile;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Base-scale scheduler that writes into the engine's reusable decision
/// buffer — the allocation-free path every hot policy follows.
struct BaseRunner;

impl Policy for BaseRunner {
    fn name(&self) -> &'static str {
        "base-runner"
    }
    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        out.capacity = ctx.max_capacity;
        out.alloc.clear();
        for v in ctx.jobs {
            out.alloc.push((v.job.id, v.job.k_min));
        }
    }
}

fn long_job(id: usize, arrival: usize) -> Job {
    Job {
        id,
        workload: "t",
        workload_idx: 0,
        arrival,
        // Far longer than the measured window, so the active set is stable
        // and no completion bookkeeping runs mid-measurement.
        length_hours: 10_000.0,
        queue: id % 3,
        slack_hours: 1e6,
        k_min: 1,
        k_max: 4,
        profile: ScalingProfile::from_comm_ratio(0.05, 4),
        watts_per_unit: 40.0,
        deps: Vec::new(),
    }
}

#[test]
fn steady_state_step_does_not_allocate() {
    const WARMUP: usize = 64;
    const MEASURED: usize = 256;
    const JOBS: usize = 24;

    let trace = CarbonTrace::new("flat", vec![120.0; WARMUP + MEASURED + 8]);
    let forecaster = Forecaster::perfect(trace);
    let sim = Simulator::new(64, EnergyModel::for_hardware(Hardware::Cpu), 3, WARMUP + MEASURED);
    let mut engine = ClusterEngine::new(sim);
    for i in 0..JOBS {
        engine.add_job(long_job(i, i)); // staggered arrivals, all inside warmup
    }
    engine.reserve(WARMUP + MEASURED + 8);
    let mut policy = BaseRunner;

    // Warmup: arrivals admitted, every reusable buffer grown to steady size.
    for t in 0..WARMUP {
        engine.step(t, &forecaster, &mut policy);
    }

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for t in WARMUP..WARMUP + MEASURED {
        engine.step(t, &forecaster, &mut policy);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "steady-state step() allocated {} time(s) over {MEASURED} slots",
        after - before
    );

    // The measured window did real work: every slot ran all jobs at base scale.
    let cols = engine.slot_columns();
    assert_eq!(cols.len(), WARMUP + MEASURED);
    assert!(
        cols.used[WARMUP..].iter().all(|&u| u as usize == JOBS),
        "cluster idled during measurement"
    );

    // --- Phase 2: the full CarbonFlex policy over a learned KB. Each slot
    // builds the Table 2 state, runs a k-NN match on the flat KD-tree into
    // the reusable hit/neighbour buffers, and executes Alg. 2/3 over the
    // recycled entry/granted/ρ buffers — none of which may allocate once
    // warm. ---
    let mut kb = KnowledgeBase::new();
    for i in 0..512usize {
        kb.push(Case {
            recorded_at: i,
            state: StateVector::from_raw(
                (i % 97) as f64 * 7.0,
                ((i % 13) as f64 - 6.0) * 10.0,
                (i % 11) as f64 / 10.0,
                &[i % 9, (i / 3) % 7, (i / 7) % 5],
                (i % 10) as f64 / 10.0,
            ),
            capacity: (i * 37) % 64,
            // ρ = 0 keeps the Alg. 3 candidate set slot-invariant, so the
            // entry buffer reaches its steady capacity during warmup.
            rho: 0.0,
        });
    }
    kb.rebuild();
    assert_eq!(kb.pending(), 0, "tree must cover every case before measuring");

    // A varying trace so the matched neighbours differ slot to slot.
    let hourly: Vec<f64> =
        (0..WARMUP + MEASURED + 32).map(|t| 250.0 + 200.0 * ((t % 24) as f64 / 24.0)).collect();
    let forecaster = Forecaster::perfect(CarbonTrace::new("varying", hourly));
    let sim = Simulator::new(64, EnergyModel::for_hardware(Hardware::Cpu), 3, WARMUP + MEASURED);
    let mut engine = ClusterEngine::new(sim);
    for i in 0..JOBS {
        engine.add_job(long_job(i, i));
    }
    engine.reserve(WARMUP + MEASURED + 8);
    let mut policy = CarbonFlex::new(kb, CarbonFlexParams::default());

    for t in 0..WARMUP {
        engine.step(t, &forecaster, &mut policy);
    }

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for t in WARMUP..WARMUP + MEASURED {
        engine.step(t, &forecaster, &mut policy);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "steady-state CarbonFlex step() allocated {} time(s) over {MEASURED} slots",
        after - before
    );

    // The measured window exercised the match + schedule path for real.
    let cols = engine.slot_columns();
    assert_eq!(cols.len(), WARMUP + MEASURED);
    assert!(
        cols.used[WARMUP..].iter().any(|&u| u > 0),
        "CarbonFlex scheduled nothing during measurement"
    );
}
