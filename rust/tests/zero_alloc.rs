//! The §Perf invariant: steady-state `ClusterEngine::step` performs no heap
//! allocation. A counting global allocator (this test binary only) snapshots
//! the allocation count after a warmup phase and asserts it does not move
//! while the engine keeps stepping a live cluster.
//!
//! Kept as a single `#[test]` so no concurrent test thread can allocate
//! inside the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::trace::CarbonTrace;
use carbonflex::cluster::energy::EnergyModel;
use carbonflex::cluster::sim::{ClusterEngine, Simulator};
use carbonflex::config::Hardware;
use carbonflex::sched::{Decision, Policy, SlotCtx};
use carbonflex::workload::job::Job;
use carbonflex::workload::profile::ScalingProfile;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Base-scale scheduler that writes into the engine's reusable decision
/// buffer — the allocation-free path every hot policy follows.
struct BaseRunner;

impl Policy for BaseRunner {
    fn name(&self) -> &'static str {
        "base-runner"
    }
    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        out.capacity = ctx.max_capacity;
        out.alloc.clear();
        for v in ctx.jobs {
            out.alloc.push((v.job.id, v.job.k_min));
        }
    }
}

fn long_job(id: usize, arrival: usize) -> Job {
    Job {
        id,
        workload: "t",
        workload_idx: 0,
        arrival,
        // Far longer than the measured window, so the active set is stable
        // and no completion bookkeeping runs mid-measurement.
        length_hours: 10_000.0,
        queue: id % 3,
        slack_hours: 1e6,
        k_min: 1,
        k_max: 4,
        profile: ScalingProfile::from_comm_ratio(0.05, 4),
        watts_per_unit: 40.0,
    }
}

#[test]
fn steady_state_step_does_not_allocate() {
    const WARMUP: usize = 64;
    const MEASURED: usize = 256;
    const JOBS: usize = 24;

    let trace = CarbonTrace::new("flat", vec![120.0; WARMUP + MEASURED + 8]);
    let forecaster = Forecaster::perfect(trace);
    let sim = Simulator::new(64, EnergyModel::for_hardware(Hardware::Cpu), 3, WARMUP + MEASURED);
    let mut engine = ClusterEngine::new(sim);
    for i in 0..JOBS {
        engine.add_job(long_job(i, i)); // staggered arrivals, all inside warmup
    }
    engine.reserve(WARMUP + MEASURED + 8);
    let mut policy = BaseRunner;

    // Warmup: arrivals admitted, every reusable buffer grown to steady size.
    for t in 0..WARMUP {
        engine.step(t, &forecaster, &mut policy);
    }

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for t in WARMUP..WARMUP + MEASURED {
        engine.step(t, &forecaster, &mut policy);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "steady-state step() allocated {} time(s) over {MEASURED} slots",
        after - before
    );

    // The measured window did real work: every slot ran all jobs at base scale.
    let slots = engine.slots();
    assert_eq!(slots.len(), WARMUP + MEASURED);
    assert!(slots[WARMUP..].iter().all(|s| s.used == JOBS), "cluster idled during measurement");
}
