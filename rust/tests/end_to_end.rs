//! End-to-end integration: the paper's headline claims must hold in shape
//! on the default configuration, the coordinator service must round-trip
//! jobs, and the config/CLI surface must load the shipped files.

use carbonflex::carbon::forecast::Forecaster;
use carbonflex::carbon::synth::{synthesize_year, Region};
use carbonflex::config::{ExperimentConfig, Hardware, ServiceConfig};
use carbonflex::coordinator::{Coordinator, CoordinatorConfig, Request, Response, SubmitRequest};
use carbonflex::experiments::runner::{run_policies, PreparedExperiment};
use carbonflex::sched::PolicyKind;

/// Reduced-size default: same structure as the paper's primary setting.
fn small_paper_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 40;
    cfg.horizon_hours = 120;
    cfg.history_hours = 240;
    cfg.replay_offsets = 3;
    cfg
}

#[test]
fn headline_ordering_holds() {
    // Fig. 6's qualitative result: Oracle > CarbonFlex > {suspend-resume
    // and non-preemptive baselines} > Agnostic. Run at the paper's full
    // scale (M=150, week horizon): the ordering is a scale-dependent
    // claim — tiny clusters flatter the non-elastic baselines.
    let rows = run_policies(&ExperimentConfig::default(), &PolicyKind::HEADLINE);
    let savings = |kind: PolicyKind| {
        rows.iter().find(|r| r.kind == kind).map(|r| r.savings_pct).unwrap()
    };
    let oracle = savings(PolicyKind::Oracle);
    let flex = savings(PolicyKind::CarbonFlex);
    let gaia = savings(PolicyKind::Gaia);
    assert!(oracle >= flex, "oracle {oracle} < flex {flex}");
    assert!(flex > gaia, "flex {flex} <= gaia {gaia}");
    assert!(flex > 20.0, "CarbonFlex saved only {flex}%");
    assert!(oracle > 35.0, "oracle saved only {oracle}%");
    assert!(savings(PolicyKind::CarbonAgnostic).abs() < 1e-9);
}

#[test]
fn savings_scale_with_trace_variability() {
    // Fig. 12's monotonicity: high-CoV regions admit more savings.
    let mut high = small_paper_cfg();
    high.region = "south-australia".into();
    let mut low = small_paper_cfg();
    low.region = "virginia".into();
    let sa = run_policies(&high, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    let va = run_policies(&low, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    assert!(sa > va + 10.0, "SA {sa}% vs VA {va}%");
    assert!(va < 12.0, "Virginia should admit little saving, got {va}%");
    // And the CoV ordering itself (Fig. 5):
    assert!(
        synthesize_year(Region::SouthAustralia, 1).daily_cov()
            > synthesize_year(Region::Virginia, 1).daily_cov() * 5.0
    );
}

#[test]
fn slack_increases_savings() {
    // Fig. 9a: more slack, more savings (diminishing but monotone-ish).
    let mut d0 = small_paper_cfg();
    d0.uniform_delay_hours = Some(0.0);
    let mut d24 = small_paper_cfg();
    d24.uniform_delay_hours = Some(24.0);
    let s0 = run_policies(&d0, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    let s24 = run_policies(&d24, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    assert!(s24 > s0 + 5.0, "d=0 {s0}% vs d=24 {s24}%");
}

#[test]
fn elasticity_increases_savings() {
    // Fig. 10: High-elasticity workloads save more than NoScaling ones.
    use carbonflex::config::ElasticityScenario;
    let mut hi = small_paper_cfg();
    hi.elasticity = ElasticityScenario::High;
    let mut none = small_paper_cfg();
    none.elasticity = ElasticityScenario::NoScaling;
    let s_hi = run_policies(&hi, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    let s_none = run_policies(&none, &[PolicyKind::Oracle]).pop().unwrap().savings_pct;
    assert!(s_hi > s_none, "high {s_hi}% vs noscaling {s_none}%");
}

#[test]
fn learning_phase_is_transferable() {
    // The KB learned on one window must still beat agnostic on a shifted
    // workload (Fig. 13's premise).
    let mut cfg = small_paper_cfg();
    cfg.arrival_scale = 1.15;
    cfg.length_scale = 1.15;
    let rows = run_policies(&cfg, &[PolicyKind::CarbonFlex]);
    assert!(rows[0].savings_pct > 10.0, "shifted savings {}", rows[0].savings_pct);
    assert_eq!(rows[0].result.metrics.unfinished, 0);
}

#[test]
fn coordinator_json_protocol_round_trip() {
    let trace = synthesize_year(Region::Ontario, 3).slice(0, 400);
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_capacity: 8,
            hardware: Hardware::Cpu,
            num_queues: 3,
            queue_slack_hours: vec![6.0, 24.0, 48.0],
            horizon: 120,
            service: ServiceConfig::default(),
        },
        Forecaster::perfect(trace),
        Box::new(carbonflex::sched::carbon_agnostic::CarbonAgnostic),
    );
    let h = coord.handle();

    // Drive it purely through the wire format.
    let submit = Request::Submit(SubmitRequest {
        workload: "Jacobi(N=2k)".into(),
        length_hours: 3.0,
        queue: 1,
    });
    let line = submit.to_json_line();
    let parsed = Request::from_json_line(&line).unwrap();
    let resp = h.request(parsed);
    assert!(matches!(resp, Response::Submitted { job_id: 0 }), "{resp:?}");
    // Response survives its own wire format.
    let resp2 = Response::from_json_line(&resp.to_json_line()).unwrap();
    assert_eq!(resp, resp2);

    h.request(Request::Tick);
    match h.request(Request::Status) {
        Response::Status(s) => {
            assert_eq!(s.active_jobs, 1);
            assert_eq!(s.used, 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.completed, 1);
}

#[test]
fn shipped_configs_load_and_run() {
    // Every file in configs/ must parse, validate, and drive a short run.
    let dir = std::path::Path::new("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "toml").unwrap_or(false) {
            found += 1;
            let mut cfg = ExperimentConfig::load(&path)
                .unwrap_or_else(|e| panic!("{path:?}: {e}"));
            // Shrink for test speed, keeping the config's structure.
            cfg.capacity = cfg.capacity.min(20);
            cfg.horizon_hours = cfg.horizon_hours.min(48);
            cfg.history_hours = cfg.history_hours.min(96).max(cfg.horizon_hours);
            cfg.replay_offsets = 1;
            let prep = PreparedExperiment::prepare(&cfg);
            let r = prep.run(PolicyKind::CarbonAgnostic);
            assert_eq!(r.metrics.unfinished, 0, "{path:?}");
        }
    }
    assert!(found >= 3, "expected shipped configs, found {found}");
}

#[test]
fn knowledge_base_round_trips_through_disk() {
    let prep = PreparedExperiment::prepare(&{
        let mut cfg = small_paper_cfg();
        cfg.capacity = 12;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        cfg
    });
    let kb = prep.knowledge_base();
    let dir = std::env::temp_dir().join("carbonflex_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("kb.csv");
    kb.save_csv(&path).unwrap();
    let loaded = carbonflex::learning::kb::KnowledgeBase::load_csv(&path).unwrap();
    assert_eq!(loaded.cases().len(), kb.cases().len());
    // Matching through the loaded KB works.
    use carbonflex::learning::kb::Matcher;
    let q = carbonflex::learning::state::StateVector::from_raw(200.0, 0.0, 0.4, &[3, 2, 1], 0.6);
    assert_eq!(loaded.top_k(&q, 5).len(), 5);
}
