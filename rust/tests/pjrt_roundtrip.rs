//! Integration: the AOT-compiled Pallas kernels executed via PJRT must agree
//! with the native Rust implementations. Skipped (with a notice) when
//! `artifacts/` has not been built — run `make artifacts` first.

use carbonflex::learning::kb::{Case, KnowledgeBase, Matcher};
use carbonflex::learning::state::StateVector;
use carbonflex::runtime::engine::Engine;
use carbonflex::runtime::matcher::PjrtMatcher;
use carbonflex::runtime::score::{score_native, ScoreKernel};
use carbonflex::util::rng::Rng;

fn engine() -> Option<Engine> {
    match Engine::cpu("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP pjrt tests: {err}");
            None
        }
    }
}

fn random_kb(n: usize, seed: u64) -> KnowledgeBase {
    let mut rng = Rng::new(seed);
    let mut kb = KnowledgeBase::new();
    for i in 0..n {
        kb.push(Case {
            recorded_at: i,
            state: StateVector::from_raw(
                rng.range(10.0, 700.0),
                rng.range(-80.0, 80.0),
                rng.f64(),
                &[rng.below(40), rng.below(40), rng.below(40)],
                rng.f64(),
            ),
            capacity: rng.below(151),
            rho: rng.range(0.2, 1.01),
        });
    }
    kb.rebuild();
    kb
}

#[test]
fn pjrt_matcher_agrees_with_native_kdtree() {
    let Some(engine) = engine() else { return };
    let kb = random_kb(1000, 42);
    let matcher = PjrtMatcher::from_kb(&engine, &kb).expect("matcher builds");
    assert_eq!(matcher.len(), 1000);

    let mut rng = Rng::new(7);
    for case in 0..50 {
        let query = StateVector::from_raw(
            rng.range(10.0, 700.0),
            rng.range(-80.0, 80.0),
            rng.f64(),
            &[rng.below(40), rng.below(40), rng.below(40)],
            rng.f64(),
        );
        let native = kb.top_k(&query, 5);
        let pjrt = matcher.top_k(&query, 5);
        assert_eq!(native.len(), pjrt.len(), "case {case}");
        for (i, (n, p)) in native.iter().zip(&pjrt).enumerate() {
            assert!(
                (n.dist - p.dist).abs() < 1e-3,
                "case {case} rank {i}: native dist {} pjrt {}",
                n.dist,
                p.dist
            );
            // Ties may reorder equal-distance neighbours; compare decisions
            // only when distances are clearly distinct.
            let distinct = i + 1 == native.len()
                || (native[i + 1].dist - n.dist).abs() > 1e-6;
            if distinct {
                assert_eq!(n.capacity, p.capacity, "case {case} rank {i}");
                assert!((n.rho - p.rho).abs() < 1e-4, "case {case} rank {i}");
            }
        }
    }
}

#[test]
fn pjrt_matcher_handles_small_kb() {
    let Some(engine) = engine() else { return };
    let kb = random_kb(3, 9);
    let matcher = PjrtMatcher::from_kb(&engine, &kb).unwrap();
    let query = StateVector::from_raw(200.0, 0.0, 0.5, &[1, 2, 3], 0.5);
    // Only 3 valid cases → at most 3 neighbours even when asking for 5.
    let hits = matcher.top_k(&query, 5);
    assert_eq!(hits.len(), 3);
    // Padding rows must never appear (their distance would be enormous).
    assert!(hits.iter().all(|h| h.dist < 1e3), "{hits:?}");
}

#[test]
fn pjrt_matcher_truncates_oversized_kb() {
    let Some(engine) = engine() else { return };
    let kb = random_kb(5000, 11); // > 4096 compiled cases
    let matcher = PjrtMatcher::from_kb(&engine, &kb).unwrap();
    assert_eq!(matcher.len(), 4096);
    let query = StateVector::from_raw(300.0, 10.0, 0.4, &[5, 5, 5], 0.6);
    assert_eq!(matcher.top_k(&query, 5).len(), 5);
}

#[test]
fn pjrt_score_kernel_matches_native() {
    let Some(engine) = engine() else { return };
    let kernel = ScoreKernel::load(&engine).expect("score kernel loads");
    let (jk, t) = kernel.shape();
    let mut rng = Rng::new(13);
    let marginals: Vec<f32> = (0..jk).map(|_| rng.f64() as f32).collect();
    let ci: Vec<f32> = (0..t).map(|_| rng.range(10.0, 700.0) as f32).collect();
    let window: Vec<f32> = (0..jk * t).map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 }).collect();

    let got = kernel.run(&marginals, &ci, &window).expect("score runs");
    let want = score_native(&marginals, &ci, &window);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-6 + 1e-4 * w.abs(), "idx {i}: {g} vs {w}");
    }
}

#[test]
fn pjrt_end_to_end_carbonflex_policy() {
    // The full hot path: CarbonFlex scheduling with the PJRT matcher backend.
    let Some(engine) = engine() else { return };
    use carbonflex::carbon::forecast::Forecaster;
    use carbonflex::cluster::energy::EnergyModel;
    use carbonflex::cluster::sim::Simulator;
    use carbonflex::config::{ExperimentConfig, Hardware};
    use carbonflex::experiments::runner::PreparedExperiment;
    use carbonflex::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
    use carbonflex::sched::PolicyKind;

    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 30;
    cfg.horizon_hours = 72;
    cfg.history_hours = 120;
    cfg.replay_offsets = 2;
    let prep = PreparedExperiment::prepare(&cfg);
    let native = prep.run(PolicyKind::CarbonFlex);

    let matcher = PjrtMatcher::from_kb(&engine, prep.knowledge_base()).unwrap();
    let mut policy = CarbonFlex::new(matcher, CarbonFlexParams::default());
    let sim = Simulator::new(
        cfg.capacity,
        EnergyModel::for_hardware(Hardware::Cpu),
        cfg.queues.len(),
        cfg.horizon_hours,
    );
    let forecaster = Forecaster::perfect(prep.eval_trace.clone());
    let pjrt = sim.run(&prep.eval_jobs, &forecaster, &mut policy);

    assert_eq!(pjrt.metrics.completed, native.metrics.completed);
    // Decisions should be near-identical (f32 rounding can flip rare ties).
    let rel = (pjrt.metrics.carbon_g - native.metrics.carbon_g).abs()
        / native.metrics.carbon_g.max(1.0);
    assert!(rel < 0.02, "pjrt {} vs native {}", pjrt.metrics.carbon_g, native.metrics.carbon_g);
}
