//! Property tests over randomized instances: every policy must uphold the
//! cluster invariants the paper's prototype (Slurm) would physically
//! enforce, on any workload/carbon trace the generators can produce.

use carbonflex::carbon::synth::{self, Region};
use carbonflex::cluster::sim::SimResult;
use carbonflex::config::{ElasticityScenario, ExperimentConfig, TraceFamily};
use carbonflex::experiments::runner::PreparedExperiment;
use carbonflex::sched::PolicyKind;
use carbonflex::util::proptest_lite::{check, Config};
use carbonflex::util::rng::Rng;
use carbonflex::workload::tracegen;

/// A randomized experimental setting.
#[derive(Debug)]
struct Instance {
    cfg: ExperimentConfig,
}

fn random_instance(rng: &mut Rng) -> Instance {
    let mut cfg = ExperimentConfig::default();
    cfg.seed = rng.next_u64();
    cfg.capacity = 4 + rng.below(28);
    cfg.horizon_hours = 48 + 24 * rng.below(3);
    cfg.history_hours = cfg.horizon_hours + 24 + 24 * rng.below(3);
    cfg.replay_offsets = 1 + rng.below(2);
    cfg.target_utilization = rng.range(0.25, 0.7);
    cfg.region = rng
        .choose(&[Region::SouthAustralia, Region::California, Region::Ontario, Region::Virginia])
        .key()
        .to_string();
    cfg.trace = *rng.choose(&[
        TraceFamily::AzureLike,
        TraceFamily::AlibabaLike,
        TraceFamily::SurfLike,
    ]);
    cfg.elasticity = *rng.choose(&[
        ElasticityScenario::Mix,
        ElasticityScenario::High,
        ElasticityScenario::Low,
        ElasticityScenario::NoScaling,
    ]);
    Instance { cfg }
}

fn run(instance: &Instance, kind: PolicyKind) -> SimResult {
    let prep = PreparedExperiment::prepare(&instance.cfg);
    prep.run(kind)
}

fn assert_invariants(instance: &Instance, kind: PolicyKind, r: &SimResult) -> Result<(), String> {
    let m = &r.metrics;
    // 1. Work conservation: every job completes.
    if m.unfinished != 0 {
        return Err(format!("{kind:?}: {} unfinished jobs", m.unfinished));
    }
    // 2. Physical capacity is never exceeded.
    if let Some(bad) = r.slots.iter().find(|s| s.used > instance.cfg.capacity) {
        return Err(format!(
            "{kind:?}: capacity exceeded at t={} ({} > {})",
            bad.t, bad.used, instance.cfg.capacity
        ));
    }
    // 3. Energy and carbon are positive and consistent between the slot
    //    ledger and the per-job ledger (boot overheads are tracked apart).
    if m.energy_kwh <= 0.0 || m.carbon_g <= 0.0 {
        return Err(format!("{kind:?}: non-positive energy/carbon"));
    }
    let slot_carbon: f64 = r.slots.iter().map(|s| s.carbon_g).sum();
    let outcome_carbon: f64 = r.outcomes.iter().map(|o| o.carbon_g).sum();
    if (slot_carbon - outcome_carbon).abs() > 1e-6 * outcome_carbon.max(1.0) {
        return Err(format!(
            "{kind:?}: slot carbon {slot_carbon} != outcome carbon {outcome_carbon}"
        ));
    }
    // 4. No job finishes before it arrives.
    for o in &r.outcomes {
        if o.completion < o.arrival {
            return Err(format!("{kind:?}: job {} finished before arriving", o.id));
        }
    }
    Ok(())
}

#[test]
fn invariants_hold_for_all_policies_on_random_instances() {
    // Full policy grid over random instances (each instance runs all 8
    // policies; kept modest so the suite stays fast).
    check(
        "policy invariants",
        Config { cases: 6, seed: 0x1234_5678 },
        random_instance,
        |instance| {
            for kind in PolicyKind::ALL {
                let r = run(instance, kind);
                assert_invariants(instance, kind, &r)?;
            }
            Ok(())
        },
    );
}

#[test]
fn oracle_never_loses_to_agnostic() {
    check(
        "oracle dominates agnostic",
        Config { cases: 8, seed: 0xBEEF },
        random_instance,
        |instance| {
            let agnostic = run(instance, PolicyKind::CarbonAgnostic);
            let oracle = run(instance, PolicyKind::Oracle);
            // Small tolerance: checkpoint/boot overheads can cost a sliver
            // on near-flat traces.
            if oracle.metrics.carbon_g > agnostic.metrics.carbon_g * 1.02 {
                return Err(format!(
                    "oracle {} > agnostic {}",
                    oracle.metrics.carbon_g, agnostic.metrics.carbon_g
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn deterministic_given_config() {
    let mut rng = Rng::new(77);
    let instance = random_instance(&mut rng);
    let a = run(&instance, PolicyKind::CarbonFlex);
    let b = run(&instance, PolicyKind::CarbonFlex);
    assert_eq!(a.metrics.carbon_g, b.metrics.carbon_g);
    assert_eq!(a.metrics.completed, b.metrics.completed);
    assert_eq!(a.metrics.mean_delay_hours, b.metrics.mean_delay_hours);
}

#[test]
fn forced_runs_bound_worst_case_delay() {
    // Sanity-bound on tail latency: delay ≤ slack + length + horizon + 24.
    let mut rng = Rng::new(99);
    for _ in 0..4 {
        let instance = random_instance(&mut rng);
        for kind in [PolicyKind::WaitAwhile, PolicyKind::CarbonFlex, PolicyKind::Gaia] {
            let r = run(&instance, kind);
            for o in &r.outcomes {
                let bound =
                    o.slack_hours + o.length_hours + instance.cfg.horizon_hours as f64 + 24.0;
                assert!(
                    o.delay_hours() <= bound,
                    "{kind:?}: job {} delay {} exceeds bound {}",
                    o.id,
                    o.delay_hours(),
                    bound
                );
            }
        }
    }
}

#[test]
fn carbon_trace_generators_are_well_formed() {
    check(
        "trace well-formedness",
        Config { cases: 24, seed: 0xD00D },
        |rng| (*rng.choose(&Region::ALL), 200 + rng.below(800), rng.next_u64()),
        |(region, hours, seed)| {
            let t = synth::synthesize(*region, *hours, *seed);
            if t.len() != *hours {
                return Err("wrong length".into());
            }
            if !t.hourly.iter().all(|&c| c.is_finite() && c > 0.0) {
                return Err("non-positive or non-finite CI".into());
            }
            Ok(())
        },
    );
}

#[test]
fn workload_generator_respects_config() {
    check(
        "tracegen well-formedness",
        Config { cases: 16, seed: 0xFEED },
        |rng| {
            let mut cfg = ExperimentConfig::default();
            cfg.seed = rng.next_u64();
            cfg.capacity = 8 + rng.below(80);
            cfg.target_utilization = rng.range(0.2, 0.8);
            (cfg, 72 + rng.below(200))
        },
        |(cfg, horizon)| {
            let jobs = tracegen::generate(cfg, *horizon, cfg.seed);
            for j in &jobs {
                if j.arrival >= *horizon {
                    return Err(format!("job {} arrives past horizon", j.id));
                }
                if j.length_hours < 1.0 || j.length_hours > 96.0 {
                    return Err(format!("job {} length {} out of range", j.id, j.length_hours));
                }
                if j.k_min > j.k_max || j.k_max > 16 {
                    return Err(format!("job {} bad scale range", j.id));
                }
            }
            let u = tracegen::implied_utilization(&jobs, cfg.capacity, *horizon);
            if (u - cfg.target_utilization).abs() > 0.2 {
                return Err(format!("utilization {u} far from target {}", cfg.target_utilization));
            }
            Ok(())
        },
    );
}

#[test]
fn noscaling_scenario_never_scales() {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 16;
    cfg.horizon_hours = 48;
    cfg.history_hours = 96;
    cfg.replay_offsets = 1;
    cfg.elasticity = ElasticityScenario::NoScaling;
    let prep = PreparedExperiment::prepare(&cfg);
    for kind in [PolicyKind::CarbonFlex, PolicyKind::Oracle, PolicyKind::CarbonScaler] {
        let r = prep.run(kind);
        assert!(
            r.slots.iter().all(|s| s.rho >= 1.0),
            "{kind:?} scaled a NoScaling workload"
        );
        assert_eq!(r.metrics.unfinished, 0);
    }
}

#[test]
fn energy_model_consistency_under_load() {
    // Energy scales with utilization: doubling the arrival rate should
    // roughly double the agnostic baseline's energy.
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 40;
    cfg.horizon_hours = 72;
    cfg.history_hours = 96;
    cfg.target_utilization = 0.25;
    let low = run(&Instance { cfg: cfg.clone() }, PolicyKind::CarbonAgnostic);
    cfg.target_utilization = 0.5;
    let high = run(&Instance { cfg }, PolicyKind::CarbonAgnostic);
    let ratio = high.metrics.energy_kwh / low.metrics.energy_kwh;
    assert!((1.5..2.6).contains(&ratio), "energy ratio {ratio}");
}
