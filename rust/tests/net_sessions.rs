//! Session-transport integration properties: seeded link-fault plans must
//! not change what the cluster computes (bitwise drain identity),
//! reconnect-with-resume must preserve exactly-once, and server-side dedup
//! must make double-submitted sequence numbers a no-op.

use std::sync::{Arc, Mutex};

use carbonflex::config::{ExperimentConfig, ServiceConfig};
use carbonflex::coordinator::{
    drive, drive_session, shard_regions, submissions_of, take_cluster, DriveReport,
    FrameHandler, LoopbackTransport, Request, SessionClient, SessionConfig, SessionCounters,
    SessionServer, ShardedCoordinator, SubmitRequest, WireRequest,
};
use carbonflex::experiments::DispatchStrategy;
use carbonflex::faults::net::{LinkFaultSpec, LinkPlan};
use carbonflex::sched::PolicyKind;
use carbonflex::util::json::{self, Json};
use carbonflex::util::proptest_lite::{check, Config};
use carbonflex::util::rng::Rng;
use carbonflex::workload::tracegen;

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.capacity = 8;
    cfg.horizon_hours = 48;
    cfg.history_hours = 72;
    cfg.replay_offsets = 1;
    cfg
}

fn small_cluster(cfg: &ExperimentConfig) -> ShardedCoordinator {
    let service = ServiceConfig::default();
    let regions = shard_regions("1", &cfg.region).unwrap();
    ShardedCoordinator::start(
        cfg,
        &service,
        PolicyKind::CarbonAgnostic,
        &regions,
        DispatchStrategy::RoundRobin,
    )
}

/// The stdio reference drive for one arrival stream.
fn stdio_baseline(cfg: &ExperimentConfig, arrivals: &[(usize, SubmitRequest)]) -> DriveReport {
    let mut cluster = small_cluster(cfg);
    let report = drive(&mut cluster, arrivals, 1, "stdio");
    cluster.shutdown();
    report
}

/// Drive the same stream through a session over a loopback link carrying
/// `plan`; returns the drive report plus both sides' telemetry.
fn session_run(
    cfg: &ExperimentConfig,
    arrivals: &[(usize, SubmitRequest)],
    plan: LinkPlan,
    seed: u64,
    window: usize,
) -> (DriveReport, SessionCounters, carbonflex::coordinator::SessionStats) {
    let server = Arc::new(Mutex::new(SessionServer::new(
        small_cluster(cfg),
        SessionConfig::default(),
    )));
    let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
    let mut client = SessionClient::new(
        Box::new(LoopbackTransport::new(handler, plan)),
        "prop-client",
        seed,
    );
    let report = drive_session(&mut client, arrivals, window, "session")
        .expect("session drive must survive the seeded plan");
    let stats = client.stats();
    drop(client);
    let counters = server.lock().unwrap().counters();
    let cluster = take_cluster(server).expect("no other holders after drive");
    cluster.shutdown();
    (report, counters, stats)
}

#[derive(Debug)]
struct PlanCase {
    plan_seed: u64,
    preset: &'static str,
    jobs: usize,
    window: usize,
}

/// Property (i): any seeded drop/dup/reorder/disconnect plan drains
/// bitwise identical to the clean stdio run — link faults may cost
/// retries, never results.
#[test]
fn seeded_fault_plans_drain_bitwise_identical() {
    let cfg = small_cfg();
    let trace = tracegen::generate_n(&cfg, 48, 17, 40);
    let arrivals = submissions_of(&trace);
    let baseline = stdio_baseline(&cfg, &arrivals);
    assert_eq!(baseline.completed, baseline.accepted);
    check(
        "fault plans preserve drain identity",
        Config { cases: 8, seed: 0x5E55_10A1 },
        |r: &mut Rng| PlanCase {
            plan_seed: r.next_u64(),
            preset: ["light", "heavy"][r.below(2)],
            jobs: 40,
            window: 1 + r.below(24),
        },
        |case| {
            let spec = LinkFaultSpec::preset(case.preset).unwrap();
            let plan = LinkPlan::generate(case.plan_seed, &spec, case.jobs + 48 + 16);
            let (report, counters, _) =
                session_run(&cfg, &arrivals, plan, case.plan_seed, case.window);
            if !baseline.drain_matches(&report) {
                return Err(format!(
                    "drain diverged: baseline {baseline:?} vs faulted {report:?}"
                ));
            }
            if counters.accepted != report.accepted as u64 {
                return Err(format!(
                    "server ledger {} != client accepted {}",
                    counters.accepted, report.accepted
                ));
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct DisconnectCase {
    client_seed: u64,
    drop_at: usize,
    window: usize,
}

/// Property (ii): a forced mid-batch disconnect followed by
/// reconnect-with-resume preserves exactly-once — nothing lost, nothing
/// double-applied, drain still bitwise identical.
#[test]
fn reconnect_with_resume_preserves_exactly_once() {
    let cfg = small_cfg();
    let trace = tracegen::generate_n(&cfg, 48, 23, 30);
    let arrivals = submissions_of(&trace);
    let baseline = stdio_baseline(&cfg, &arrivals);
    check(
        "resume after disconnect is exactly-once",
        Config { cases: 8, seed: 0x0D15_C0FF },
        |r: &mut Rng| DisconnectCase {
            client_seed: r.next_u64(),
            drop_at: 1 + r.below(arrivals.len() - 1),
            window: 1 + r.below(8),
        },
        |case| {
            let server = Arc::new(Mutex::new(SessionServer::new(
                small_cluster(&cfg),
                SessionConfig::default(),
            )));
            let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
            let mut client = SessionClient::new(
                Box::new(LoopbackTransport::new(handler, LinkPlan::none())),
                "prop-resume",
                case.client_seed,
            );
            // Drive the stream by hand so the disconnect lands mid-batch:
            // once at least `drop_at` submissions are in (so a session
            // exists to resume), drop the link before the next window.
            let mut accepted = 0usize;
            let last_slot = arrivals.iter().map(|(t, _)| *t).max().unwrap_or(0);
            let mut cursor = 0usize;
            let mut submitted = 0usize;
            let mut forced = false;
            for t in 0..=last_slot {
                let start = cursor;
                while cursor < arrivals.len() && arrivals[cursor].0 == t {
                    cursor += 1;
                }
                for chunk in arrivals[start..cursor].chunks(case.window) {
                    if !forced && submitted >= case.drop_at && submitted > 0 {
                        client.force_disconnect();
                        forced = true;
                    }
                    submitted += chunk.len();
                    let reqs: Vec<Request> =
                        chunk.iter().map(|(_, s)| Request::Submit(s.clone())).collect();
                    for resp in client.pipeline(reqs).map_err(|e| e.to_string())? {
                        if matches!(resp, carbonflex::coordinator::Response::Submitted { .. })
                        {
                            accepted += 1;
                        }
                    }
                }
                client.request(Request::Tick).map_err(|e| e.to_string())?;
            }
            if !forced {
                // Late drop points can fall past the final window; drop
                // before the drain instead so every case reconnects once.
                client.force_disconnect();
            }
            let drained = client.request(Request::Drain).map_err(|e| e.to_string())?;
            let stats = client.stats();
            client.bye();
            let counters = server.lock().unwrap().counters();
            let cluster = take_cluster(server).expect("no other holders");
            cluster.shutdown();
            let completed = match drained {
                carbonflex::coordinator::Response::Drained { completed, carbon_g, .. } => {
                    if carbon_g.to_bits() != baseline.carbon_g.to_bits() {
                        return Err("carbon diverged from the stdio baseline".into());
                    }
                    completed
                }
                other => return Err(format!("unexpected drain response {other:?}")),
            };
            if completed != accepted || completed != baseline.completed {
                return Err(format!(
                    "exactly-once broken: accepted {accepted}, completed {completed}, \
                     baseline {}",
                    baseline.completed
                ));
            }
            if counters.accepted != accepted as u64 {
                return Err("server ledger disagrees with the client".into());
            }
            if stats.handshakes < 2 {
                return Err("forced disconnect never triggered a resume".into());
            }
            if counters.resumes == 0 {
                return Err("server saw no resume handshake".into());
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct DedupCase {
    submits: usize,
    resend: usize,
}

/// Property (iii): re-sending an already-applied sequence number returns
/// the cached response verbatim and never reaches the cluster — a
/// double-submitted seq is a no-op.
#[test]
fn dedup_makes_double_submitted_seqs_a_noop() {
    check(
        "dedup replays are no-ops",
        Config { cases: 8, seed: 0xDD_0B1 },
        |r: &mut Rng| {
            let submits = 2 + r.below(10);
            DedupCase { submits, resend: r.below(submits) }
        },
        |case| {
            let cfg = small_cfg();
            let mut server =
                SessionServer::new(small_cluster(&cfg), SessionConfig::default());
            let hello = server
                .handle_line(r#"{"op":"hello","client":"dedup-prop"}"#)
                .pop()
                .ok_or("no hello reply")?;
            let sid = json::parse(&hello)
                .map_err(|e| e.to_string())?
                .get("session")
                .and_then(Json::as_f64)
                .ok_or("hello reply missing session")? as u64;
            let frame = |seq: u64| {
                WireRequest::new(Request::Submit(SubmitRequest {
                    workload: "N-body(N=100k)".to_string(),
                    length_hours: 2.0,
                    queue: 0,
                }))
                .to_json_line_with(&[
                    ("session", Json::num(sid as f64)),
                    ("seq", Json::num(seq as f64)),
                ])
            };
            let mut firsts = Vec::new();
            for seq in 0..case.submits as u64 {
                let mut out = server.handle_line(&frame(seq));
                if out.len() != 1 {
                    return Err(format!("expected one response, got {out:?}"));
                }
                firsts.push(out.pop().unwrap());
            }
            let before = server.counters();
            // Double-submit one seq, then the whole prefix again.
            let replay = server
                .handle_line(&frame(case.resend as u64))
                .pop()
                .ok_or("dedup returned nothing")?;
            if replay != firsts[case.resend] {
                return Err(format!(
                    "cached replay differs: {replay} vs {}",
                    firsts[case.resend]
                ));
            }
            for seq in 0..case.submits as u64 {
                let again = server.handle_line(&frame(seq)).pop().ok_or("no replay")?;
                if again != firsts[seq as usize] {
                    return Err("full-prefix replay diverged".into());
                }
            }
            let after = server.counters();
            if after.accepted != before.accepted {
                return Err(format!(
                    "replays reached the cluster: accepted {} -> {}",
                    before.accepted, after.accepted
                ));
            }
            if after.dedup_hits != before.dedup_hits + 1 + case.submits as u64 {
                return Err(format!(
                    "dedup hits off: {} -> {} for {} replays",
                    before.dedup_hits,
                    after.dedup_hits,
                    1 + case.submits
                ));
            }
            server.into_cluster().shutdown();
            Ok(())
        },
    );
}
