//! Tiny non-cryptographic hashing (FNV-1a), used for the golden
//! determinism fingerprints: a stable 64-bit digest of per-slot records
//! that must survive engine refactors bit for bit.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fold(0xcbf2_9ce4_8422_2325, bytes)
}

/// Fold more bytes into an existing FNV-1a state (seed with
/// [`FNV_OFFSET`], or chain from a previous digest).
pub fn fold(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(0x0000_0100_0000_01b3);
    }
    state
}

/// The FNV-1a offset basis (initial state for [`fold`]).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn folding_is_concatenation() {
        let whole = fnv1a64(b"hello world");
        let halves = fold(fold(FNV_OFFSET, b"hello "), b"world");
        assert_eq!(whole, halves);
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        assert_ne!(fnv1a64(b"slot:1"), fnv1a64(b"slot:2"));
    }
}
