//! Hand-rolled property-testing substrate (no `proptest` offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs from a
//! seeded generator; on failure it reports the case index and seed so the
//! exact input can be regenerated. Generators compose via plain closures
//! over [`crate::util::rng::Rng`].

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xCAFE_F00D }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`. Panics with the failing
/// case number and seed on the first violation (message from `prop`'s Err).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = root.fork(case as u64);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}):\n  {msg}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Like [`check`] but with the default config.
pub fn quickcheck<T: std::fmt::Debug>(
    name: &str,
    generate: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(name, Config::default(), generate, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        quickcheck(
            "abs is non-negative",
            |r| r.normal_ms(0.0, 10.0),
            |x| if x.abs() >= 0.0 { Ok(()) } else { Err("negative abs".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        quickcheck("always-fails", |r| r.f64(), |_| Err("always-fails".into()));
    }

    #[test]
    fn generator_sees_distinct_inputs() {
        let mut seen = std::collections::BTreeSet::new();
        check(
            "inputs vary",
            Config { cases: 32, seed: 1 },
            |r| r.next_u64(),
            |x| {
                seen.insert(*x);
                Ok(())
            },
        );
        assert!(seen.len() > 30);
    }
}
