//! Minimal JSON substrate (emit + parse).
//!
//! Used for (a) the artifact metadata file written by `python/compile/aot.py`
//! (shapes of the AOT-compiled kernels), and (b) the coordinator's line
//! protocol. The offline environment has no `serde`, so this is a small,
//! strict, dependency-free implementation covering the JSON we produce.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (sufficient for our metadata).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric value from anything convertible to f64 (usize, u64 counters…).
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { pos: self.pos, msg: "bad \\u".into() })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| JsonError { pos: self.pos, msg: "invalid utf8".into() })?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn nested_access() {
        let v = parse(r#"{"shapes": {"cases": 4096, "features": 8}}"#).unwrap();
        assert_eq!(v.get("shapes").unwrap().get("cases").unwrap().as_usize(), Some(4096));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA"));
        // emit side
        assert_eq!(Json::Str("a\"b".into()).to_string(), r#""a\"b""#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""héllo — ok""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }
}
