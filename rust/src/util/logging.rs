//! Tiny leveled stderr logger (no `env_logger` offline).
//!
//! Controlled by `CARBONFLEX_LOG` = error|warn|info|debug|trace (default info).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = std::env::var("CARBONFLEX_LOG")
            .map(|s| Level::from_env(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    // Safety: only valid discriminants are stored.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically (tests, CLI `--verbose`).
pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// True if a message at `lvl` would be printed.
pub fn enabled(lvl: Level) -> bool {
    lvl <= current_level()
}

/// Core log fn — prefer the `log_*!` macros.
pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5}] {}: {}", lvl.as_str(), target, msg);
    }
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn from_env_strings() {
        assert_eq!(Level::from_env("TRACE"), Level::Trace);
        assert_eq!(Level::from_env("bogus"), Level::Info);
    }
}
