//! Minimal command-line argument parser (no `clap` offline).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`; `-h/--help` is
//! handled by the caller via [`Args::flag`].

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional arguments (after the subcommand).
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    args.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = it.next().unwrap();
                    args.options.insert(rest.to_string(), val);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option access with a parse-or-default contract.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Option<Result<T, String>> {
        self.get(name).map(|s| {
            s.parse::<T>().map_err(|_| format!("invalid value for --{name}: '{s}'"))
        })
    }

    /// Typed option with default; returns Err on malformed input.
    pub fn num_or<T: std::str::FromStr + Copy>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get_parsed::<T>(name) {
            None => Ok(default),
            Some(r) => r,
        }
    }

    /// Comma-separated typed list: `--name 1,2,3`. Absent or empty option
    /// yields an empty vec ("axis not given"); any malformed entry is an Err.
    pub fn num_list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>, String> {
        match self.get(name) {
            None => Ok(Vec::new()),
            Some(raw) => raw
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|s| s.parse::<T>().map_err(|_| format!("invalid --{name} entry '{s}'")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("simulate pos1 --config configs/fig6.toml --seed 7 --quiet");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get("config"), Some("configs/fig6.toml"));
        assert_eq!(a.num_or::<u64>("seed", 0).unwrap(), 7);
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("learn --window=336 --offsets=4");
        assert_eq!(a.num_or::<usize>("window", 0).unwrap(), 336);
        assert_eq!(a.num_or::<usize>("offsets", 0).unwrap(), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
        assert!(a.get("verbose").is_none());
    }

    #[test]
    fn malformed_number_is_error() {
        let a = parse("run --seed abc");
        assert!(a.num_or::<u64>("seed", 0).is_err());
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.command.is_none());
        assert!(a.options.is_empty());
    }

    #[test]
    fn num_list_parses_and_rejects() {
        let a = parse("sweep --seeds 1,2, 3 --capacities 10,oops");
        assert_eq!(a.num_list::<u64>("seeds").unwrap(), vec![1, 2]);
        assert!(a.num_list::<usize>("capacities").is_err());
        assert!(a.num_list::<usize>("absent").unwrap().is_empty());
    }
}
