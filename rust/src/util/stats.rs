//! Descriptive statistics used by the trace synthesizers, metrics, and the
//! bench harness: mean, variance, coefficient of variation, percentiles,
//! histograms, and simple linear regression (for gradient estimation).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (σ/μ); 0.0 if mean is ~0.
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-12 {
        return 0.0;
    }
    std_dev(xs) / m
}

/// Mean per-day coefficient of variation — the "daily variability" metric of
/// the paper's Fig. 5: CoV computed within each 24-sample day, averaged over
/// days.
pub fn daily_cov(hourly: &[f64]) -> f64 {
    if hourly.len() < 24 {
        return cov(hourly);
    }
    let days = hourly.len() / 24;
    let covs: Vec<f64> = (0..days).map(|d| cov(&hourly[d * 24..(d + 1) * 24])).collect();
    mean(&covs)
}

/// p-th percentile (0..=100) by linear interpolation; 0.0 for empty input
/// (a percentile over no samples has no meaningful value, and serving-path
/// callers must never panic on an empty latency window).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp, not partial_cmp().unwrap(): a single NaN (e.g. a 0/0 ratio
    // upstream) must not panic the metrics path. IEEE total order sorts NaN
    // above +inf, so finite percentiles stay exactly where they were.
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// p-th percentile over an already-sorted slice; 0.0 for empty input.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Min of a slice (NaN-free input assumed); panics on empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a slice; panics on empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Rank of `x` within `window` as a fraction in [0,1]: 0 = lowest value.
/// Used for the day-ahead carbon-intensity rank feature (Table 2, CI^R).
pub fn rank_fraction(x: f64, window: &[f64]) -> f64 {
    if window.is_empty() {
        return 0.5;
    }
    let below = window.iter().filter(|&&w| w < x).count();
    below as f64 / window.len() as f64
}

/// Least-squares slope of y over x = 0..n (per-step gradient).
pub fn slope(ys: &[f64]) -> f64 {
    let n = ys.len();
    if n < 2 {
        return 0.0;
    }
    let xs_mean = (n as f64 - 1.0) / 2.0;
    let ys_mean = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, y) in ys.iter().enumerate() {
        let dx = i as f64 - xs_mean;
        num += dx * (y - ys_mean);
        den += dx * dx;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Fixed-width histogram: returns (bin_edges, counts).
pub fn histogram(xs: &[f64], bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0);
    if xs.is_empty() {
        return (vec![0.0; bins + 1], vec![0; bins]);
    }
    let lo = min(xs);
    let hi = max(xs);
    let width = if hi > lo { (hi - lo) / bins as f64 } else { 1.0 };
    let edges: Vec<f64> = (0..=bins).map(|i| lo + i as f64 * width).collect();
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    (edges, counts)
}

/// Fixed-footprint log₂-bucketed latency histogram for the serving path.
///
/// `record` is O(1) and allocation-free (one `u64` counter per power-of-two
/// nanosecond bucket), so it can sit on the coordinator's hot submit path.
/// Percentiles are read from the cumulative bucket counts and are exact to
/// within one octave (each bucket spans `[2^(k-1), 2^k)` ns), which is
/// plenty for p50/p99 decision-latency reporting.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; 64],
    total: u64,
    sum_ns: f64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { counts: [0; 64], total: 0, sum_ns: 0.0, max_ns: 0 }
    }

    pub fn record_ns(&mut self, ns: u64) {
        let bucket = (64 - ns.leading_zeros() as usize).min(63);
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ms(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum_ns / self.total as f64 / 1e6 }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ns as f64 / 1e6
    }

    /// p-th percentile (0..=100) in milliseconds: the upper edge of the
    /// bucket holding the p-th sample, clamped to the observed max.
    /// 0.0 when empty.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target =
            ((p.clamp(0.0, 100.0) / 100.0 * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (bucket, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let upper_ns = (1u128 << bucket) as f64;
                return (upper_ns / 1e6).min(self.max_ns as f64 / 1e6);
            }
        }
        self.max_ns as f64 / 1e6
    }

    /// Merge another histogram into this one (shard aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Welford online accumulator — used by the bench harness and metrics to
/// stream statistics without storing samples.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(cov(&[]), 0.0);
    }

    #[test]
    fn cov_scales_free() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        assert!((cov(&a) - cov(&b)).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 30.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_sorted(&[], 99.0), 0.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: partial_cmp().unwrap() panicked on any NaN sample.
        // total_cmp sorts NaN after +inf, so low/mid percentiles of a mostly
        // finite window are unchanged and nothing panics.
        let xs = [3.0, f64::NAN, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 40.0), 3.0);
        // The top percentile lands on the NaN tail — defined, not a panic.
        assert!(percentile(&xs, 100.0).is_nan());
        // All-NaN input is equally panic-free.
        assert!(percentile(&[f64::NAN; 3], 50.0).is_nan());
    }

    #[test]
    fn rank_fraction_bounds() {
        let w = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(rank_fraction(5.0, &w), 0.0);
        assert_eq!(rank_fraction(45.0, &w), 1.0);
        assert!((rank_fraction(25.0, &w) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn slope_of_line() {
        let ys = [1.0, 3.0, 5.0, 7.0];
        assert!((slope(&ys) - 2.0).abs() < 1e-12);
        assert_eq!(slope(&[5.0]), 0.0);
    }

    #[test]
    fn histogram_counts_all() {
        let xs = [0.0, 0.5, 1.0, 1.5, 2.0];
        let (_, counts) = histogram(&xs, 4);
        assert_eq!(counts.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn latency_histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        // 99 fast samples (~1 µs) and one slow outlier (~16 ms).
        for _ in 0..99 {
            h.record_ns(1_000);
        }
        h.record_ns(16_000_000);
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_ms(50.0);
        let p99 = h.percentile_ms(99.0);
        let p100 = h.percentile_ms(100.0);
        // p50/p99 fall in the fast bucket (≤ 2^10 ns ≈ 1 µs upper edge ×2).
        assert!(p50 <= 0.01, "p50 {p50}");
        assert!(p99 <= 0.01, "p99 {p99}");
        // p100 lands on the outlier's bucket, clamped to the observed max.
        assert!(p100 >= 8.0 && p100 <= 16.0, "p100 {p100}");
        assert!(h.mean_ms() > 0.0);
        assert!(p50 <= p99 && p99 <= p100);
    }

    #[test]
    fn latency_histogram_empty_and_merge() {
        let mut a = LatencyHistogram::new();
        assert_eq!(a.percentile_ms(99.0), 0.0);
        assert_eq!(a.mean_ms(), 0.0);
        let mut b = LatencyHistogram::new();
        b.record(std::time::Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.count(), 1);
        assert!(a.percentile_ms(50.0) > 0.0);
    }

    #[test]
    fn daily_cov_flat_days() {
        // Two days: first flat at 100 (CoV 0), second flat at 200 (CoV 0).
        let mut xs = vec![100.0; 24];
        xs.extend(vec![200.0; 24]);
        assert!(daily_cov(&xs).abs() < 1e-12);
        // Overall CoV would be ~0.33 — daily CoV must not see cross-day variance.
        assert!(cov(&xs) > 0.3);
    }
}
