//! Infrastructure substrates built in-repo because the offline build has no
//! access to `rand`, `serde`, `clap`, `criterion`, or `proptest`:
//! deterministic RNG + distributions, statistics, JSON, logging, a CLI arg
//! parser, a bench harness, and a property-testing helper.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod logging;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
