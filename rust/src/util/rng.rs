//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline build environment has no `rand` crate, so CarbonFlex ships its
//! own small PRNG substrate: a [SplitMix64](https://prng.di.unimi.it/splitmix64.c)
//! seeder feeding a xoshiro256** generator, plus the handful of distributions
//! the trace synthesizers need (uniform, normal, lognormal, exponential,
//! Pareto, Poisson). Everything is deterministic given a seed, which is what
//! makes every experiment in `EXPERIMENTS.md` exactly reproducible.

/// SplitMix64 step: used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
///
/// All stochastic components (trace synthesis, workload generation, noise
/// injection, property tests) draw from this generator so that a single
/// `seed` in a config file pins the entire experiment.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for parallel substreams per
    /// region / trace / test case without correlation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let base = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(base)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our purposes: modulo bias is
        // negligible for n << 2^64 (all our uses are tiny).
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single value; second is discarded to
    /// keep the stream stateless-per-call).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / rate
    }

    /// Pareto (Lomax-free classic form): scale * U^(-1/alpha), support [scale, inf).
    pub fn pareto(&mut self, scale: f64, alpha: f64) -> f64 {
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        scale * u.powf(-1.0 / alpha)
    }

    /// Poisson draw (Knuth for small lambda, normal approximation above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose an index by (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.5).abs() < 0.08, "mean {m}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.poisson(80.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 80.0).abs() < 0.5, "mean {m}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(23);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(1.0, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        // median of lognormal = e^mu
        assert!((median - 1.0f64.exp()).abs() / 1.0f64.exp() < 0.05, "median {median}");
    }

    #[test]
    fn pareto_support() {
        let mut r = Rng::new(29);
        for _ in 0..10_000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(31);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(41);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
