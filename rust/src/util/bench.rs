//! Mini benchmark harness for `harness = false` benches (criterion is not
//! available in the offline environment).
//!
//! Provides warmup + timed iterations with mean/σ/min/max reporting, and a
//! fixed-width table printer used by the per-figure benches to emit rows in
//! the same shape as the paper's tables.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Online;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Machine-readable form for `BENCH_hotpaths.json` and CI artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_seconds", Json::Num(self.mean.as_secs_f64())),
            ("std_dev_seconds", Json::Num(self.std_dev.as_secs_f64())),
            ("min_seconds", Json::Num(self.min.as_secs_f64())),
            ("max_seconds", Json::Num(self.max.as_secs_f64())),
        ])
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>12} ± {:<10} (min {:>10}, max {:>10}, n={})",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.std_dev),
            fmt_duration(self.min),
            fmt_duration(self.max),
            self.iters
        )
    }
}

/// Human duration formatting with unit auto-scaling.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Human rate formatting with unit auto-scaling ("12.3k/s", "1.20M/s").
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec < 1e3 {
        format!("{per_sec:.1}/s")
    } else if per_sec < 1e6 {
        format!("{:.1}k/s", per_sec / 1e3)
    } else {
        format!("{:.2}M/s", per_sec / 1e6)
    }
}

/// Run `f` with `warmup` untimed iterations then `iters` timed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut acc = Online::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        acc.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: acc.count(),
        mean: Duration::from_secs_f64(acc.mean()),
        std_dev: Duration::from_secs_f64(acc.std_dev()),
        min: Duration::from_secs_f64(acc.min()),
        max: Duration::from_secs_f64(acc.max()),
    }
}

/// Auto-calibrating variant: runs for roughly `budget` wall time.
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // One calibration call to estimate per-iter cost.
    let t0 = Instant::now();
    f();
    let per_iter = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / per_iter.as_secs_f64()).clamp(3.0, 1000.0) as u64;
    bench(name, (iters / 10).max(1), iters, f)
}

/// Chunked variant for spiky amortized workloads (e.g. sliding-window
/// maintenance where most iterations are cheap tombstoning and an
/// occasional one pays a full index rebuild): times blocks of `chunk`
/// iterations and reports **per-iteration** statistics over the block
/// means, so `mean` is the amortized cost and σ reflects block-to-block
/// drift rather than the individual spikes.
pub fn bench_chunked<F: FnMut()>(
    name: &str,
    budget: Duration,
    chunk: u64,
    mut f: F,
) -> BenchResult {
    let chunk = chunk.max(1);
    // One calibration block to estimate per-chunk cost.
    let t0 = Instant::now();
    for _ in 0..chunk {
        f();
    }
    let per_chunk = t0.elapsed().max(Duration::from_nanos(100));
    let chunks = (budget.as_secs_f64() / per_chunk.as_secs_f64()).clamp(3.0, 1000.0) as u64;
    let mut acc = Online::new();
    for _ in 0..chunks {
        let t = Instant::now();
        for _ in 0..chunk {
            f();
        }
        acc.push(t.elapsed().as_secs_f64() / chunk as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: acc.count() * chunk,
        mean: Duration::from_secs_f64(acc.mean()),
        std_dev: Duration::from_secs_f64(acc.std_dev()),
        min: Duration::from_secs_f64(acc.min()),
        max: Duration::from_secs_f64(acc.max()),
    }
}

/// Fixed-width table printer for paper-style result rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        let widths = headers.iter().map(|h| h.len()).collect();
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), widths, rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        out.push_str(&line(&self.headers, &self.widths));
        out.push('\n');
        out.push('|');
        for w in &self.widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &self.widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12);
        assert!(r.min <= r.mean && r.mean <= r.max);
    }

    #[test]
    fn fmt_rate_scales() {
        assert_eq!(fmt_rate(12.0), "12.0/s");
        assert_eq!(fmt_rate(12_300.0), "12.3k/s");
        assert_eq!(fmt_rate(1_200_000.0), "1.20M/s");
    }

    #[test]
    fn bench_result_json_shape() {
        let r = bench("noop", 0, 5, || {});
        let j = r.to_json();
        assert_eq!(j.get("iters").and_then(Json::as_usize), Some(5));
        assert!(j.get("mean_seconds").and_then(Json::as_f64).is_some());
        assert!(j.get("min_seconds").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn bench_chunked_reports_per_iteration_cost() {
        let mut n = 0u64;
        let r = bench_chunked("chunked", Duration::from_millis(5), 8, || n += 1);
        assert_eq!(r.iters % 8, 0, "iters {} not a whole number of chunks", r.iters);
        assert!(r.iters >= 3 * 8);
        // n counts the calibration chunk too.
        assert_eq!(n, r.iters + 8);
        assert!(r.min <= r.mean && r.mean <= r.max);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["policy", "savings"]);
        t.row(&["CarbonFlex".into(), "57.5%".into()]);
        t.row(&["GAIA".into(), "10%".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("CarbonFlex"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
