//! Closed-loop load generator for the traffic-serving coordinator.
//!
//! Replays a [`tracegen`](crate::workload::tracegen) trace against a
//! [`ShardedCoordinator`]: each virtual slot submits that slot's arrivals
//! (singly or in batches), ticks, and finally drains. The same job stream is
//! driven through single, batched, and sharded ingest so `serve-bench` can
//! assert both throughput gains and bitwise-identical drain reports.

use std::time::Instant;

use crate::carbon::synth::Region;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::api::{ErrorCode, Request, Response, SubmitOutcome, SubmitRequest};
use crate::coordinator::client::SessionClient;
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::transport::TransportError;
use crate::experiments::cells::DispatchStrategy;
use crate::sched::PolicyKind;
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;
use crate::workload::job::Job;
use crate::workload::tracegen;

/// Turn a generated trace into `(arrival_slot, request)` pairs, preserving
/// trace order (tracegen emits arrivals sorted).
pub fn submissions_of(jobs: &[Job]) -> Vec<(usize, SubmitRequest)> {
    jobs.iter()
        .map(|j| {
            (
                j.arrival,
                SubmitRequest {
                    workload: j.workload.to_string(),
                    length_hours: j.length_hours,
                    queue: j.queue,
                },
            )
        })
        .collect()
}

/// Outcome of one closed-loop drive of a coordinator deployment.
#[derive(Debug, Clone)]
pub struct DriveReport {
    pub mode: String,
    pub submitted: usize,
    pub accepted: usize,
    pub shed: usize,
    pub rejected_other: usize,
    pub wall_seconds: f64,
    pub submissions_per_sec: f64,
    pub shed_rate: f64,
    pub p50_decision_ms: f64,
    pub p99_decision_ms: f64,
    pub completed: usize,
    pub carbon_g: f64,
    pub mean_delay_hours: f64,
}

impl DriveReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode.clone())),
            ("submitted", Json::num(self.submitted as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("rejected_other", Json::num(self.rejected_other as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("submissions_per_sec", Json::num(self.submissions_per_sec)),
            ("shed_rate", Json::num(self.shed_rate)),
            ("p50_decision_ms", Json::num(self.p50_decision_ms)),
            ("p99_decision_ms", Json::num(self.p99_decision_ms)),
            ("completed", Json::num(self.completed as f64)),
            ("carbon_g", Json::num(self.carbon_g)),
            ("mean_delay_hours", Json::num(self.mean_delay_hours)),
        ])
    }

    /// Drain-report equality at the bit level — the determinism check
    /// `serve-bench` reports.
    pub fn drain_matches(&self, other: &DriveReport) -> bool {
        self.completed == other.completed
            && self.carbon_g.to_bits() == other.carbon_g.to_bits()
            && self.mean_delay_hours.to_bits() == other.mean_delay_hours.to_bits()
    }
}

fn count_outcome(
    out: &SubmitOutcome,
    accepted: &mut usize,
    shed: &mut usize,
    other: &mut usize,
) {
    match out {
        SubmitOutcome::Accepted { .. } => *accepted += 1,
        SubmitOutcome::Rejected { code: ErrorCode::QueueFull | ErrorCode::Shed, .. } => *shed += 1,
        SubmitOutcome::Rejected { .. } => *other += 1,
    }
}

/// Drive `arrivals` through `cluster` slot by slot. `batch <= 1` submits
/// singly; otherwise arrivals within a slot go in chunks of up to `batch`
/// via `SubmitBatch`. Client-side decision latency is measured around each
/// request (batch latency amortized per member). Ends with a drain.
pub fn drive(
    cluster: &mut ShardedCoordinator,
    arrivals: &[(usize, SubmitRequest)],
    batch: usize,
    mode: &str,
) -> DriveReport {
    let last_slot = arrivals.iter().map(|(t, _)| *t).max().unwrap_or(0);
    let mut hist = LatencyHistogram::new();
    let (mut accepted, mut shed, mut other) = (0usize, 0usize, 0usize);
    let wall = Instant::now();
    let mut cursor = 0usize;
    for t in 0..=last_slot {
        let start = cursor;
        while cursor < arrivals.len() && arrivals[cursor].0 == t {
            cursor += 1;
        }
        let slot_jobs = &arrivals[start..cursor];
        if batch <= 1 {
            for (_, s) in slot_jobs {
                let t0 = Instant::now();
                let resp = cluster.submit(s);
                hist.record(t0.elapsed());
                match resp {
                    Response::Submitted { .. } => accepted += 1,
                    Response::Error {
                        code: ErrorCode::QueueFull | ErrorCode::Shed, ..
                    } => shed += 1,
                    _ => other += 1,
                }
            }
        } else {
            for chunk in slot_jobs.chunks(batch) {
                let jobs: Vec<SubmitRequest> = chunk.iter().map(|(_, s)| s.clone()).collect();
                let n = jobs.len() as u32;
                let t0 = Instant::now();
                let resp = cluster.handle_request(Request::SubmitBatch(jobs));
                let per = t0.elapsed() / n.max(1);
                match resp {
                    Response::Batch { results } => {
                        for out in &results {
                            hist.record(per);
                            count_outcome(out, &mut accepted, &mut shed, &mut other);
                        }
                    }
                    _ => {
                        for _ in chunk {
                            hist.record(per);
                            other += 1;
                        }
                    }
                }
            }
        }
        cluster.tick();
    }
    let drained = cluster.drain();
    let wall_seconds = wall.elapsed().as_secs_f64();
    let submitted = arrivals.len();
    let (completed, carbon_g, mean_delay_hours) = match drained {
        Response::Drained { completed, carbon_g, mean_delay_hours } => {
            (completed, carbon_g, mean_delay_hours)
        }
        _ => (0, 0.0, 0.0),
    };
    DriveReport {
        mode: mode.to_string(),
        submitted,
        accepted,
        shed,
        rejected_other: other,
        wall_seconds,
        submissions_per_sec: if wall_seconds > 0.0 { submitted as f64 / wall_seconds } else { 0.0 },
        shed_rate: if submitted > 0 { shed as f64 / submitted as f64 } else { 0.0 },
        p50_decision_ms: hist.percentile_ms(50.0),
        p99_decision_ms: hist.percentile_ms(99.0),
        completed,
        carbon_g,
        mean_delay_hours,
    }
}

/// Drive `arrivals` through a [`SessionClient`] slot by slot: submits go
/// out pipelined in windows of up to `window` frames, each slot ends with
/// a `Tick`, and the run ends with a `Drain` — the same request stream the
/// stdio [`drive`] issues, so a fault-free session drive must produce a
/// bitwise-identical drain report. Latency is measured around each
/// pipeline window, amortized per member.
pub fn drive_session(
    client: &mut SessionClient,
    arrivals: &[(usize, SubmitRequest)],
    window: usize,
    mode: &str,
) -> Result<DriveReport, TransportError> {
    let window = window.max(1);
    let last_slot = arrivals.iter().map(|(t, _)| *t).max().unwrap_or(0);
    let mut hist = LatencyHistogram::new();
    let (mut accepted, mut shed, mut other) = (0usize, 0usize, 0usize);
    let wall = Instant::now();
    let mut cursor = 0usize;
    for t in 0..=last_slot {
        let start = cursor;
        while cursor < arrivals.len() && arrivals[cursor].0 == t {
            cursor += 1;
        }
        let slot_jobs = &arrivals[start..cursor];
        for chunk in slot_jobs.chunks(window) {
            let reqs: Vec<Request> =
                chunk.iter().map(|(_, s)| Request::Submit(s.clone())).collect();
            let n = reqs.len() as u32;
            let t0 = Instant::now();
            let resps = client.pipeline(reqs)?;
            let per = t0.elapsed() / n.max(1);
            for resp in &resps {
                hist.record(per);
                match resp {
                    Response::Submitted { .. } => accepted += 1,
                    Response::Error { code: ErrorCode::QueueFull | ErrorCode::Shed, .. } => {
                        shed += 1
                    }
                    _ => other += 1,
                }
            }
        }
        client.request(Request::Tick)?;
    }
    let drained = client.request(Request::Drain)?;
    client.bye();
    let wall_seconds = wall.elapsed().as_secs_f64();
    let submitted = arrivals.len();
    let (completed, carbon_g, mean_delay_hours) = match drained {
        Response::Drained { completed, carbon_g, mean_delay_hours } => {
            (completed, carbon_g, mean_delay_hours)
        }
        _ => (0, 0.0, 0.0),
    };
    Ok(DriveReport {
        mode: mode.to_string(),
        submitted,
        accepted,
        shed,
        rejected_other: other,
        wall_seconds,
        submissions_per_sec: if wall_seconds > 0.0 { submitted as f64 / wall_seconds } else { 0.0 },
        shed_rate: if submitted > 0 { shed as f64 / submitted as f64 } else { 0.0 },
        p50_decision_ms: hist.percentile_ms(50.0),
        p99_decision_ms: hist.percentile_ms(99.0),
        completed,
        carbon_g,
        mean_delay_hours,
    })
}

/// Options for [`run_serve_bench`].
#[derive(Debug, Clone)]
pub struct ServeBenchOpts {
    pub cfg: ExperimentConfig,
    pub service: ServiceConfig,
    pub kind: PolicyKind,
    pub jobs: usize,
    pub horizon: usize,
    pub seed: u64,
    pub batch: usize,
    pub regions: Vec<Region>,
    pub strategy: DispatchStrategy,
}

/// Run the serve benchmark: the same generated trace driven three ways —
/// single submits, batched submits, and batched submits over the sharded
/// deployment — and report throughput, tail latency, shed rate, and whether
/// the drain reports match bitwise.
pub fn run_serve_bench(opts: &ServeBenchOpts) -> (Vec<DriveReport>, Json) {
    let base_region = Region::parse(&opts.cfg.region).unwrap_or(Region::ALL[0]);
    let trace = tracegen::generate_n(&opts.cfg, opts.horizon, opts.seed, opts.jobs);
    let arrivals = submissions_of(&trace);
    let batch = opts.batch.clamp(2, opts.service.max_batch.max(2));

    let mut single_c = ShardedCoordinator::start(
        &opts.cfg,
        &opts.service,
        opts.kind,
        &[base_region],
        opts.strategy,
    );
    let single = drive(&mut single_c, &arrivals, 1, "single");
    single_c.shutdown();

    let mut batch_c = ShardedCoordinator::start(
        &opts.cfg,
        &opts.service,
        opts.kind,
        &[base_region],
        opts.strategy,
    );
    let batched = drive(&mut batch_c, &arrivals, batch, "batch");
    batch_c.shutdown();

    let mut shard_c = ShardedCoordinator::start(
        &opts.cfg,
        &opts.service,
        opts.kind,
        &opts.regions,
        opts.strategy,
    );
    let sharded = drive(&mut shard_c, &arrivals, batch, "sharded");
    shard_c.shutdown();

    // Single vs batched ingest must match bitwise always; the sharded run
    // only joins the comparison when its topology matches (1 shard in the
    // base region) — shard count legitimately changes placement.
    let mut identical = single.drain_matches(&batched);
    let sharded_comparable =
        opts.regions.len() == 1 && opts.regions[0].key() == base_region.key();
    if sharded_comparable {
        identical = identical && single.drain_matches(&sharded);
    }

    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        (
            "config",
            Json::obj(vec![
                ("policy", Json::str(opts.kind.key())),
                ("jobs", Json::num(opts.jobs as f64)),
                ("horizon_hours", Json::num(opts.horizon as f64)),
                ("seed", Json::num(opts.seed as f64)),
                ("batch", Json::num(batch as f64)),
                ("shards", Json::num(opts.regions.len() as f64)),
                (
                    "regions",
                    Json::Arr(opts.regions.iter().map(|r| Json::str(r.key())).collect()),
                ),
                ("capacity", Json::num(opts.cfg.capacity as f64)),
                ("region", Json::str(opts.cfg.region.clone())),
                ("max_pending", Json::num(opts.service.max_pending as f64)),
                ("shed_policy", Json::str(opts.service.shed.as_str())),
            ]),
        ),
        // Headline metrics come from the batched run — the shape `serve`
        // deployments are expected to use.
        ("submissions_per_sec", Json::num(batched.submissions_per_sec)),
        ("p99_decision_ms", Json::num(batched.p99_decision_ms)),
        ("shed_rate", Json::num(batched.shed_rate)),
        (
            "modes",
            Json::obj(vec![
                ("single", single.to_json()),
                ("batch", batched.to_json()),
                ("sharded", sharded.to_json()),
            ]),
        ),
        (
            "drain",
            Json::obj(vec![
                ("completed", Json::num(batched.completed as f64)),
                ("carbon_g", Json::num(batched.carbon_g)),
                ("mean_delay_hours", Json::num(batched.mean_delay_hours)),
            ]),
        ),
        ("reports_identical", Json::Bool(identical)),
        ("batch_speedup", {
            let s = if single.submissions_per_sec > 0.0 {
                batched.submissions_per_sec / single.submissions_per_sec
            } else {
                0.0
            };
            Json::num(s)
        }),
    ]);
    (vec![single, batched, sharded], doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 12;
        cfg.horizon_hours = 48;
        cfg.history_hours = 48;
        cfg
    }

    #[test]
    fn submissions_preserve_trace_order() {
        let cfg = small_cfg();
        let jobs = tracegen::generate_n(&cfg, 48, 7, 40);
        let subs = submissions_of(&jobs);
        assert_eq!(subs.len(), 40);
        for (pair, job) in subs.iter().zip(&jobs) {
            assert_eq!(pair.0, job.arrival);
            assert_eq!(pair.1.workload, job.workload);
            assert_eq!(pair.1.queue, job.queue);
        }
        for w in subs.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn single_and_batched_drains_match_bitwise() {
        let cfg = small_cfg();
        let service = ServiceConfig::default();
        let jobs = tracegen::generate_n(&cfg, 48, 21, 60);
        let arrivals = submissions_of(&jobs);
        let region = Region::parse(&cfg.region).unwrap_or(Region::ALL[0]);

        let mut a = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &[region],
            DispatchStrategy::RoundRobin,
        );
        let ra = drive(&mut a, &arrivals, 1, "single");
        a.shutdown();

        let mut b = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &[region],
            DispatchStrategy::RoundRobin,
        );
        let rb = drive(&mut b, &arrivals, 16, "batch");
        b.shutdown();

        assert_eq!(ra.accepted, rb.accepted);
        assert!(ra.drain_matches(&rb), "single {ra:?} vs batch {rb:?}");
        assert_eq!(ra.completed, ra.accepted);
    }

    #[test]
    fn fault_free_session_drive_matches_stdio_drive_bitwise() {
        use crate::coordinator::session::{SessionConfig, SessionServer};
        use crate::coordinator::transport::{FrameHandler, LoopbackTransport};
        use crate::faults::net::LinkPlan;
        use std::sync::{Arc, Mutex};

        let cfg = small_cfg();
        let service = ServiceConfig::default();
        let jobs = tracegen::generate_n(&cfg, 48, 33, 50);
        let arrivals = submissions_of(&jobs);
        let region = Region::parse(&cfg.region).unwrap_or(Region::ALL[0]);

        let mut a = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &[region],
            DispatchStrategy::RoundRobin,
        );
        let stdio = drive(&mut a, &arrivals, 1, "single");
        a.shutdown();

        let b = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &[region],
            DispatchStrategy::RoundRobin,
        );
        let server = Arc::new(Mutex::new(SessionServer::new(b, SessionConfig::default())));
        let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
        let mut client = SessionClient::new(
            Box::new(LoopbackTransport::new(handler, LinkPlan::none())),
            "loadgen",
            5,
        );
        let session = drive_session(&mut client, &arrivals, 16, "session").unwrap();
        assert_eq!(stdio.accepted, session.accepted);
        assert!(stdio.drain_matches(&session), "stdio {stdio:?} vs session {session:?}");
        let st = client.stats();
        assert_eq!(st.reconnects + st.retries, 0, "clean link must not retry");
    }

    #[test]
    fn serve_bench_doc_has_headline_fields() {
        let cfg = small_cfg();
        let opts = ServeBenchOpts {
            cfg: cfg.clone(),
            service: ServiceConfig::default(),
            kind: PolicyKind::CarbonAgnostic,
            jobs: 30,
            horizon: 48,
            seed: 3,
            batch: 8,
            regions: vec![Region::parse(&cfg.region).unwrap_or(Region::ALL[0])],
            strategy: DispatchStrategy::RoundRobin,
        };
        let (reports, doc) = run_serve_bench(&opts);
        assert_eq!(reports.len(), 3);
        let obj = doc.as_obj().expect("doc is an object");
        for key in ["submissions_per_sec", "p99_decision_ms", "shed_rate", "reports_identical"] {
            assert!(obj.contains_key(key), "missing {key}");
        }
        // 1-shard sharded run is topology-identical → all three match.
        assert_eq!(obj["reports_identical"], Json::Bool(true));
        let text = doc.to_string();
        let parsed = json::parse(&text).expect("round-trips");
        assert_eq!(parsed.get("schema").and_then(Json::as_f64), Some(1.0));
    }
}
