//! The coordinator service: a leader thread owning the cluster engine and a
//! policy, behind a versioned JSON-lines wire API with batched ingest,
//! backpressure, service stats, and an optional sharded (one coordinator
//! per region) deployment shape. Connection-oriented access layers on
//! top: a session protocol (resume tokens, sequence numbers, idempotent
//! retry) over pluggable transports (in-process loopback with seeded
//! link faults, or real TCP).

pub mod api;
pub mod client;
pub mod loadgen;
pub mod server;
pub mod session;
pub mod shard;
pub mod transport;

pub use api::{
    ErrorCode, ParseFailure, Request, Response, StatsResponse, StatusResponse, SubmitOutcome,
    SubmitRequest, WireRequest, WireResponse, PROTOCOL_VERSION,
};
pub use client::{BackoffConfig, SessionClient, SessionStats};
pub use loadgen::{
    drive, drive_session, run_serve_bench, submissions_of, DriveReport, ServeBenchOpts,
};
pub use server::{
    CheckpointState, ClusterHandle, ControlError, Coordinator, CoordinatorConfig,
};
pub use session::{take_cluster, SessionConfig, SessionCounters, SessionServer};
pub use shard::{shard_regions, ShardedCoordinator};
pub use transport::{
    Connection, FrameHandler, LoopbackTransport, TcpTransport, Transport, TransportError,
};
