//! The coordinator service: a leader thread owning the cluster engine and a
//! policy, behind a versioned JSON-lines wire API with batched ingest,
//! backpressure, service stats, and an optional sharded (one coordinator
//! per region) deployment shape.

pub mod api;
pub mod loadgen;
pub mod server;
pub mod shard;

pub use api::{
    ErrorCode, ParseFailure, Request, Response, StatsResponse, StatusResponse, SubmitOutcome,
    SubmitRequest, WireRequest, WireResponse, PROTOCOL_VERSION,
};
pub use loadgen::{drive, run_serve_bench, submissions_of, DriveReport, ServeBenchOpts};
pub use server::{CheckpointState, ClusterHandle, Coordinator, CoordinatorConfig};
pub use shard::{shard_regions, ShardedCoordinator};
