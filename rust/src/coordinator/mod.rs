//! The coordinator service: a leader thread owning the cluster engine and a
//! policy, with a channel-based submission/status API and a JSON line codec
//! for external clients.

pub mod api;
pub mod server;

pub use api::{Request, Response, StatusResponse, SubmitRequest};
pub use server::{ClusterHandle, Coordinator, CoordinatorConfig};
