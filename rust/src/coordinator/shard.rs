//! Sharded deployment shape: one coordinator per region behind a
//! deterministic geo-dispatcher.
//!
//! The dispatcher reuses the spatial sweep's routing
//! ([`route_arrival`](crate::experiments::cells::route_arrival)), so a
//! sharded service routes exactly like the paper's multi-region experiment
//! cells. Routing depends only on (job order, virtual slot, per-region
//! forecasts) — never on ingest granularity — so a fixed job stream produces
//! bitwise-identical drain reports whether it arrives singly or in batches.
//!
//! With one shard the frontend is a transparent passthrough over a single
//! [`Coordinator`]; `serve` and `serve-bench` always go through this type so
//! every deployment shape exercises the same code path.

use crate::carbon::forecast::Forecaster;
use crate::carbon::synth::Region;
use crate::cluster::metrics::RunMetrics;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::api::{
    ErrorCode, Request, Response, StatsResponse, StatusResponse, SubmitOutcome, SubmitRequest,
};
use crate::coordinator::server::{ClusterHandle, Coordinator, CoordinatorConfig};
use crate::experiments::cells::{route_arrival, DispatchStrategy};
use crate::experiments::runner::PreparedExperiment;
use crate::faults::ShardKill;
use crate::sched::PolicyKind;
use crate::util::stats::LatencyHistogram;

/// Parse a `--shards` value: either a shard count (regions drawn cyclically
/// from [`Region::ALL`] starting at the base config's region, so `1` keeps
/// the configured region) or a '+'-joined region set
/// ("south-australia+ontario").
pub fn shard_regions(raw: &str, base_region: &str) -> Result<Vec<Region>, String> {
    let raw = raw.trim();
    if let Ok(n) = raw.parse::<usize>() {
        if n == 0 {
            return Err("--shards must be positive".into());
        }
        let start = Region::ALL.iter().position(|r| r.key() == base_region).unwrap_or(0);
        return Ok((0..n).map(|i| Region::ALL[(start + i) % Region::ALL.len()]).collect());
    }
    let regions: Result<Vec<Region>, String> = raw
        .split('+')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|k| {
            Region::parse(k).ok_or_else(|| {
                format!(
                    "unknown region '{k}' (known: {})",
                    Region::ALL.map(|r| r.key()).join(", ")
                )
            })
        })
        .collect();
    let regions = regions?;
    if regions.is_empty() {
        return Err("--shards region set is empty".into());
    }
    Ok(regions)
}

struct Shard {
    region: Region,
    /// The dispatcher's view of the shard's carbon forecast (same trace the
    /// shard's own policy sees).
    forecaster: Forecaster,
    coord: Coordinator,
    handle: ClusterHandle,
}

/// A fleet of per-region coordinators behind a deterministic geo-dispatcher.
///
/// The frontend doubles as the **shard supervisor** for fault injection
/// (see `crate::faults`): a [`ShardKill`] plan kills chosen shards at chosen
/// submission counts; the supervisor replays the dead shard's write-ahead
/// checkpoint onto survivors (bounded retry, deterministic rotation) and
/// restarts the shard from its original recipe so it rejoins empty but
/// deterministic at the dispatcher's current virtual slot.
pub struct ShardedCoordinator {
    shards: Vec<Shard>,
    strategy: DispatchStrategy,
    rr: usize,
    slot: usize,
    cfg: ExperimentConfig,
    service: ServiceConfig,
    /// Policy every shard runs (part of the restart recipe).
    kind: PolicyKind,
    /// Per-shard capacity (part of the restart recipe).
    per_capacity: usize,
    /// Pending shard kills, consumed as their submission counts are reached.
    kill_plan: Vec<ShardKill>,
    /// Submissions routed so far (accepted or not) — the kill-plan clock.
    submissions_seen: u64,
    /// Supervisor counters reported through `stats`.
    failovers: u64,
    rerouted: u64,
    failover_shed: u64,
    /// Final metrics of killed shard incarnations (folded into `shutdown`).
    killed_metrics: Vec<RunMetrics>,
}

impl ShardedCoordinator {
    /// Start one coordinator per region. Aggregate capacity is split evenly
    /// (at least 1 server per shard); each shard gets its own region trace,
    /// forecaster, and policy instance prepared from the base config.
    pub fn start(
        cfg: &ExperimentConfig,
        service: &ServiceConfig,
        kind: PolicyKind,
        regions: &[Region],
        strategy: DispatchStrategy,
    ) -> ShardedCoordinator {
        assert!(!regions.is_empty(), "at least one shard region required");
        let per_capacity = (cfg.capacity / regions.len()).max(1);
        let shards = regions
            .iter()
            .map(|&region| Self::spawn_shard(cfg, service, kind, region, per_capacity))
            .collect();
        ShardedCoordinator {
            shards,
            strategy,
            rr: 0,
            slot: 0,
            cfg: cfg.clone(),
            service: service.clone(),
            kind,
            per_capacity,
            kill_plan: Vec::new(),
            submissions_seen: 0,
            failovers: 0,
            rerouted: 0,
            failover_shed: 0,
            killed_metrics: Vec::new(),
        }
    }

    /// The shard construction recipe shared by `start` and failover
    /// restarts — same inputs, same shard, deterministically.
    fn spawn_shard(
        cfg: &ExperimentConfig,
        service: &ServiceConfig,
        kind: PolicyKind,
        region: Region,
        per_capacity: usize,
    ) -> Shard {
        let mut rcfg = cfg.clone();
        rcfg.region = region.key().to_string();
        rcfg.capacity = per_capacity;
        let prep = PreparedExperiment::prepare(&rcfg);
        let policy = prep.build_policy(kind);
        let forecaster = Forecaster::perfect(prep.eval_trace.clone());
        let coord = Coordinator::start(
            CoordinatorConfig::from_experiment(&rcfg, service.clone()),
            forecaster.clone(),
            policy,
        );
        let handle = coord.handle();
        Shard { region, forecaster, coord, handle }
    }

    /// Arm the supervisor with a seeded kill plan (see
    /// [`crate::faults::FaultPlan`]). Kills fire as submissions arrive.
    pub fn set_kill_plan(&mut self, kills: &[ShardKill]) {
        self.kill_plan = kills.to_vec();
    }

    /// Supervisor counters: (failovers, rerouted, failover_shed).
    pub fn failover_counters(&self) -> (u64, u64, u64) {
        (self.failovers, self.rerouted, self.failover_shed)
    }

    /// Final metrics of shard incarnations killed by the fault plan.
    pub fn killed_metrics(&self) -> &[RunMetrics] {
        &self.killed_metrics
    }

    /// Fire any armed kills whose submission count has been reached.
    fn maybe_kill(&mut self) {
        while let Some(pos) = self
            .kill_plan
            .iter()
            .position(|k| k.at_submission <= self.submissions_seen && k.shard < self.shards.len())
        {
            let k = self.kill_plan.remove(pos);
            self.fail_shard(k.shard);
        }
    }

    /// Kill shard `s`, fail its checkpointed pending submissions over to the
    /// survivors, and restart it from the original recipe. Deterministic end
    /// to end: the checkpoint is exact (requests are synchronous), the
    /// retry rotation is a function of (pending index, attempt), and the
    /// restarted shard is rebuilt from the same inputs and ticked to the
    /// dispatcher's clock.
    fn fail_shard(&mut self, s: usize) {
        if self.shards.len() <= 1 || s >= self.shards.len() {
            return; // no survivor to fail over to
        }
        self.failovers += 1;
        let region = self.shards[s].region;
        let fresh =
            Self::spawn_shard(&self.cfg, &self.service, self.kind, region, self.per_capacity);
        let dead = std::mem::replace(&mut self.shards[s], fresh);
        let checkpoint = dead.coord.checkpoint();
        self.killed_metrics.push(dead.coord.kill());
        // Rejoin: catch the fresh incarnation up to the dispatcher's clock.
        for _ in 0..self.slot {
            let _ = self.shards[s].handle.request(Request::Tick);
        }
        // Bounded retry over the survivors: pending job j starts at survivor
        // (j mod n-1) and rotates once per attempt — deterministic backoff
        // in virtual time, at most one attempt per survivor.
        let pending = checkpoint.pending();
        let survivors: Vec<usize> = (0..self.shards.len()).filter(|&i| i != s).collect();
        for (j, sub) in pending.iter().enumerate() {
            let mut placed = false;
            for attempt in 0..survivors.len() {
                let target = survivors[(j + attempt) % survivors.len()];
                if let Response::Submitted { .. } =
                    self.shards[target].handle.request(Request::Submit(sub.clone()))
                {
                    self.rerouted += 1;
                    placed = true;
                    break;
                }
            }
            if !placed {
                self.failover_shed += 1;
            }
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn regions(&self) -> Vec<Region> {
        self.shards.iter().map(|s| s.region).collect()
    }

    /// Route one submission to its destination shard index.
    fn route(&mut self, s: &SubmitRequest) -> usize {
        let queue = s.queue.min(self.cfg.queues.len().saturating_sub(1));
        let slack = self.cfg.queues.get(queue).map(|q| q.delay_hours).unwrap_or(24.0);
        let window = (s.length_hours + slack).ceil() as usize;
        route_arrival(
            self.strategy,
            &mut self.rr,
            &self.shards,
            |sh| &sh.forecaster,
            self.slot,
            window,
        )
    }

    /// Dispatch any wire request — the entry point `serve` uses.
    pub fn handle_request(&mut self, req: Request) -> Response {
        match req {
            Request::Submit(s) => self.submit(&s),
            Request::SubmitBatch(jobs) => self.submit_batch(jobs),
            Request::Tick => self.tick(),
            Request::Status => self.status(),
            Request::Stats => self.stats_merged(),
            Request::Drain => self.drain(),
        }
    }

    pub fn submit(&mut self, s: &SubmitRequest) -> Response {
        self.submissions_seen += 1;
        if !self.kill_plan.is_empty() {
            self.maybe_kill();
        }
        let r = self.route(s);
        self.shards[r].handle.request(Request::Submit(s.clone()))
    }

    /// Route a batch member-by-member (same rr/forecast decisions as single
    /// submits), forward one sub-batch per shard, and merge outcomes back
    /// into member order.
    pub fn submit_batch(&mut self, jobs: Vec<SubmitRequest>) -> Response {
        if jobs.is_empty() {
            return Response::Error { code: ErrorCode::BadRequest, message: "empty batch".into() };
        }
        if jobs.len() > self.service.max_batch {
            return Response::Error {
                code: ErrorCode::BadRequest,
                message: format!(
                    "batch of {} exceeds max_batch {}",
                    jobs.len(),
                    self.service.max_batch
                ),
            };
        }
        if self.shards.len() == 1 {
            self.submissions_seen += jobs.len() as u64;
            return self.shards[0].handle.request(Request::SubmitBatch(jobs));
        }
        let n = jobs.len();
        let mut groups: Vec<Vec<SubmitRequest>> = vec![Vec::new(); self.shards.len()];
        let mut positions: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, s) in jobs.into_iter().enumerate() {
            // Each member advances the kill-plan clock exactly as a single
            // submit would, so a fixed stream kills at the same point
            // whichever ingest shape delivered it.
            self.submissions_seen += 1;
            if !self.kill_plan.is_empty() {
                self.maybe_kill();
            }
            let r = self.route(&s);
            groups[r].push(s);
            positions[r].push(i);
        }
        let mut merged: Vec<Option<SubmitOutcome>> = vec![None; n];
        for (r, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            match self.shards[r].handle.request(Request::SubmitBatch(group)) {
                Response::Batch { results } => {
                    for (&pos, out) in positions[r].iter().zip(results) {
                        merged[pos] = Some(out);
                    }
                }
                Response::Error { code, message } => {
                    for &pos in &positions[r] {
                        merged[pos] =
                            Some(SubmitOutcome::Rejected { code, message: message.clone() });
                    }
                }
                other => {
                    for &pos in &positions[r] {
                        merged[pos] = Some(SubmitOutcome::Rejected {
                            code: ErrorCode::BadRequest,
                            message: format!("unexpected shard response {other:?}"),
                        });
                    }
                }
            }
        }
        let results = merged
            .into_iter()
            .map(|o| {
                o.unwrap_or(SubmitOutcome::Rejected {
                    code: ErrorCode::BadRequest,
                    message: "unrouted batch member".into(),
                })
            })
            .collect();
        Response::Batch { results }
    }

    /// Advance every shard one slot (and the dispatcher's clock with them).
    pub fn tick(&mut self) -> Response {
        for sh in &self.shards {
            let _ = sh.handle.request(Request::Tick);
        }
        self.slot += 1;
        Response::Ticked { slot: self.slot }
    }

    /// Merged cluster status: sums across shards, dispatcher slot.
    pub fn status(&self) -> Response {
        let mut agg = StatusResponse {
            slot: self.slot,
            active_jobs: 0,
            completed: 0,
            provisioned: 0,
            used: 0,
            carbon_g: 0.0,
            energy_kwh: 0.0,
        };
        for sh in &self.shards {
            if let Response::Status(s) = sh.handle.request(Request::Status) {
                agg.active_jobs += s.active_jobs;
                agg.completed += s.completed;
                agg.provisioned += s.provisioned;
                agg.used += s.used;
                agg.carbon_g += s.carbon_g;
                agg.energy_kwh += s.energy_kwh;
            }
        }
        Response::Status(agg)
    }

    /// Per-shard stats snapshots, in shard order (errors skipped).
    pub fn stats(&self) -> Vec<StatsResponse> {
        self.shards
            .iter()
            .filter_map(|sh| match sh.handle.request(Request::Stats) {
                Response::Stats(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    /// Merged service stats: counters and queue depths sum across shards;
    /// latency percentiles come from the bucket-wise sum of every shard's
    /// [`LatencyHistogram`] — the percentile of the union of all recorded
    /// decisions. (Taking the max shard percentile instead would report a
    /// fleet median of 1 ms when one near-idle shard is slow and thousands
    /// of fast decisions ran elsewhere.)
    pub fn stats_merged(&self) -> Response {
        let per = self.stats();
        let mut merged = LatencyHistogram::new();
        for sh in &self.shards {
            // A dead or wedged shard contributes nothing rather than
            // hanging the whole stats fetch (see `ControlError`).
            if let Ok(h) = sh.handle.latency_histogram() {
                merged.merge(&h);
            }
        }
        let mut agg = StatsResponse {
            slot: self.slot,
            requests: 0,
            accepted: 0,
            shed: 0,
            batches: 0,
            pending: 0,
            max_pending: 0,
            queue_depths: vec![0; self.cfg.queues.len().max(1)],
            p50_decision_ms: merged.percentile_ms(50.0),
            p99_decision_ms: merged.percentile_ms(99.0),
            carbon_g: 0.0,
            degraded_stale: 0,
            degraded_fallback: 0,
            failovers: self.failovers,
            rerouted: self.rerouted,
            failover_shed: self.failover_shed,
        };
        for s in &per {
            agg.requests += s.requests;
            agg.accepted += s.accepted;
            agg.shed += s.shed;
            agg.batches += s.batches;
            agg.pending += s.pending;
            agg.max_pending += s.max_pending;
            for (d, &sd) in agg.queue_depths.iter_mut().zip(&s.queue_depths) {
                *d += sd;
            }
            agg.carbon_g += s.carbon_g;
            agg.degraded_stale += s.degraded_stale;
            agg.degraded_fallback += s.degraded_fallback;
        }
        Response::Stats(agg)
    }

    /// Drain every shard (fixed shard order) and merge: counts and carbon
    /// sum; mean delay is completed-weighted, mirroring the spatial cells'
    /// regional aggregation. Terminal — shards answer `draining` afterwards.
    pub fn drain(&mut self) -> Response {
        let mut completed = 0usize;
        let mut carbon_g = 0.0f64;
        let mut delay_weighted = 0.0f64;
        for sh in &self.shards {
            if let Response::Drained { completed: c, carbon_g: g, mean_delay_hours: d } =
                sh.handle.request(Request::Drain)
            {
                completed += c;
                carbon_g += g;
                delay_weighted += d * c as f64;
            }
        }
        let mean_delay_hours =
            if completed == 0 { 0.0 } else { delay_weighted / completed as f64 };
        Response::Drained { completed, carbon_g, mean_delay_hours }
    }

    /// Stop every shard and collect their final run metrics (shard order,
    /// followed by any fault-killed incarnations in kill order).
    pub fn shutdown(self) -> Vec<RunMetrics> {
        let mut out: Vec<RunMetrics> =
            self.shards.into_iter().map(|sh| sh.coord.shutdown()).collect();
        out.extend(self.killed_metrics);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_regions_count_and_set() {
        let rs = shard_regions("2", "ontario").unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].key(), "ontario");
        // shards=1 keeps the configured region.
        let one = shard_regions("1", "south-australia").unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].key(), "south-australia");
        let set = shard_regions("south-australia+ontario", "ignored").unwrap();
        assert_eq!(set.len(), 2);
        assert!(shard_regions("0", "ontario").is_err());
        assert!(shard_regions("narnia", "ontario").is_err());
        assert!(shard_regions("", "ontario").is_err());
    }

    #[test]
    fn shard_count_wraps_region_table() {
        let all = Region::ALL.len();
        let rs = shard_regions(&(all + 2).to_string(), Region::ALL[0].key()).unwrap();
        assert_eq!(rs.len(), all + 2);
        assert_eq!(rs[all].key(), Region::ALL[0].key());
    }

    #[test]
    fn merged_percentile_is_not_max_of_shard_percentiles() {
        // Shard A: 99 fast decisions (~1 µs). Shard B: one slow (~1 ms).
        // Max-of-shards would claim the fleet median is 1 ms; the union of
        // samples knows 99 out of 100 are microseconds.
        let mut a = LatencyHistogram::new();
        for _ in 0..99 {
            a.record_ns(1_000);
        }
        let mut b = LatencyHistogram::new();
        b.record_ns(1_000_000);
        let max_p50 = a.percentile_ms(50.0).max(b.percentile_ms(50.0));
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), 100);
        assert!(
            merged.percentile_ms(50.0) < max_p50 / 100.0,
            "merged p50 {} should be orders below max-of-shards {}",
            merged.percentile_ms(50.0),
            max_p50
        );
        // The tail is still visible in the union.
        assert!(merged.percentile_ms(99.5) >= b.percentile_ms(50.0) * 0.5);
    }

    #[test]
    fn shard_kill_failover_drains_accepted_exactly_once() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 8;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let service = ServiceConfig::default();
        let regions = shard_regions("2", &cfg.region).unwrap();
        let mut cluster = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &regions,
            DispatchStrategy::RoundRobin,
        );
        cluster.set_kill_plan(&[ShardKill { shard: 0, at_submission: 4 }]);
        let mut accepted = 0usize;
        for i in 0..8usize {
            let r = cluster.submit(&SubmitRequest {
                workload: "N-body(N=100k)".to_string(),
                length_hours: 2.0,
                queue: i % 3,
            });
            if matches!(r, Response::Submitted { .. }) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 8);
        let (failovers, rerouted, shed) = cluster.failover_counters();
        assert_eq!(failovers, 1);
        assert!(rerouted > 0, "killed shard held pending jobs to fail over");
        assert_eq!(shed, 0, "ample survivor capacity must not shed");
        match cluster.stats_merged() {
            Response::Stats(st) => assert_eq!(st.failovers, 1),
            other => panic!("expected stats, got {other:?}"),
        }
        // Exactly-once: what the killed incarnation completed plus what the
        // fleet drains equals every accepted submission.
        let killed_completed: usize =
            cluster.killed_metrics().iter().map(|m| m.completed).sum();
        let drained = match cluster.drain() {
            Response::Drained { completed, .. } => completed,
            other => panic!("expected drained, got {other:?}"),
        };
        assert_eq!(killed_completed + drained, accepted);
        let metrics = cluster.shutdown();
        // Live shards plus one killed incarnation.
        assert_eq!(metrics.len(), 3);
    }

    #[test]
    fn sharded_stats_merge_latency_across_shards() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 8;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let service = ServiceConfig::default();
        let regions = shard_regions("2", &cfg.region).unwrap();
        let mut cluster = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &regions,
            DispatchStrategy::RoundRobin,
        );
        for i in 0..6usize {
            let r = cluster.submit(&SubmitRequest {
                workload: "N-body(N=100k)".to_string(),
                length_hours: 2.0,
                queue: i % 3,
            });
            assert!(matches!(r, Response::Submitted { .. }), "{r:?}");
        }
        // Round-robin spread the stream, so the union must hold every
        // recorded decision across both shards.
        let total: u64 = cluster
            .shards
            .iter()
            .map(|sh| sh.handle.latency_histogram().unwrap().count())
            .sum();
        assert_eq!(total, 6);
        match cluster.stats_merged() {
            Response::Stats(st) => {
                assert_eq!(st.accepted, 6);
                assert!(st.p99_decision_ms > 0.0);
                assert!(st.p99_decision_ms >= st.p50_decision_ms);
            }
            other => panic!("expected stats, got {other:?}"),
        }
        cluster.drain();
        cluster.shutdown();
    }
}
