//! Connection-oriented transports for the coordinator.
//!
//! Two implementations of one [`Transport`] / [`Connection`] pair:
//!
//! * [`TcpTransport`] — a real `std::net` TCP client, speaking
//!   length-delimited v2 envelopes (u32 big-endian byte length + UTF-8
//!   JSON body), paired with [`run_tcp_server`]'s thread-per-connection
//!   listener (`carbonflex serve --tcp ADDR`).
//! * [`LoopbackTransport`] — a deterministic in-process link whose
//!   faults (drop, duplicate, reorder/delay, response loss, mid-session
//!   disconnect) are expanded from a seeded
//!   [`LinkPlan`](crate::faults::net::LinkPlan). No threads, no clocks:
//!   the same plan replays the identical byte history every run.
//!
//! Both hand received frames to a [`FrameHandler`] — the session layer
//! implements it — so the transport knows nothing about sessions and the
//! session layer knows nothing about sockets.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::faults::net::{LinkFault, LinkPlan};

/// Largest accepted frame body, bytes. A length prefix beyond this is
/// treated as a corrupt stream, not an allocation request.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Structured transport failures. `Timeout` and `Disconnected` are the
/// two the session client acts on (retry vs. reconnect); everything else
/// is terminal for the attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// No frame arrived within the read timeout; the link may be fine.
    Timeout,
    /// The peer hung up (EOF / reset / planned disconnect).
    Disconnected,
    /// The transport was shut down on purpose; do not reconnect.
    Closed,
    /// Any other I/O or framing failure.
    Io(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Timeout => write!(f, "transport timeout"),
            TransportError::Disconnected => write!(f, "transport disconnected"),
            TransportError::Closed => write!(f, "transport closed"),
            TransportError::Io(msg) => write!(f, "transport i/o error: {msg}"),
        }
    }
}

/// One live connection: send a frame, receive a frame. Frames are whole
/// JSON envelope lines without trailing newline.
pub trait Connection: Send {
    fn send(&mut self, frame: &str) -> Result<(), TransportError>;
    fn recv(&mut self) -> Result<String, TransportError>;
}

/// A dialable endpoint. `dial` either establishes a fresh connection or
/// reports why it cannot; `is_wall_clock` tells the client whether
/// reconnect backoff should actually sleep (TCP) or just count
/// (deterministic loopback).
pub trait Transport: Send {
    fn dial(&mut self) -> Result<Box<dyn Connection>, TransportError>;
    fn is_wall_clock(&self) -> bool {
        false
    }
}

/// The server side of a transport: consumes one envelope line, returns
/// zero or more response lines. Implemented by the session layer.
pub trait FrameHandler: Send {
    fn handle_frame(&mut self, line: &str) -> Vec<String>;
    /// True once the served cluster has drained and the listener should
    /// stop accepting and wind down.
    fn done(&self) -> bool;
}

// ---------------------------------------------------------------------------
// Frame codec: u32 big-endian body length + UTF-8 JSON body.
// ---------------------------------------------------------------------------

/// Encode one frame into `buf` (length prefix + body).
pub fn encode_frame(frame: &str, buf: &mut Vec<u8>) {
    let body = frame.as_bytes();
    buf.extend_from_slice(&(body.len() as u32).to_be_bytes());
    buf.extend_from_slice(body);
}

/// Try to pop one complete frame off the front of `buf`. Returns
/// `Ok(None)` when more bytes are needed.
pub fn decode_frame(buf: &mut Vec<u8>) -> Result<Option<String>, TransportError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(TransportError::Io(format!(
            "frame length {len} exceeds max {MAX_FRAME_BYTES}"
        )));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let body = buf[4..4 + len].to_vec();
    buf.drain(..4 + len);
    String::from_utf8(body)
        .map(Some)
        .map_err(|_| TransportError::Io("frame body is not UTF-8".to_string()))
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// Dials a TCP address; each connection reads with a bounded timeout so
/// the client can notice silence and retry.
pub struct TcpTransport {
    pub addr: String,
    pub read_timeout: Duration,
}

impl TcpTransport {
    pub fn new(addr: &str) -> TcpTransport {
        TcpTransport { addr: addr.to_string(), read_timeout: Duration::from_millis(2000) }
    }
}

impl Transport for TcpTransport {
    fn dial(&mut self) -> Result<Box<dyn Connection>, TransportError> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| TransportError::Io(format!("connect {}: {e}", self.addr)))?;
        stream
            .set_read_timeout(Some(self.read_timeout))
            .map_err(|e| TransportError::Io(e.to_string()))?;
        let _ = stream.set_nodelay(true);
        Ok(Box::new(TcpConnection { stream, inbuf: Vec::new() }))
    }

    fn is_wall_clock(&self) -> bool {
        true
    }
}

struct TcpConnection {
    stream: TcpStream,
    /// Partial-frame bytes survive read timeouts, so a timeout mid-frame
    /// never desynchronizes the length-delimited stream.
    inbuf: Vec<u8>,
}

impl Connection for TcpConnection {
    fn send(&mut self, frame: &str) -> Result<(), TransportError> {
        let mut out = Vec::with_capacity(frame.len() + 4);
        encode_frame(frame, &mut out);
        self.stream.write_all(&out).map_err(io_to_transport)
    }

    fn recv(&mut self) -> Result<String, TransportError> {
        loop {
            if let Some(frame) = decode_frame(&mut self.inbuf)? {
                return Ok(frame);
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err(TransportError::Disconnected),
                Ok(n) => self.inbuf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(io_to_transport(e)),
            }
        }
    }
}

fn io_to_transport(e: std::io::Error) -> TransportError {
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => TransportError::Timeout,
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => TransportError::Disconnected,
        _ => TransportError::Io(e.to_string()),
    }
}

/// Run the TCP listener: accept in a non-blocking loop, spawn one thread
/// per connection, stop once the handler reports `done`. Use
/// [`bind_tcp`] + [`serve_on`] instead when the caller needs the bound
/// address first (e.g. `addr` asked for port 0).
pub fn run_tcp_server(
    addr: &str,
    handler: Arc<Mutex<dyn FrameHandler>>,
) -> Result<(), TransportError> {
    let listener =
        TcpListener::bind(addr).map_err(|e| TransportError::Io(format!("bind {addr}: {e}")))?;
    listener.set_nonblocking(true).map_err(|e| TransportError::Io(e.to_string()))?;
    serve_on(listener, handler)
}

/// Bind to `addr` and return `(listener, bound_addr)` without serving
/// yet — lets a caller learn an OS-assigned port before dialing.
pub fn bind_tcp(addr: &str) -> Result<(TcpListener, String), TransportError> {
    let listener =
        TcpListener::bind(addr).map_err(|e| TransportError::Io(format!("bind {addr}: {e}")))?;
    listener.set_nonblocking(true).map_err(|e| TransportError::Io(e.to_string()))?;
    let bound = listener
        .local_addr()
        .map(|a| a.to_string())
        .map_err(|e| TransportError::Io(e.to_string()))?;
    Ok((listener, bound))
}

/// Accept/serve loop over an already-bound non-blocking listener.
pub fn serve_on(
    listener: TcpListener,
    handler: Arc<Mutex<dyn FrameHandler>>,
) -> Result<(), TransportError> {
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if handler.lock().map(|h| h.done()).unwrap_or(true) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let h = Arc::clone(&handler);
                workers.push(std::thread::spawn(move || serve_connection(stream, h)));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(TransportError::Io(e.to_string())),
        }
    }
    for w in workers {
        let _ = w.join();
    }
    Ok(())
}

fn serve_connection(stream: TcpStream, handler: Arc<Mutex<dyn FrameHandler>>) {
    if stream.set_read_timeout(Some(Duration::from_millis(200))).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let mut conn = TcpConnection { stream, inbuf: Vec::new() };
    loop {
        match conn.recv() {
            Ok(frame) => {
                let responses = match handler.lock() {
                    Ok(mut h) => h.handle_frame(&frame),
                    Err(_) => return,
                };
                for resp in responses {
                    if conn.send(&resp).is_err() {
                        return;
                    }
                }
            }
            // Silence: poll the done flag so drained servers shed
            // lingering connections instead of blocking shutdown.
            Err(TransportError::Timeout) => {
                if handler.lock().map(|h| h.done()).unwrap_or(true) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// Deterministic loopback with seeded link faults
// ---------------------------------------------------------------------------

struct LinkState {
    plan: LinkPlan,
    /// Monotonic across reconnects, so retried frames consume fresh plan
    /// indices instead of re-hitting the fault that killed them.
    send_index: usize,
    /// Delayed frames: `(deliver_at_index, drop_resp, frame)`.
    held: Vec<(usize, bool, String)>,
    resp_queue: VecDeque<String>,
    disconnected: bool,
}

/// In-process transport: frames go straight to the [`FrameHandler`]
/// through a fault lens expanded from a seeded [`LinkPlan`]. With an
/// empty plan it is a perfectly clean, perfectly ordered link.
pub struct LoopbackTransport {
    handler: Arc<Mutex<dyn FrameHandler>>,
    state: Arc<Mutex<LinkState>>,
}

impl LoopbackTransport {
    pub fn new(handler: Arc<Mutex<dyn FrameHandler>>, plan: LinkPlan) -> LoopbackTransport {
        LoopbackTransport {
            handler,
            state: Arc::new(Mutex::new(LinkState {
                plan,
                send_index: 0,
                held: Vec::new(),
                resp_queue: VecDeque::new(),
                disconnected: false,
            })),
        }
    }
}

impl Transport for LoopbackTransport {
    fn dial(&mut self) -> Result<Box<dyn Connection>, TransportError> {
        let mut st = self.state.lock().map_err(|_| TransportError::Closed)?;
        // A fresh connection: the break heals, but anything in flight at
        // the moment of disconnect is gone for good.
        st.disconnected = false;
        st.held.clear();
        st.resp_queue.clear();
        drop(st);
        Ok(Box::new(LoopbackConnection {
            handler: Arc::clone(&self.handler),
            state: Arc::clone(&self.state),
        }))
    }
}

struct LoopbackConnection {
    handler: Arc<Mutex<dyn FrameHandler>>,
    state: Arc<Mutex<LinkState>>,
}

impl LoopbackConnection {
    fn deliver(&self, st: &mut LinkState, frame: &str, drop_resp: bool) {
        let responses = match self.handler.lock() {
            Ok(mut h) => h.handle_frame(frame),
            Err(_) => return,
        };
        if !drop_resp {
            st.resp_queue.extend(responses);
        }
    }

    /// Deliver held frames whose scheduled index has passed (or all of
    /// them when `all` — the link draining while the client waits).
    fn flush_held(&self, st: &mut LinkState, all: bool) {
        loop {
            let idx = st
                .held
                .iter()
                .enumerate()
                .filter(|(_, (at, _, _))| all || *at <= st.send_index)
                .min_by_key(|(_, (at, _, _))| *at)
                .map(|(i, _)| i);
            match idx {
                Some(i) => {
                    let (_, drop_resp, frame) = st.held.remove(i);
                    self.deliver(st, &frame, drop_resp);
                }
                None => break,
            }
        }
    }
}

impl Connection for LoopbackConnection {
    fn send(&mut self, frame: &str) -> Result<(), TransportError> {
        let mut st = self.state.lock().map_err(|_| TransportError::Closed)?;
        if st.disconnected {
            return Err(TransportError::Disconnected);
        }
        let i = st.send_index;
        st.send_index += 1;
        let fault = if st.plan.is_empty() { None } else { st.plan.fault_at(i) };
        match fault {
            Some(LinkFault::Disconnect) => {
                st.disconnected = true;
                st.held.clear();
                return Err(TransportError::Disconnected);
            }
            Some(LinkFault::DropReq) => {}
            Some(LinkFault::DupReq) => {
                self.deliver(&mut st, frame, false);
                self.deliver(&mut st, frame, false);
            }
            Some(LinkFault::Delay(by)) => {
                let at = i + by;
                st.held.push((at, false, frame.to_string()));
            }
            Some(LinkFault::DropResp) => self.deliver(&mut st, frame, true),
            None => self.deliver(&mut st, frame, false),
        }
        self.flush_held(&mut st, false);
        Ok(())
    }

    fn recv(&mut self) -> Result<String, TransportError> {
        let mut st = self.state.lock().map_err(|_| TransportError::Closed)?;
        if let Some(resp) = st.resp_queue.pop_front() {
            return Ok(resp);
        }
        if st.disconnected {
            return Err(TransportError::Disconnected);
        }
        // The client is waiting and nothing else is in flight: any
        // delayed frames arrive now, in schedule order.
        self.flush_held(&mut st, true);
        match st.resp_queue.pop_front() {
            Some(resp) => Ok(resp),
            None => Err(TransportError::Timeout),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::net::LinkFaultSpec;

    use std::sync::atomic::{AtomicBool, Ordering};

    /// Echo handler: replies with the same line prefixed `ok:`.
    struct Echo {
        seen: Vec<String>,
        stop: Arc<AtomicBool>,
    }

    impl Echo {
        fn new() -> Echo {
            Echo { seen: Vec::new(), stop: Arc::new(AtomicBool::new(false)) }
        }
    }

    impl FrameHandler for Echo {
        fn handle_frame(&mut self, line: &str) -> Vec<String> {
            self.seen.push(line.to_string());
            vec![format!("ok:{line}")]
        }
        fn done(&self) -> bool {
            self.stop.load(Ordering::SeqCst)
        }
    }

    #[test]
    fn frame_codec_roundtrip() {
        let mut buf = Vec::new();
        encode_frame("hello", &mut buf);
        encode_frame("world", &mut buf);
        assert_eq!(decode_frame(&mut buf).unwrap(), Some("hello".to_string()));
        assert_eq!(decode_frame(&mut buf).unwrap(), Some("world".to_string()));
        assert_eq!(decode_frame(&mut buf).unwrap(), None);
        // Partial frames wait for more bytes.
        let mut partial = Vec::new();
        encode_frame("abcdef", &mut partial);
        let mut head: Vec<u8> = partial[..7].to_vec();
        assert_eq!(decode_frame(&mut head).unwrap(), None);
        head.extend_from_slice(&partial[7..]);
        assert_eq!(decode_frame(&mut head).unwrap(), Some("abcdef".to_string()));
        // Oversized length prefix is a structured error.
        let mut bad = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes().to_vec();
        bad.push(0);
        assert!(decode_frame(&mut bad).is_err());
    }

    #[test]
    fn clean_loopback_is_ordered_and_lossless() {
        let handler: Arc<Mutex<dyn FrameHandler>> =
            Arc::new(Mutex::new(Echo::new()));
        let mut t = LoopbackTransport::new(Arc::clone(&handler), LinkPlan::none());
        let mut conn = t.dial().unwrap();
        for i in 0..5 {
            conn.send(&format!("m{i}")).unwrap();
        }
        for i in 0..5 {
            assert_eq!(conn.recv().unwrap(), format!("ok:m{i}"));
        }
        assert_eq!(conn.recv(), Err(TransportError::Timeout));
    }

    #[test]
    fn loopback_faults_fire_as_planned() {
        use std::collections::BTreeMap;
        let mut faults = BTreeMap::new();
        faults.insert(1, LinkFault::DropReq);
        faults.insert(2, LinkFault::DupReq);
        faults.insert(3, LinkFault::Delay(2));
        faults.insert(4, LinkFault::DropResp);
        faults.insert(6, LinkFault::Disconnect);
        let plan = LinkPlan { faults };
        let handler: Arc<Mutex<dyn FrameHandler>> =
            Arc::new(Mutex::new(Echo::new()));
        let mut t = LoopbackTransport::new(Arc::clone(&handler), plan);
        let mut conn = t.dial().unwrap();
        for i in 0..6 {
            conn.send(&format!("m{i}")).unwrap();
        }
        // Index 6 hits the disconnect.
        assert_eq!(conn.send("m6"), Err(TransportError::Disconnected));
        let mut got = Vec::new();
        while let Ok(r) = conn.recv() {
            got.push(r);
        }
        // m0 clean, m1 dropped, m2 duplicated, m3 delayed until index 5,
        // m4 delivered respless, m5 clean.
        {
            let h = handler.lock().unwrap();
            let seen: Vec<&str> = h.seen.iter().map(|s| s.as_str()).collect();
            assert_eq!(seen, vec!["m0", "m2", "m2", "m4", "m3", "m5"]);
        }
        assert_eq!(got, vec!["ok:m0", "ok:m2", "ok:m2", "ok:m3", "ok:m5"]);
        // Reconnect heals the link; indices keep advancing past 6.
        let mut conn2 = t.dial().unwrap();
        conn2.send("m7").unwrap();
        assert_eq!(conn2.recv().unwrap(), "ok:m7");
    }

    #[test]
    fn seeded_plan_behaves_identically_across_runs() {
        let spec = LinkFaultSpec::light();
        let run = |seed: u64| -> Vec<String> {
            let plan = LinkPlan::generate(seed, &spec, 32);
            let handler: Arc<Mutex<dyn FrameHandler>> =
                Arc::new(Mutex::new(Echo::new()));
            let mut t = LoopbackTransport::new(Arc::clone(&handler), plan);
            let mut conn = match t.dial() {
                Ok(c) => c,
                Err(_) => return Vec::new(),
            };
            let mut got = Vec::new();
            for i in 0..32 {
                if conn.send(&format!("m{i}")).is_err() {
                    conn = t.dial().unwrap();
                    let _ = conn.send(&format!("m{i}"));
                }
                while let Ok(r) = conn.recv() {
                    got.push(r);
                }
            }
            got
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn tcp_roundtrip_over_localhost() {
        let echo = Echo::new();
        let stop = Arc::clone(&echo.stop);
        let handler: Arc<Mutex<dyn FrameHandler>> = Arc::new(Mutex::new(echo));
        let (listener, bound) = bind_tcp("127.0.0.1:0").unwrap();
        let h = Arc::clone(&handler);
        let server = std::thread::spawn(move || serve_on(listener, h));
        let mut t = TcpTransport::new(&bound);
        let mut conn = t.dial().unwrap();
        conn.send("ping-1").unwrap();
        assert_eq!(conn.recv().unwrap(), "ok:ping-1");
        conn.send("ping-2").unwrap();
        assert_eq!(conn.recv().unwrap(), "ok:ping-2");
        drop(conn);
        stop.store(true, Ordering::SeqCst);
        server.join().unwrap().unwrap();
    }
}
