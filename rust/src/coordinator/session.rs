//! Session layer over the coordinator transports: handshake with resume
//! tokens, per-session monotonic sequence numbers, server-side dedup of
//! retried submits, bounded replay buffers, and heartbeat/lease expiry.
//!
//! The [`SessionServer`] wraps a [`ShardedCoordinator`] behind the
//! [`FrameHandler`] interface both transports speak. Clients open a
//! session with a `hello` frame, then send ordinary v2 envelopes carrying
//! three extra top-level keys:
//!
//! * `session` — the session id from the handshake,
//! * `seq` — a per-session monotonic sequence number starting at 0,
//! * `ack` — the highest `seq` whose response the client has received
//!   (lets the server drop replay entries).
//!
//! The server applies frames **in sequence order**: duplicates
//! (`seq < next`) are answered from the replay cache without touching the
//! cluster — a retried submit is idempotent and, crucially, does not
//! advance the kill-plan submission clock — and early frames
//! (`seq > next`) are parked until the gap closes, so a reordered link
//! drains bitwise identical to an in-order one. Lease expiry sheds
//! sessions whose client went silent, folding their counters into the
//! exactly-once accounting instead of losing them.
//!
//! Frames without a `session` key pass straight through to the cluster —
//! a session-unaware stdio/TCP client sees the exact pre-session
//! protocol.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::api::{
    ErrorCode, Request, Response, WireRequest, WireResponse, PROTOCOL_VERSION,
};
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::transport::FrameHandler;
use crate::util::json::{self, Json};

/// Session-layer knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionConfig {
    /// A session with no traffic for this many virtual slots is expired
    /// and its counters folded into the retired accounting.
    pub lease_slots: usize,
    /// Largest tolerated gap between an early frame's `seq` and the next
    /// expected one, and the bound on cached unacked responses.
    pub replay_window: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { lease_slots: 24, replay_window: 1024 }
    }
}

/// Per-session accounting folded into [`SessionCounters`] on close.
#[derive(Debug, Clone, Default)]
struct SessionLedger {
    accepted: u64,
    shed: u64,
    dedup_hits: u64,
}

struct SessionState {
    client: String,
    token: String,
    /// Lowest sequence number not yet applied.
    next_apply: u64,
    /// Early frames (raw lines) waiting for the gap to close.
    parked: BTreeMap<u64, String>,
    /// Applied-but-unacked responses, keyed by seq, ready for replay.
    replay: BTreeMap<u64, String>,
    /// Virtual slot of the last frame seen from this session.
    last_active_slot: usize,
    ledger: SessionLedger,
}

/// Aggregate session-layer counters (live sessions + retired ones).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionCounters {
    /// Fresh handshakes served.
    pub handshakes: u64,
    /// Successful resume handshakes.
    pub resumes: u64,
    /// Retried frames answered from the replay cache without touching
    /// the cluster.
    pub dedup_hits: u64,
    /// Sessions shed by lease expiry.
    pub expired_sessions: u64,
    /// Unacked responses outstanding when their session expired.
    pub expired_unacked: u64,
    /// Sessions closed cleanly by `bye`.
    pub closed_sessions: u64,
    /// Submissions accepted across all sessions (the client side of the
    /// exactly-once identity).
    pub accepted: u64,
    /// Submissions shed by backpressure across all sessions.
    pub shed: u64,
}

/// The server side of the session protocol: owns the cluster and every
/// live session. One instance serves all connections of a deployment
/// (the transports hand it frames under a mutex).
pub struct SessionServer {
    cluster: ShardedCoordinator,
    cfg: SessionConfig,
    sessions: BTreeMap<u64, SessionState>,
    by_token: BTreeMap<String, u64>,
    next_session: u64,
    /// Virtual slot mirror (advanced by applied ticks) — the lease clock.
    slot: usize,
    /// Counters of sessions already retired (expired or closed).
    retired: SessionCounters,
    done: bool,
}

/// Deterministic resume token: a keyed fold of (client, session id).
/// Deterministic on purpose — reconnect tests and seeded benches replay
/// identical handshakes; this is not an authentication boundary.
fn token_of(client: &str, id: u64) -> String {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in client.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^= id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h = h.rotate_left(27).wrapping_mul(0x2545_F491_4F6C_DD1D);
    format!("tok-{h:016x}")
}

/// Checked decode of an unsigned envelope counter (`seq`, `ack`,
/// `session`): present, finite, integral, non-negative.
fn seq_field(v: &Json, key: &str) -> Option<u64> {
    v.get(key)
        .and_then(Json::as_f64)
        .filter(|f| f.is_finite() && *f >= 0.0 && f.fract() == 0.0)
        .map(|f| f as u64)
}

impl SessionServer {
    pub fn new(cluster: ShardedCoordinator, cfg: SessionConfig) -> SessionServer {
        SessionServer {
            cluster,
            cfg,
            sessions: BTreeMap::new(),
            by_token: BTreeMap::new(),
            next_session: 0,
            slot: 0,
            retired: SessionCounters::default(),
            done: false,
        }
    }

    /// True once a drain has been applied (via any path).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Live + retired counters.
    pub fn counters(&self) -> SessionCounters {
        let mut c = self.retired;
        for s in self.sessions.values() {
            c.accepted += s.ledger.accepted;
            c.shed += s.ledger.shed;
            c.dedup_hits += s.ledger.dedup_hits;
        }
        c
    }

    /// Hand the cluster back for shutdown accounting (killed metrics,
    /// failover counters).
    pub fn into_cluster(self) -> ShardedCoordinator {
        self.cluster
    }

    /// Consume one envelope line, produce zero or more response lines.
    /// Zero happens only for parked (early) frames.
    pub fn handle_line(&mut self, line: &str) -> Vec<String> {
        let parsed = match json::parse(line.trim()) {
            Ok(v) => v,
            Err(e) => {
                return vec![error_line(
                    ErrorCode::BadRequest,
                    &format!("invalid json: {e}"),
                    None,
                    &[],
                )]
            }
        };
        match parsed.get("op").and_then(Json::as_str) {
            Some("hello") => vec![self.handshake(&parsed)],
            Some("ping") => vec![self.ping(&parsed)],
            Some("bye") => vec![self.bye(&parsed)],
            _ if parsed.get("session").is_some() => self.sequenced(&parsed, line),
            _ => vec![self.passthrough(line)],
        }
    }

    /// `hello`: open a fresh session, or resume one by token. The reply
    /// carries the session id, resume token, next expected seq, and the
    /// lease length, so the client knows both its address and how long
    /// it may stay silent.
    fn handshake(&mut self, v: &Json) -> String {
        let client = v.get("client").and_then(Json::as_str).unwrap_or("anon").to_string();
        if let Some(token) = v.get("resume").and_then(Json::as_str) {
            if let Some(&sid) = self.by_token.get(token) {
                let slot = self.slot;
                let ack = seq_field(v, "ack");
                let sess = self.sessions.get_mut(&sid).expect("token index out of sync");
                sess.last_active_slot = slot;
                if let Some(a) = ack {
                    apply_ack(sess, a);
                }
                self.retired.resumes += 1;
                return hello_line(sid, &sess.token, sess.next_apply, self.cfg, true);
            }
            // Unknown or expired token: fall through to a fresh session.
            // The reply says `resumed: false`, so the client knows its
            // unacked frames must not be replayed blindly.
        }
        let sid = self.next_session;
        self.next_session += 1;
        let token = token_of(&client, sid);
        self.by_token.insert(token.clone(), sid);
        self.sessions.insert(
            sid,
            SessionState {
                client,
                token: token.clone(),
                next_apply: 0,
                parked: BTreeMap::new(),
                replay: BTreeMap::new(),
                last_active_slot: self.slot,
                ledger: SessionLedger::default(),
            },
        );
        self.retired.handshakes += 1;
        hello_line(sid, &token, 0, self.cfg, false)
    }

    /// `ping`: unsequenced heartbeat. Refreshes the lease; answers with
    /// the server's virtual slot.
    fn ping(&mut self, v: &Json) -> String {
        let mut pairs = vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("ok", Json::Bool(true)),
            ("kind", Json::Str("pong".into())),
            ("slot", Json::num(self.slot as f64)),
        ];
        if let Some(sid) = seq_field(v, "session") {
            if let Some(sess) = self.sessions.get_mut(&sid) {
                sess.last_active_slot = self.slot;
                pairs.push(("session", Json::num(sid as f64)));
            }
        }
        Json::obj(pairs).to_string()
    }

    /// `bye`: clean close. Final ack applies, counters fold into the
    /// retired totals, the session and its token disappear.
    fn bye(&mut self, v: &Json) -> String {
        if let Some(sid) = seq_field(v, "session") {
            if let Some(mut sess) = self.sessions.remove(&sid) {
                if let Some(a) = seq_field(v, "ack") {
                    apply_ack(&mut sess, a);
                }
                self.by_token.remove(&sess.token);
                self.retired.closed_sessions += 1;
                self.fold_ledger(&sess);
            }
        }
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("ok", Json::Bool(true)),
            ("kind", Json::Str("bye".into())),
        ])
        .to_string()
    }

    fn fold_ledger(&mut self, sess: &SessionState) {
        self.retired.accepted += sess.ledger.accepted;
        self.retired.shed += sess.ledger.shed;
        self.retired.dedup_hits += sess.ledger.dedup_hits;
    }

    /// A sequenced frame: dedup below the cursor, apply at it, park above
    /// it. All responses carry `session` and `seq` extras for client-side
    /// correlation.
    fn sequenced(&mut self, v: &Json, line: &str) -> Vec<String> {
        let Some(sid) = seq_field(v, "session") else {
            return vec![error_line(
                ErrorCode::BadRequest,
                "'session' must be a non-negative integer",
                None,
                &[],
            )];
        };
        if !self.sessions.contains_key(&sid) {
            // Unknown (expired or never opened): the client must
            // re-handshake before anything else applies.
            return vec![error_line(
                ErrorCode::BadRequest,
                &format!("unknown session {sid}"),
                None,
                &[("session", Json::num(sid as f64))],
            )];
        }
        let Some(seq) = seq_field(v, "seq") else {
            return vec![error_line(
                ErrorCode::BadRequest,
                "sequenced frame missing 'seq'",
                None,
                &[("session", Json::num(sid as f64))],
            )];
        };
        let ack = seq_field(v, "ack");
        let window = self.cfg.replay_window;
        let slot = self.slot;
        {
            let sess = self.sessions.get_mut(&sid).expect("checked above");
            sess.last_active_slot = slot;
            if let Some(a) = ack {
                apply_ack(sess, a);
            }
            if seq < sess.next_apply {
                // Retry of an already-applied frame: answer from the
                // replay cache. The cluster — and with it the kill-plan
                // submission clock — is never consulted twice.
                sess.ledger.dedup_hits += 1;
                let cached = sess.replay.get(&seq).cloned();
                return vec![cached.unwrap_or_else(|| {
                    error_line(
                        ErrorCode::BadRequest,
                        &format!("seq {seq} already applied and acked"),
                        None,
                        &[("session", Json::num(sid as f64)), ("seq", Json::num(seq as f64))],
                    )
                })];
            }
            if seq > sess.next_apply {
                if seq - sess.next_apply > window {
                    return vec![error_line(
                        ErrorCode::BadRequest,
                        &format!(
                            "seq {seq} is {} past the cursor (replay window {window})",
                            seq - sess.next_apply
                        ),
                        None,
                        &[("session", Json::num(sid as f64)), ("seq", Json::num(seq as f64))],
                    )];
                }
                // Early: park until the gap closes. No response yet — the
                // client's retry discipline covers the missing frame.
                sess.parked.insert(seq, line.to_string());
                return Vec::new();
            }
        }
        // seq == next_apply: apply it, then drain any parked successors
        // the gap-close just unlocked.
        let mut out = vec![self.apply_one(sid, seq, line)];
        loop {
            let next = {
                let sess = self.sessions.get_mut(&sid).expect("session vanished mid-apply");
                let cursor = sess.next_apply;
                sess.parked.remove(&cursor).map(|l| (cursor, l))
            };
            match next {
                Some((cursor, parked_line)) => {
                    out.push(self.apply_one(sid, cursor, parked_line.as_str()))
                }
                None => break,
            }
        }
        out
    }

    /// Apply one in-order frame to the cluster, cache and return its
    /// encoded response.
    fn apply_one(&mut self, sid: u64, seq: u64, line: &str) -> String {
        let extras =
            [("session", Json::num(sid as f64)), ("seq", Json::num(seq as f64))];
        let encoded = match WireRequest::from_json_line(line) {
            Ok(wire) => {
                let resp = self.cluster.handle_request(wire.req);
                if let Some(sess) = self.sessions.get_mut(&sid) {
                    count_outcomes(&mut sess.ledger, &resp);
                }
                match &resp {
                    Response::Ticked { slot } => {
                        self.slot = *slot;
                        self.expire_leases();
                    }
                    Response::Drained { .. } => self.done = true,
                    _ => {}
                }
                WireResponse { v: PROTOCOL_VERSION, id: wire.id, resp }
                    .to_json_line_with(&extras)
            }
            // Malformed frames still consume their sequence slot — the
            // error is the (cached, replayable) response.
            Err(p) => error_line(p.code, &p.message, p.id, &extras),
        };
        if let Some(sess) = self.sessions.get_mut(&sid) {
            sess.next_apply = seq + 1;
            sess.replay.insert(seq, encoded.clone());
            // A client that never acks cannot grow the cache without
            // bound; oldest entries go first (it acked nothing, so it can
            // re-derive nothing — misbehavior costs the misbehaver).
            while sess.replay.len() as u64 > self.cfg.replay_window {
                sess.replay.pop_first();
            }
        }
        encoded
    }

    /// Shed sessions whose lease ran out: silent past `lease_slots`.
    /// Their counters fold into the retired totals, so the exactly-once
    /// accounting keeps every accepted submission visible.
    fn expire_leases(&mut self) {
        let cutoff = self.cfg.lease_slots;
        let slot = self.slot;
        let dead: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| slot.saturating_sub(s.last_active_slot) > cutoff)
            .map(|(&id, _)| id)
            .collect();
        for sid in dead {
            if let Some(sess) = self.sessions.remove(&sid) {
                self.by_token.remove(&sess.token);
                self.retired.expired_sessions += 1;
                self.retired.expired_unacked += sess.replay.len() as u64;
                self.fold_ledger(&sess);
            }
        }
    }

    /// A line with no session machinery: the pre-session stdio protocol,
    /// byte for byte.
    fn passthrough(&mut self, line: &str) -> String {
        match WireRequest::from_json_line(line) {
            Ok(wire) => {
                let resp = self.cluster.handle_request(wire.req);
                match &resp {
                    Response::Ticked { slot } => {
                        self.slot = *slot;
                        self.expire_leases();
                    }
                    Response::Drained { .. } => self.done = true,
                    _ => {}
                }
                WireResponse { v: wire.v.max(1), id: wire.id, resp }.to_json_line()
            }
            Err(p) => WireResponse {
                v: PROTOCOL_VERSION,
                id: p.id,
                resp: Response::Error { code: p.code, message: p.message },
            }
            .to_json_line(),
        }
    }
}

impl FrameHandler for SessionServer {
    fn handle_frame(&mut self, line: &str) -> Vec<String> {
        self.handle_line(line)
    }
    fn done(&self) -> bool {
        self.done
    }
}

/// Extract the cluster back out of a shared server once every transport
/// clone has been dropped. `None` while other `Arc` handles survive.
pub fn take_cluster(server: Arc<Mutex<SessionServer>>) -> Option<ShardedCoordinator> {
    Arc::try_unwrap(server)
        .ok()
        .map(|m| m.into_inner().expect("session server poisoned").into_cluster())
}

fn apply_ack(sess: &mut SessionState, ack: u64) {
    // Everything at or below the ack cursor is delivered; replaying it
    // can never be needed again.
    sess.replay.retain(|&seq, _| seq > ack);
}

/// Fold a response's submission outcomes into a session ledger.
fn count_outcomes(ledger: &mut SessionLedger, resp: &Response) {
    match resp {
        Response::Submitted { .. } => ledger.accepted += 1,
        Response::Error { code: ErrorCode::QueueFull | ErrorCode::Shed, .. } => ledger.shed += 1,
        Response::Batch { results } => {
            for r in results {
                match r {
                    crate::coordinator::api::SubmitOutcome::Accepted { .. } => {
                        ledger.accepted += 1
                    }
                    crate::coordinator::api::SubmitOutcome::Rejected {
                        code: ErrorCode::QueueFull | ErrorCode::Shed,
                        ..
                    } => ledger.shed += 1,
                    crate::coordinator::api::SubmitOutcome::Rejected { .. } => {}
                }
            }
        }
        _ => {}
    }
}

fn hello_line(sid: u64, token: &str, next_seq: u64, cfg: SessionConfig, resumed: bool) -> String {
    Json::obj(vec![
        ("v", Json::num(PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(true)),
        ("kind", Json::Str("hello".into())),
        ("session", Json::num(sid as f64)),
        ("token", Json::Str(token.to_string())),
        ("next_seq", Json::num(next_seq as f64)),
        ("lease_slots", Json::num(cfg.lease_slots as f64)),
        ("resumed", Json::Bool(resumed)),
    ])
    .to_string()
}

fn error_line(
    code: ErrorCode,
    message: &str,
    id: Option<String>,
    extras: &[(&str, Json)],
) -> String {
    WireResponse {
        v: PROTOCOL_VERSION,
        id,
        resp: Response::Error { code, message: message.to_string() },
    }
    .to_json_line_with(extras)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, ServiceConfig};
    use crate::coordinator::shard::shard_regions;
    use crate::experiments::cells::DispatchStrategy;
    use crate::sched::PolicyKind;

    fn small_server() -> SessionServer {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 8;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let service = ServiceConfig::default();
        let regions = shard_regions("1", &cfg.region).unwrap();
        let cluster = ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &regions,
            DispatchStrategy::RoundRobin,
        );
        SessionServer::new(cluster, SessionConfig::default())
    }

    fn submit_line(sid: u64, seq: u64, ack: Option<u64>) -> String {
        let wire = WireRequest::new(Request::Submit(crate::coordinator::api::SubmitRequest {
            workload: "N-body(N=100k)".to_string(),
            length_hours: 2.0,
            queue: 0,
        }));
        let mut extras = vec![
            ("session", Json::num(sid as f64)),
            ("seq", Json::num(seq as f64)),
        ];
        if let Some(a) = ack {
            extras.push(("ack", Json::num(a as f64)));
        }
        wire.to_json_line_with(&extras)
    }

    fn hello(server: &mut SessionServer, client: &str) -> (u64, String) {
        let line = format!(r#"{{"op":"hello","client":"{client}"}}"#);
        let out = server.handle_line(&line);
        assert_eq!(out.len(), 1);
        let v = json::parse(&out[0]).unwrap();
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("hello"));
        let sid = v.get("session").and_then(Json::as_usize).unwrap() as u64;
        let token = v.get("token").and_then(Json::as_str).unwrap().to_string();
        (sid, token)
    }

    #[test]
    fn handshake_submit_dedup_roundtrip() {
        let mut server = small_server();
        let (sid, _token) = hello(&mut server, "alice");
        let line = submit_line(sid, 0, None);
        let first = server.handle_line(&line);
        assert_eq!(first.len(), 1);
        assert!(first[0].contains("\"job_id\""), "{}", first[0]);
        // Retrying the same seq replays the identical bytes and never
        // re-submits: accepted stays 1, dedup_hits counts the retry.
        let retry = server.handle_line(&line);
        assert_eq!(retry, first);
        let c = server.counters();
        assert_eq!(c.accepted, 1);
        assert_eq!(c.dedup_hits, 1);
        assert_eq!(c.handshakes, 1);
    }

    #[test]
    fn reordered_frames_apply_in_sequence_order() {
        let mut server = small_server();
        let (sid, _) = hello(&mut server, "bob");
        // seq 1 arrives early: parked, no response.
        let early = server.handle_line(&submit_line(sid, 1, None));
        assert!(early.is_empty());
        // seq 0 closes the gap: both apply, in order, in one go.
        let out = server.handle_line(&submit_line(sid, 0, None));
        assert_eq!(out.len(), 2);
        let v0 = json::parse(&out[0]).unwrap();
        let v1 = json::parse(&out[1]).unwrap();
        assert_eq!(v0.get("seq").and_then(Json::as_usize), Some(0));
        assert_eq!(v1.get("seq").and_then(Json::as_usize), Some(1));
        assert_eq!(v0.get("job_id").and_then(Json::as_usize), Some(0));
        assert_eq!(v1.get("job_id").and_then(Json::as_usize), Some(1));
        assert_eq!(server.counters().accepted, 2);
    }

    #[test]
    fn ack_compacts_replay_and_resume_restores_cursor() {
        let mut server = small_server();
        let (sid, token) = hello(&mut server, "carol");
        server.handle_line(&submit_line(sid, 0, None));
        server.handle_line(&submit_line(sid, 1, Some(0)));
        {
            let sess = server.sessions.get(&sid).unwrap();
            assert_eq!(sess.replay.len(), 1, "acked seq 0 must be dropped");
            assert!(sess.replay.contains_key(&1));
        }
        // Resume by token: same session, cursor intact.
        let out =
            server.handle_line(&format!(r#"{{"op":"hello","client":"carol","resume":"{token}"}}"#));
        let v = json::parse(&out[0]).unwrap();
        assert_eq!(v.get("resumed").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("session").and_then(Json::as_usize), Some(sid as usize));
        assert_eq!(v.get("next_seq").and_then(Json::as_usize), Some(2));
        assert_eq!(server.counters().resumes, 1);
        // Unknown token opens a fresh session instead.
        let out = server.handle_line(r#"{"op":"hello","client":"carol","resume":"tok-bogus"}"#);
        let v = json::parse(&out[0]).unwrap();
        assert_eq!(v.get("resumed").and_then(Json::as_bool), Some(false));
        assert_ne!(v.get("session").and_then(Json::as_usize), Some(sid as usize));
    }

    #[test]
    fn lease_expiry_sheds_silent_sessions_into_accounting() {
        let mut server = small_server();
        server.cfg.lease_slots = 2;
        let (sid, _) = hello(&mut server, "dave");
        server.handle_line(&submit_line(sid, 0, None));
        // Another client ticks the clock past dave's lease.
        let (sid2, _) = hello(&mut server, "erin");
        for seq in 0..4u64 {
            let tick = WireRequest::new(Request::Tick).to_json_line_with(&[
                ("session", Json::num(sid2 as f64)),
                ("seq", Json::num(seq as f64)),
            ]);
            server.handle_line(&tick);
        }
        assert!(!server.sessions.contains_key(&sid), "silent session must expire");
        let c = server.counters();
        assert_eq!(c.expired_sessions, 1);
        assert_eq!(c.expired_unacked, 1, "dave never acked his submit");
        assert_eq!(c.accepted, 1, "expired accounting keeps the accepted submit");
        // A frame on the dead session is a structured error, not a crash.
        let out = server.handle_line(&submit_line(sid, 1, None));
        assert!(out[0].contains("unknown session"), "{}", out[0]);
    }

    #[test]
    fn passthrough_lines_match_the_stdio_protocol() {
        let mut server = small_server();
        let line = WireRequest::new(Request::Status).to_json_line();
        let out = server.handle_line(&line);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("\"kind\": \"status\"") || out[0].contains("\"kind\":\"status\""));
        assert!(!out[0].contains("session"));
        // Drain flips done for the transports' accept loops.
        let out = server.handle_line(&WireRequest::new(Request::Drain).to_json_line());
        assert!(out[0].contains("drained"), "{}", out[0]);
        assert!(server.is_done());
    }

    #[test]
    fn seq_gap_beyond_window_is_rejected() {
        let mut server = small_server();
        server.cfg.replay_window = 4;
        let (sid, _) = hello(&mut server, "frank");
        let out = server.handle_line(&submit_line(sid, 100, None));
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("replay window"), "{}", out[0]);
        // The cursor did not move; in-order traffic still applies.
        let ok = server.handle_line(&submit_line(sid, 0, None));
        assert!(ok[0].contains("job_id"), "{}", ok[0]);
    }

    #[test]
    fn bye_closes_and_folds_counters() {
        let mut server = small_server();
        let (sid, _) = hello(&mut server, "gina");
        server.handle_line(&submit_line(sid, 0, None));
        let out = server.handle_line(&format!(r#"{{"op":"bye","session":{sid},"ack":0}}"#));
        assert!(out[0].contains("\"bye\""), "{}", out[0]);
        assert!(!server.sessions.contains_key(&sid));
        let c = server.counters();
        assert_eq!(c.closed_sessions, 1);
        assert_eq!(c.accepted, 1);
    }
}
