//! Session client: the connection-holding counterpart of
//! [`SessionServer`](crate::coordinator::session::SessionServer).
//!
//! Owns a [`Transport`], a live [`Connection`], and the session state
//! (id, resume token, sequence cursor). Requests go out pipelined with
//! `session`/`seq`/`ack` envelope extras; the client matches responses
//! back by `seq`, retries unanswered frames on timeout, and reconnects
//! with capped, seed-jittered exponential backoff on disconnect —
//! resuming the same session by token so the server's dedup makes every
//! retry idempotent. On a deterministic (non-wall-clock) transport the
//! backoff only counts; on TCP it actually sleeps.

use std::collections::BTreeMap;

use crate::coordinator::api::{Request, Response, WireRequest, WireResponse};
use crate::coordinator::transport::{Connection, Transport, TransportError};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Reconnect/retry policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackoffConfig {
    /// First retry delay, milliseconds.
    pub base_ms: u64,
    /// Delay ceiling, milliseconds.
    pub cap_ms: u64,
    /// Dial attempts per reconnect, and timed-out waits per pipeline,
    /// before giving up.
    pub max_attempts: usize,
}

impl Default for BackoffConfig {
    fn default() -> Self {
        BackoffConfig { base_ms: 50, cap_ms: 2000, max_attempts: 8 }
    }
}

/// Client-side session telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionStats {
    /// Successful reconnect + resume cycles survived.
    pub reconnects: u64,
    /// Frames re-sent after a timeout or reconnect.
    pub retries: u64,
    /// Receive timeouts observed.
    pub timeouts: u64,
    /// Handshakes performed (first connect + every resume).
    pub handshakes: u64,
    /// Backoff delay accumulated, milliseconds (counted even on
    /// deterministic transports that do not sleep).
    pub backoff_ms_total: u64,
}

/// A resuming, retrying session over any [`Transport`].
pub struct SessionClient {
    transport: Box<dyn Transport>,
    conn: Option<Box<dyn Connection>>,
    client_id: String,
    session: Option<u64>,
    token: Option<String>,
    /// Next sequence number to assign.
    next_seq: u64,
    /// Highest seq for which this client has received every response at
    /// or below it — piggybacked as `ack` on outgoing frames.
    ack_cursor: Option<u64>,
    backoff: BackoffConfig,
    rng: Rng,
    stats: SessionStats,
}

impl SessionClient {
    /// `seed` drives the backoff jitter; mixing in the client id keeps
    /// many clients from synchronizing their retry storms.
    pub fn new(transport: Box<dyn Transport>, client_id: &str, seed: u64) -> SessionClient {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in client_id.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        SessionClient {
            transport,
            conn: None,
            client_id: client_id.to_string(),
            session: None,
            token: None,
            next_seq: 0,
            ack_cursor: None,
            backoff: BackoffConfig::default(),
            rng: Rng::new(seed ^ h),
            stats: SessionStats::default(),
        }
    }

    pub fn with_backoff(mut self, backoff: BackoffConfig) -> SessionClient {
        self.backoff = backoff;
        self
    }

    pub fn stats(&self) -> SessionStats {
        self.stats
    }

    pub fn session_id(&self) -> Option<u64> {
        self.session
    }

    /// Drop the live connection (test hook / forced-reconnect demo): the
    /// next operation dials and resumes.
    pub fn force_disconnect(&mut self) {
        self.conn = None;
    }

    /// Capped exponential backoff with seeded jitter:
    /// `min(cap, base * 2^attempt) * (0.5 + 0.5 * u)`. Sleeps only on
    /// wall-clock transports; always counts toward the stats.
    fn backoff_delay_ms(&mut self, attempt: usize) -> u64 {
        let raw = self
            .backoff
            .base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.backoff.cap_ms);
        let jittered = (raw as f64 * (0.5 + 0.5 * self.rng.f64())).round() as u64;
        self.stats.backoff_ms_total += jittered;
        if self.transport.is_wall_clock() {
            std::thread::sleep(std::time::Duration::from_millis(jittered));
        }
        jittered
    }

    /// Dial + handshake until connected, with backoff between attempts.
    /// Resumes by token when one is held; a fresh session otherwise.
    fn ensure_connected(&mut self) -> Result<(), TransportError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut last = TransportError::Disconnected;
        for attempt in 0..self.backoff.max_attempts {
            if attempt > 0 {
                self.backoff_delay_ms(attempt - 1);
            }
            let mut conn = match self.transport.dial() {
                Ok(c) => c,
                Err(e) => {
                    last = e;
                    continue;
                }
            };
            match self.handshake(conn.as_mut()) {
                Ok(()) => {
                    self.conn = Some(conn);
                    return Ok(());
                }
                Err(e) => {
                    last = e;
                    continue;
                }
            }
        }
        Err(last)
    }

    /// Send `hello` (with the resume token when held) and wait for the
    /// `hello` reply. Retries the frame on timeout: a handshake lost to
    /// link faults must not kill the connection attempt.
    fn handshake(&mut self, conn: &mut dyn Connection) -> Result<(), TransportError> {
        let mut pairs = vec![
            ("op", Json::Str("hello".into())),
            ("client", Json::Str(self.client_id.clone())),
        ];
        if let Some(tok) = &self.token {
            pairs.push(("resume", Json::Str(tok.clone())));
        }
        if let Some(a) = self.ack_cursor {
            pairs.push(("ack", Json::num(a as f64)));
        }
        let line = Json::obj(pairs).to_string();
        for _ in 0..self.backoff.max_attempts {
            conn.send(&line)?;
            loop {
                match conn.recv() {
                    Ok(frame) => {
                        let v = match json::parse(&frame) {
                            Ok(v) => v,
                            Err(_) => continue,
                        };
                        if v.get("kind").and_then(Json::as_str) != Some("hello") {
                            // A stale response from before the reconnect;
                            // the seq-matched pipeline will pick it up or
                            // re-request it. Keep waiting for the hello.
                            continue;
                        }
                        let sid = v
                            .get("session")
                            .and_then(Json::as_f64)
                            .map(|f| f as u64)
                            .ok_or_else(|| {
                                TransportError::Io("hello reply missing session".into())
                            })?;
                        let resumed =
                            v.get("resumed").and_then(Json::as_bool).unwrap_or(false);
                        if self.token.is_some() && !resumed {
                            // The server lost our session (lease expiry):
                            // previously applied-but-unacked work cannot be
                            // replayed without double-submitting, so
                            // resuming silently would break exactly-once.
                            return Err(TransportError::Closed);
                        }
                        self.session = Some(sid);
                        self.token = v
                            .get("token")
                            .and_then(Json::as_str)
                            .map(str::to_string)
                            .or_else(|| self.token.clone());
                        self.stats.handshakes += 1;
                        return Ok(());
                    }
                    Err(TransportError::Timeout) => {
                        self.stats.timeouts += 1;
                        break; // resend the hello
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        Err(TransportError::Timeout)
    }

    /// Send one request and wait for its response.
    pub fn request(&mut self, req: Request) -> Result<Response, TransportError> {
        self.pipeline(vec![req]).map(|mut v| v.remove(0))
    }

    /// Send a window of requests back to back, then collect responses by
    /// sequence number. Unanswered frames are re-sent on timeout; a
    /// disconnect triggers reconnect + resume + replay of everything
    /// still unanswered — the server's dedup makes the replay idempotent.
    /// Responses come back in request order.
    pub fn pipeline(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>, TransportError> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        self.ensure_connected()?;
        let first_seq = self.next_seq;
        let mut lines: BTreeMap<u64, String> = BTreeMap::new();
        for (i, req) in reqs.into_iter().enumerate() {
            let seq = first_seq + i as u64;
            lines.insert(seq, self.encode(req, seq));
        }
        let last_seq = self.next_seq + lines.len() as u64 - 1;
        self.next_seq = last_seq + 1;

        let mut results: BTreeMap<u64, Response> = BTreeMap::new();
        self.send_all(&lines, &results, true)?;
        let mut idle_waits = 0usize;
        while results.len() < lines.len() {
            let outcome = self.conn.as_mut().expect("connected above").recv();
            match outcome {
                Ok(frame) => {
                    if self.absorb(&frame, first_seq, last_seq, &mut results)? {
                        idle_waits = 0;
                    }
                }
                Err(TransportError::Timeout) => {
                    self.stats.timeouts += 1;
                    idle_waits += 1;
                    if idle_waits > self.backoff.max_attempts {
                        return Err(TransportError::Timeout);
                    }
                    self.send_all(&lines, &results, false)?;
                }
                Err(TransportError::Disconnected) => {
                    self.conn = None;
                    self.stats.reconnects += 1;
                    self.ensure_connected()?;
                    idle_waits = 0;
                    self.send_all(&lines, &results, false)?;
                }
                Err(e) => return Err(e),
            }
        }
        self.ack_cursor = Some(last_seq);
        Ok(results.into_values().collect())
    }

    /// Send every line not yet answered. `initial` marks the first pass
    /// (later passes count as retries).
    fn send_all(
        &mut self,
        lines: &BTreeMap<u64, String>,
        results: &BTreeMap<u64, Response>,
        initial: bool,
    ) -> Result<(), TransportError> {
        loop {
            self.ensure_connected()?;
            let mut failed = false;
            for (seq, line) in lines {
                if results.contains_key(seq) {
                    continue;
                }
                if !initial {
                    self.stats.retries += 1;
                }
                let conn = self.conn.as_mut().expect("connected above");
                match conn.send(line) {
                    Ok(()) => {}
                    Err(TransportError::Disconnected) => {
                        // Mid-window disconnect: reconnect + resume, then
                        // restart the pass for everything unanswered.
                        self.conn = None;
                        self.stats.reconnects += 1;
                        failed = true;
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            if !failed {
                return Ok(());
            }
        }
    }

    /// Fold one received frame into `results` if it belongs to the
    /// in-flight window. Returns whether progress was made.
    fn absorb(
        &mut self,
        frame: &str,
        first_seq: u64,
        last_seq: u64,
        results: &mut BTreeMap<u64, Response>,
    ) -> Result<bool, TransportError> {
        let v = match json::parse(frame) {
            Ok(v) => v,
            Err(_) => return Ok(false),
        };
        match v.get("kind").and_then(Json::as_str) {
            // Session-control frames are not pipeline responses.
            Some("hello") | Some("pong") | Some("bye") => return Ok(false),
            _ => {}
        }
        let Some(seq) = v.get("seq").and_then(Json::as_f64).map(|f| f as u64) else {
            // An unsequenced error aimed at this session (e.g. "unknown
            // session") is fatal for the window: replaying onto a fresh
            // session could double-apply, so surface it instead.
            if v.get("kind").and_then(Json::as_str) == Some("error")
                && v.get("session").is_some()
            {
                return Err(TransportError::Closed);
            }
            return Ok(false);
        };
        if seq < first_seq || seq > last_seq || results.contains_key(&seq) {
            // Stale duplicate from an earlier window (or a fault-dup);
            // already accounted for.
            return Ok(false);
        }
        let wire = WireResponse::from_json_line(frame)
            .map_err(|e| TransportError::Io(format!("bad response frame: {e}")))?;
        results.insert(seq, wire.resp);
        Ok(true)
    }

    fn encode(&self, req: Request, seq: u64) -> String {
        let sid = self.session.expect("encode called before handshake");
        let mut extras = vec![
            ("session", Json::num(sid as f64)),
            ("seq", Json::num(seq as f64)),
        ];
        if let Some(a) = self.ack_cursor {
            extras.push(("ack", Json::num(a as f64)));
        }
        WireRequest::new(req).to_json_line_with(&extras)
    }

    /// Best-effort clean close: final ack, then `bye`.
    pub fn bye(&mut self) {
        let Some(sid) = self.session else { return };
        let Some(conn) = self.conn.as_mut() else { return };
        let mut pairs = vec![
            ("op", Json::Str("bye".into())),
            ("session", Json::num(sid as f64)),
        ];
        if let Some(a) = self.ack_cursor {
            pairs.push(("ack", Json::num(a as f64)));
        }
        let _ = conn.send(&Json::obj(pairs).to_string());
        let _ = conn.recv();
        self.session = None;
        self.token = None;
        self.conn = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, ServiceConfig};
    use crate::coordinator::api::SubmitRequest;
    use crate::coordinator::session::{SessionConfig, SessionServer};
    use crate::coordinator::shard::{shard_regions, ShardedCoordinator};
    use crate::coordinator::transport::{FrameHandler, LoopbackTransport};
    use crate::experiments::cells::DispatchStrategy;
    use crate::faults::net::{LinkFaultSpec, LinkPlan};
    use crate::sched::PolicyKind;
    use std::sync::{Arc, Mutex};

    fn small_cluster() -> ShardedCoordinator {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 8;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let service = ServiceConfig::default();
        let regions = shard_regions("1", &cfg.region).unwrap();
        ShardedCoordinator::start(
            &cfg,
            &service,
            PolicyKind::CarbonAgnostic,
            &regions,
            DispatchStrategy::RoundRobin,
        )
    }

    fn loopback_client(plan: LinkPlan) -> (SessionClient, Arc<Mutex<SessionServer>>) {
        let server =
            Arc::new(Mutex::new(SessionServer::new(small_cluster(), SessionConfig::default())));
        let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
        let transport = LoopbackTransport::new(handler, plan);
        let client = SessionClient::new(Box::new(transport), "test-client", 7);
        (client, server)
    }

    fn sub(q: usize) -> Request {
        Request::Submit(SubmitRequest {
            workload: "N-body(N=100k)".to_string(),
            length_hours: 2.0,
            queue: q,
        })
    }

    #[test]
    fn clean_pipeline_roundtrip() {
        let (mut client, server) = loopback_client(LinkPlan::none());
        let resps = client.pipeline(vec![sub(0), sub(1), Request::Tick]).unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(resps[0], Response::Submitted { job_id: 0 });
        assert_eq!(resps[1], Response::Submitted { job_id: 1 });
        assert!(matches!(resps[2], Response::Ticked { .. }));
        let st = client.stats();
        assert_eq!(st.reconnects, 0);
        assert_eq!(st.retries, 0);
        assert_eq!(st.handshakes, 1);
        assert_eq!(server.lock().unwrap().counters().accepted, 2);
    }

    #[test]
    fn forced_reconnect_resumes_same_session() {
        let (mut client, server) = loopback_client(LinkPlan::none());
        client.pipeline(vec![sub(0)]).unwrap();
        let sid = client.session_id().unwrap();
        client.force_disconnect();
        let resps = client.pipeline(vec![sub(1)]).unwrap();
        assert_eq!(resps[0], Response::Submitted { job_id: 1 });
        assert_eq!(client.session_id(), Some(sid), "resume must keep the session");
        assert_eq!(client.stats().handshakes, 2);
        let c = server.lock().unwrap().counters();
        assert_eq!(c.resumes, 1);
        assert_eq!(c.accepted, 2);
    }

    #[test]
    fn faulty_link_preserves_exactly_once() {
        let plan = LinkPlan::generate(11, &LinkFaultSpec::heavy(), 64);
        assert!(!plan.is_empty());
        let (mut client, server) = loopback_client(plan);
        let mut accepted = 0u64;
        for i in 0..16usize {
            let resps = client.pipeline(vec![sub(i % 3)]).unwrap();
            if matches!(resps[0], Response::Submitted { .. }) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 16, "ample capacity: every submit admits exactly once");
        let c = server.lock().unwrap().counters();
        assert_eq!(c.accepted, 16, "server-side ledger agrees");
        let st = client.stats();
        assert!(
            st.retries + st.reconnects > 0,
            "a heavy plan must actually exercise the retry path: {st:?}"
        );
    }
}
