//! Coordinator wire protocol: versioned request/response envelopes and
//! their JSON line codec.
//!
//! The coordinator speaks a newline-delimited JSON protocol so external
//! clients (and the `serve` CLI subcommand) can submit jobs and poll status
//! without linking the library. The codec is built on `util::json` (no
//! serde offline).
//!
//! **Protocol v2** wraps every request in an envelope: the line carries
//! `"v"` (protocol version) and an optional client-chosen `"id"` string that
//! is echoed verbatim in the response, so pipelined clients can correlate
//! replies. Errors are structured: a machine-readable [`ErrorCode`] plus a
//! human message. Requests without a `"v"` key parse as **legacy v1** lines
//! (the pre-envelope protocol) and receive legacy-shaped responses; v1 is
//! deprecated and documented only for compatibility (see README).

use crate::util::json::{self, Json};

/// Current wire protocol version. Lines carrying `"v"` greater than this are
/// rejected with [`ErrorCode::BadRequest`].
pub const PROTOCOL_VERSION: u64 = 2;

/// Machine-readable error class carried by [`Response::Error`] and
/// [`SubmitOutcome::Rejected`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed line, unknown op, bad field, unsupported version.
    BadRequest,
    /// Workload name not in the hardware catalog.
    UnknownWorkload,
    /// Backpressure: the submission queue is at `max_pending` and the shed
    /// policy is reject-newest.
    QueueFull,
    /// Backpressure: shed by the reject-lowest-queue policy (only queue 0 is
    /// admitted over the bound).
    Shed,
    /// The coordinator has drained and no longer accepts requests.
    Draining,
}

impl ErrorCode {
    pub const ALL: [ErrorCode; 5] = [
        ErrorCode::BadRequest,
        ErrorCode::UnknownWorkload,
        ErrorCode::QueueFull,
        ErrorCode::Shed,
        ErrorCode::Draining,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownWorkload => "unknown_workload",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Shed => "shed",
            ErrorCode::Draining => "draining",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.into_iter().find(|c| c.as_str() == s)
    }
}

/// A job submission as it arrives over the API: the user picks a workload
/// from the catalog and a queue (paper §3: "users submit their batch jobs to
/// a specific queue according to their willingness to delay").
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    /// Catalog workload name (e.g. "ResNet18").
    pub workload: String,
    /// Base-scale length in hours.
    pub length_hours: f64,
    /// Queue index (0 = shortest slack).
    pub queue: usize,
}

/// Requests accepted by the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit(SubmitRequest),
    /// Batched ingest: one envelope, one admission decision round, many jobs.
    SubmitBatch(Vec<SubmitRequest>),
    /// Advance one slot (virtual time).
    Tick,
    /// Current cluster status.
    Status,
    /// Service counters and latency percentiles.
    Stats,
    /// Finish all work and return the final report.
    Drain,
}

/// Snapshot of cluster state.
#[derive(Debug, Clone, PartialEq)]
pub struct StatusResponse {
    pub slot: usize,
    pub active_jobs: usize,
    pub completed: usize,
    pub provisioned: usize,
    pub used: usize,
    pub carbon_g: f64,
    pub energy_kwh: f64,
}

/// Service-level counters and latency percentiles (the `stats` op).
#[derive(Debug, Clone, PartialEq)]
pub struct StatsResponse {
    pub slot: usize,
    /// Envelopes processed (including this stats request).
    pub requests: u64,
    /// Job submissions admitted into the engine.
    pub accepted: u64,
    /// Job submissions rejected by backpressure (queue_full + shed).
    pub shed: u64,
    /// `submit_batch` envelopes processed.
    pub batches: u64,
    /// Jobs currently waiting or running.
    pub pending: usize,
    /// Configured backpressure bound.
    pub max_pending: usize,
    /// Waiting + running jobs per queue.
    pub queue_depths: Vec<usize>,
    /// Median per-submission decision latency (milliseconds).
    pub p50_decision_ms: f64,
    /// Tail per-submission decision latency (milliseconds).
    pub p99_decision_ms: f64,
    /// Carbon emitted by completed jobs so far (grams).
    pub carbon_g: f64,
    /// Degradation ladder: slots decided on a stale last-known-good carbon
    /// forecast (see `crate::faults`; 0 when the signal never degraded).
    pub degraded_stale: u64,
    /// Degradation ladder: slots decided by the carbon-agnostic fallback.
    pub degraded_fallback: u64,
    /// Shard supervisor: shard kills detected and failed over (0 at the
    /// single-shard leader; populated by the sharded frontend).
    pub failovers: u64,
    /// Shard supervisor: checkpointed submissions re-routed to survivors.
    pub rerouted: u64,
    /// Shard supervisor: checkpointed submissions no survivor would admit.
    pub failover_shed: u64,
}

/// Per-member outcome inside a [`Response::Batch`].
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitOutcome {
    Accepted { job_id: usize },
    Rejected { code: ErrorCode, message: String },
}

/// Responses produced by the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Submitted { job_id: usize },
    /// One outcome per batch member, in member order.
    Batch { results: Vec<SubmitOutcome> },
    Ticked { slot: usize },
    Status(StatusResponse),
    Stats(StatsResponse),
    Drained { completed: usize, carbon_g: f64, mean_delay_hours: f64 },
    Error { code: ErrorCode, message: String },
}

/// A parse failure with enough recovered context to answer the client: the
/// error code/message plus the client `id` when the line was at least valid
/// JSON with an `"id"` field.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseFailure {
    pub code: ErrorCode,
    pub message: String,
    pub id: Option<String>,
}

impl ParseFailure {
    fn bad(message: impl Into<String>, id: Option<String>) -> ParseFailure {
        ParseFailure { code: ErrorCode::BadRequest, message: message.into(), id }
    }
}

/// A request envelope: protocol version, optional client correlation id, and
/// the operation.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub v: u64,
    pub id: Option<String>,
    pub req: Request,
}

/// A response envelope mirroring [`WireRequest`]: the version the client
/// spoke and its `id` echoed back.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    pub v: u64,
    pub id: Option<String>,
    pub resp: Response,
}

impl WireRequest {
    /// Envelope at the current protocol version, no correlation id.
    pub fn new(req: Request) -> WireRequest {
        WireRequest { v: PROTOCOL_VERSION, id: None, req }
    }

    pub fn with_id(req: Request, id: impl Into<String>) -> WireRequest {
        WireRequest { v: PROTOCOL_VERSION, id: Some(id.into()), req }
    }

    pub fn to_json_line(&self) -> String {
        self.to_json_line_with(&[])
    }

    /// Encode with extra top-level envelope keys (the session layer adds
    /// `session`/`seq`/`ack`). The v2 parser reads only known keys, so
    /// extras pass through older peers untouched. Extras force the v2
    /// encoding: the flat legacy shape has nowhere to carry them.
    pub fn to_json_line_with(&self, extra: &[(&str, Json)]) -> String {
        // Legacy v1 lines keep the pre-envelope shape (no "v"/"id"); ops
        // that postdate v1 fall through to the v2 encoding.
        if self.v <= 1 && extra.is_empty() {
            match &self.req {
                Request::Submit(_) | Request::Tick | Request::Status | Request::Drain => {
                    return legacy_request_json(&self.req).to_string();
                }
                Request::SubmitBatch(_) | Request::Stats => {}
            }
        }
        let mut pairs: Vec<(&str, Json)> = vec![("v", Json::Num(self.v.max(2) as f64))];
        if let Some(id) = &self.id {
            pairs.push(("id", Json::Str(id.clone())));
        }
        match &self.req {
            Request::Submit(s) => {
                pairs.push(("op", Json::Str("submit".into())));
                pairs.extend(submit_fields(s));
            }
            Request::SubmitBatch(jobs) => {
                pairs.push(("op", Json::Str("submit_batch".into())));
                let arr = jobs.iter().map(|s| Json::obj(submit_fields(s))).collect();
                pairs.push(("jobs", Json::Arr(arr)));
            }
            Request::Tick => pairs.push(("op", Json::Str("tick".into()))),
            Request::Status => pairs.push(("op", Json::Str("status".into()))),
            Request::Stats => pairs.push(("op", Json::Str("stats".into()))),
            Request::Drain => pairs.push(("op", Json::Str("drain".into()))),
        }
        for (k, val) in extra {
            pairs.push((k, val.clone()));
        }
        Json::obj(pairs).to_string()
    }

    /// Parse a request line, accepting both the v2 envelope and legacy v1
    /// lines (no `"v"` key). On failure the client `id` is recovered when
    /// possible so the caller can still address its error response.
    pub fn from_json_line(line: &str) -> Result<WireRequest, ParseFailure> {
        let v = json::parse(line.trim())
            .map_err(|e| ParseFailure::bad(format!("invalid json: {e}"), None))?;
        let id = v.get("id").and_then(Json::as_str).map(str::to_string);
        let version = match v.get("v") {
            None => 1,
            Some(n) => match n.as_f64() {
                Some(f) if f >= 1.0 && f.fract() == 0.0 => f as u64,
                _ => return Err(ParseFailure::bad("'v' must be a positive integer", id)),
            },
        };
        if version > PROTOCOL_VERSION {
            return Err(ParseFailure::bad(
                format!("unsupported protocol version {version} (max {PROTOCOL_VERSION})"),
                id,
            ));
        }
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| ParseFailure::bad("missing 'op'", id.clone()))?;
        let req = match op {
            "submit" => Request::Submit(
                parse_submit(&v).map_err(|m| ParseFailure::bad(m, id.clone()))?,
            ),
            "submit_batch" => {
                let arr = v
                    .get("jobs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ParseFailure::bad("missing 'jobs' array", id.clone()))?;
                let jobs: Result<Vec<SubmitRequest>, String> =
                    arr.iter().map(parse_submit).collect();
                Request::SubmitBatch(jobs.map_err(|m| ParseFailure::bad(m, id.clone()))?)
            }
            "tick" => Request::Tick,
            "status" => Request::Status,
            "stats" => Request::Stats,
            "drain" => Request::Drain,
            other => return Err(ParseFailure::bad(format!("unknown op '{other}'"), id)),
        };
        Ok(WireRequest { v: version, id, req })
    }
}

impl WireResponse {
    pub fn to_json_line(&self) -> String {
        self.to_json_line_with(&[])
    }

    /// Encode with extra top-level envelope keys (see
    /// [`WireRequest::to_json_line_with`]); extras force the v2 shape.
    pub fn to_json_line_with(&self, extra: &[(&str, Json)]) -> String {
        // Legacy-shaped emission for v1 clients; ops without a v1 shape
        // (batch, stats) fall through to the v2 encoding.
        if self.v <= 1 && extra.is_empty() {
            match &self.resp {
                Response::Batch { .. } | Response::Stats(_) => {}
                other => return legacy_response_json(other).to_string(),
            }
        }
        let ok = !matches!(self.resp, Response::Error { .. });
        let mut pairs: Vec<(&str, Json)> =
            vec![("v", Json::Num(self.v.max(2) as f64)), ("ok", Json::Bool(ok))];
        if let Some(id) = &self.id {
            pairs.push(("id", Json::Str(id.clone())));
        }
        match &self.resp {
            Response::Submitted { job_id } => {
                pairs.push(("kind", Json::Str("submitted".into())));
                pairs.push(("job_id", Json::Num(*job_id as f64)));
            }
            Response::Batch { results } => {
                pairs.push(("kind", Json::Str("batch".into())));
                let arr = results
                    .iter()
                    .map(|r| match r {
                        SubmitOutcome::Accepted { job_id } => {
                            Json::obj(vec![("job_id", Json::Num(*job_id as f64))])
                        }
                        SubmitOutcome::Rejected { code, message } => Json::obj(vec![
                            ("code", Json::Str(code.as_str().into())),
                            ("error", Json::Str(message.clone())),
                        ]),
                    })
                    .collect();
                pairs.push(("results", Json::Arr(arr)));
            }
            Response::Ticked { slot } => {
                pairs.push(("kind", Json::Str("ticked".into())));
                pairs.push(("slot", Json::Num(*slot as f64)));
            }
            Response::Status(s) => {
                pairs.push(("kind", Json::Str("status".into())));
                pairs.push(("slot", Json::Num(s.slot as f64)));
                pairs.push(("active_jobs", Json::Num(s.active_jobs as f64)));
                pairs.push(("completed", Json::Num(s.completed as f64)));
                pairs.push(("provisioned", Json::Num(s.provisioned as f64)));
                pairs.push(("used", Json::Num(s.used as f64)));
                pairs.push(("carbon_g", Json::Num(s.carbon_g)));
                pairs.push(("energy_kwh", Json::Num(s.energy_kwh)));
            }
            Response::Stats(s) => {
                pairs.push(("kind", Json::Str("stats".into())));
                pairs.push(("slot", Json::Num(s.slot as f64)));
                pairs.push(("requests", Json::Num(s.requests as f64)));
                pairs.push(("accepted", Json::Num(s.accepted as f64)));
                pairs.push(("shed", Json::Num(s.shed as f64)));
                pairs.push(("batches", Json::Num(s.batches as f64)));
                pairs.push(("pending", Json::Num(s.pending as f64)));
                pairs.push(("max_pending", Json::Num(s.max_pending as f64)));
                let depths = s.queue_depths.iter().map(|&d| Json::Num(d as f64)).collect();
                pairs.push(("queue_depths", Json::Arr(depths)));
                pairs.push(("p50_decision_ms", Json::Num(s.p50_decision_ms)));
                pairs.push(("p99_decision_ms", Json::Num(s.p99_decision_ms)));
                pairs.push(("carbon_g", Json::Num(s.carbon_g)));
                pairs.push(("degraded_stale", Json::Num(s.degraded_stale as f64)));
                pairs.push(("degraded_fallback", Json::Num(s.degraded_fallback as f64)));
                pairs.push(("failovers", Json::Num(s.failovers as f64)));
                pairs.push(("rerouted", Json::Num(s.rerouted as f64)));
                pairs.push(("failover_shed", Json::Num(s.failover_shed as f64)));
            }
            Response::Drained { completed, carbon_g, mean_delay_hours } => {
                pairs.push(("kind", Json::Str("drained".into())));
                pairs.push(("completed", Json::Num(*completed as f64)));
                pairs.push(("carbon_g", Json::Num(*carbon_g)));
                pairs.push(("mean_delay_hours", Json::Num(*mean_delay_hours)));
            }
            Response::Error { code, message } => {
                pairs.push(("kind", Json::Str("error".into())));
                pairs.push(("code", Json::Str(code.as_str().into())));
                pairs.push(("error", Json::Str(message.clone())));
            }
        }
        for (k, val) in extra {
            pairs.push((k, val.clone()));
        }
        Json::obj(pairs).to_string()
    }

    pub fn from_json_line(line: &str) -> Result<WireResponse, String> {
        let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
        let id = v.get("id").and_then(Json::as_str).map(str::to_string);
        match v.get("v").and_then(Json::as_usize) {
            Some(version) => {
                let resp = parse_v2_response(&v)?;
                Ok(WireResponse { v: version as u64, id, resp })
            }
            None => Ok(WireResponse { v: 1, id, resp: parse_legacy_response(&v)? }),
        }
    }
}

fn submit_fields(s: &SubmitRequest) -> Vec<(&'static str, Json)> {
    vec![
        ("workload", Json::Str(s.workload.clone())),
        ("length_hours", Json::Num(s.length_hours)),
        ("queue", Json::Num(s.queue as f64)),
    ]
}

fn parse_submit(v: &Json) -> Result<SubmitRequest, String> {
    Ok(SubmitRequest {
        workload: v
            .get("workload")
            .and_then(Json::as_str)
            .ok_or("missing 'workload'")?
            .to_string(),
        length_hours: v
            .get("length_hours")
            .and_then(Json::as_f64)
            .ok_or("missing 'length_hours'")?,
        queue: v.get("queue").and_then(Json::as_usize).unwrap_or(0),
    })
}

/// Pre-envelope (v1) request shape.
fn legacy_request_json(req: &Request) -> Json {
    match req {
        Request::Submit(s) => {
            let mut pairs = vec![("op", Json::Str("submit".into()))];
            pairs.extend(submit_fields(s));
            Json::obj(pairs)
        }
        Request::Tick => Json::obj(vec![("op", Json::Str("tick".into()))]),
        Request::Status => Json::obj(vec![("op", Json::Str("status".into()))]),
        Request::Drain => Json::obj(vec![("op", Json::Str("drain".into()))]),
        // No v1 shape exists for these; callers route them to v2.
        Request::SubmitBatch(_) | Request::Stats => unreachable!("no legacy shape"),
    }
}

/// Pre-envelope (v1) response shape. Errors additionally carry the v2
/// `"code"` key, which v1 clients ignore.
fn legacy_response_json(resp: &Response) -> Json {
    match resp {
        Response::Submitted { job_id } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("job_id", Json::Num(*job_id as f64)),
        ]),
        Response::Ticked { slot } => {
            Json::obj(vec![("ok", Json::Bool(true)), ("slot", Json::Num(*slot as f64))])
        }
        Response::Status(s) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("slot", Json::Num(s.slot as f64)),
            ("active_jobs", Json::Num(s.active_jobs as f64)),
            ("completed", Json::Num(s.completed as f64)),
            ("provisioned", Json::Num(s.provisioned as f64)),
            ("used", Json::Num(s.used as f64)),
            ("carbon_g", Json::Num(s.carbon_g)),
            ("energy_kwh", Json::Num(s.energy_kwh)),
        ]),
        Response::Drained { completed, carbon_g, mean_delay_hours } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("completed", Json::Num(*completed as f64)),
            ("carbon_g", Json::Num(*carbon_g)),
            ("mean_delay_hours", Json::Num(*mean_delay_hours)),
        ]),
        Response::Error { code, message } => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("code", Json::Str(code.as_str().into())),
            ("error", Json::Str(message.clone())),
        ]),
        Response::Batch { .. } | Response::Stats(_) => unreachable!("no legacy shape"),
    }
}

/// Checked decode of a `u64` counter field: absent keys read as 0 (additive
/// fields stay wire-compatible with older peers), but a present value must
/// be a nonnegative integer representable losslessly in the f64-carried JSON
/// number (≤ 2^53) — a lossy `as u64` cast would silently wrap negative
/// values and truncate fractions.
fn counter_field(v: &Json, key: &str) -> Result<u64, String> {
    match v.get(key) {
        None => Ok(0),
        Some(n) => n
            .as_f64()
            .filter(|f| {
                f.is_finite() && *f >= 0.0 && f.fract() == 0.0 && *f <= 9_007_199_254_740_992.0
            })
            .map(|f| f as u64)
            .ok_or_else(|| format!("'{key}' must be a nonnegative integer counter")),
    }
}

fn parse_v2_response(v: &Json) -> Result<Response, String> {
    let kind = v.get("kind").and_then(Json::as_str).ok_or("missing 'kind'")?;
    match kind {
        "submitted" => Ok(Response::Submitted {
            job_id: v.get("job_id").and_then(Json::as_usize).ok_or("missing 'job_id'")?,
        }),
        "batch" => {
            let arr = v.get("results").and_then(Json::as_arr).ok_or("missing 'results'")?;
            let results: Result<Vec<SubmitOutcome>, String> = arr
                .iter()
                .map(|r| {
                    if let Some(job_id) = r.get("job_id").and_then(Json::as_usize) {
                        Ok(SubmitOutcome::Accepted { job_id })
                    } else {
                        let code = r
                            .get("code")
                            .and_then(Json::as_str)
                            .and_then(ErrorCode::parse)
                            .ok_or("batch member missing 'job_id' or 'code'")?;
                        let message =
                            r.get("error").and_then(Json::as_str).unwrap_or("").to_string();
                        Ok(SubmitOutcome::Rejected { code, message })
                    }
                })
                .collect();
            Ok(Response::Batch { results: results? })
        }
        "ticked" => Ok(Response::Ticked {
            slot: v.get("slot").and_then(Json::as_usize).ok_or("missing 'slot'")?,
        }),
        "status" => Ok(Response::Status(parse_status_fields(v))),
        "stats" => Ok(Response::Stats(StatsResponse {
            slot: v.get("slot").and_then(Json::as_usize).unwrap_or(0),
            requests: counter_field(v, "requests")?,
            accepted: counter_field(v, "accepted")?,
            shed: counter_field(v, "shed")?,
            batches: counter_field(v, "batches")?,
            pending: v.get("pending").and_then(Json::as_usize).unwrap_or(0),
            max_pending: v.get("max_pending").and_then(Json::as_usize).unwrap_or(0),
            queue_depths: v
                .get("queue_depths")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            p50_decision_ms: v.get("p50_decision_ms").and_then(Json::as_f64).unwrap_or(0.0),
            p99_decision_ms: v.get("p99_decision_ms").and_then(Json::as_f64).unwrap_or(0.0),
            carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
            degraded_stale: counter_field(v, "degraded_stale")?,
            degraded_fallback: counter_field(v, "degraded_fallback")?,
            failovers: counter_field(v, "failovers")?,
            rerouted: counter_field(v, "rerouted")?,
            failover_shed: counter_field(v, "failover_shed")?,
        })),
        "drained" => Ok(Response::Drained {
            completed: v.get("completed").and_then(Json::as_usize).unwrap_or(0),
            carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
            mean_delay_hours: v.get("mean_delay_hours").and_then(Json::as_f64).unwrap_or(0.0),
        }),
        "error" => Ok(Response::Error {
            code: v
                .get("code")
                .and_then(Json::as_str)
                .and_then(ErrorCode::parse)
                .unwrap_or(ErrorCode::BadRequest),
            message: v.get("error").and_then(Json::as_str).unwrap_or("?").to_string(),
        }),
        other => Err(format!("unknown response kind '{other}'")),
    }
}

fn parse_status_fields(v: &Json) -> StatusResponse {
    StatusResponse {
        slot: v.get("slot").and_then(Json::as_usize).unwrap_or(0),
        active_jobs: v.get("active_jobs").and_then(Json::as_usize).unwrap_or(0),
        completed: v.get("completed").and_then(Json::as_usize).unwrap_or(0),
        provisioned: v.get("provisioned").and_then(Json::as_usize).unwrap_or(0),
        used: v.get("used").and_then(Json::as_usize).unwrap_or(0),
        carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
        energy_kwh: v.get("energy_kwh").and_then(Json::as_f64).unwrap_or(0.0),
    }
}

/// Legacy (v1) response recognition: shape heuristics over the flat keys.
fn parse_legacy_response(v: &Json) -> Result<Response, String> {
    let ok = v.get("ok").and_then(Json::as_bool).ok_or("missing 'ok'")?;
    if !ok {
        return Ok(Response::Error {
            code: v
                .get("code")
                .and_then(Json::as_str)
                .and_then(ErrorCode::parse)
                .unwrap_or(ErrorCode::BadRequest),
            message: v.get("error").and_then(Json::as_str).unwrap_or("?").to_string(),
        });
    }
    if let Some(id) = v.get("job_id").and_then(Json::as_usize) {
        return Ok(Response::Submitted { job_id: id });
    }
    if v.get("active_jobs").is_some() {
        return Ok(Response::Status(parse_status_fields(v)));
    }
    if v.get("mean_delay_hours").is_some() {
        return Ok(Response::Drained {
            completed: v.get("completed").and_then(Json::as_usize).unwrap_or(0),
            carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
            mean_delay_hours: v.get("mean_delay_hours").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    if let Some(slot) = v.get("slot").and_then(Json::as_usize) {
        return Ok(Response::Ticked { slot });
    }
    Err("unrecognized response".into())
}

impl Request {
    /// Legacy (v1) encoding shim; prefer [`WireRequest::to_json_line`].
    pub fn to_json_line(&self) -> String {
        WireRequest { v: 1, id: None, req: self.clone() }.to_json_line()
    }

    /// Version-agnostic parse shim; accepts v1 and v2 lines.
    pub fn from_json_line(line: &str) -> Result<Request, String> {
        WireRequest::from_json_line(line).map(|w| w.req).map_err(|p| p.message)
    }
}

impl Response {
    /// Legacy (v1) encoding shim; prefer [`WireResponse::to_json_line`].
    pub fn to_json_line(&self) -> String {
        WireResponse { v: 1, id: None, resp: self.clone() }.to_json_line()
    }

    /// Version-agnostic parse shim; accepts v1 and v2 lines.
    pub fn from_json_line(line: &str) -> Result<Response, String> {
        WireResponse::from_json_line(line).map(|w| w.resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::Submit(SubmitRequest {
                workload: "ResNet18".into(),
                length_hours: 4.5,
                queue: 1,
            }),
            Request::Tick,
            Request::Status,
            Request::Drain,
        ];
        for r in reqs {
            let line = r.to_json_line();
            assert_eq!(Request::from_json_line(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Submitted { job_id: 42 },
            Response::Ticked { slot: 7 },
            Response::Status(StatusResponse {
                slot: 3,
                active_jobs: 5,
                completed: 2,
                provisioned: 100,
                used: 80,
                carbon_g: 123.5,
                energy_kwh: 4.25,
            }),
            Response::Drained { completed: 10, carbon_g: 500.0, mean_delay_hours: 2.5 },
            Response::Error { code: ErrorCode::BadRequest, message: "nope".into() },
        ];
        for r in resps {
            let line = r.to_json_line();
            assert_eq!(Response::from_json_line(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn envelope_roundtrip_with_id() {
        let w = WireRequest::with_id(
            Request::SubmitBatch(vec![
                SubmitRequest { workload: "A".into(), length_hours: 1.0, queue: 0 },
                SubmitRequest { workload: "B".into(), length_hours: 2.5, queue: 2 },
            ]),
            "req-17",
        );
        let line = w.to_json_line();
        assert_eq!(WireRequest::from_json_line(&line).unwrap(), w, "{line}");

        let r = WireResponse {
            v: PROTOCOL_VERSION,
            id: Some("req-17".into()),
            resp: Response::Batch {
                results: vec![
                    SubmitOutcome::Accepted { job_id: 0 },
                    SubmitOutcome::Rejected {
                        code: ErrorCode::QueueFull,
                        message: "queue full".into(),
                    },
                ],
            },
        };
        let line = r.to_json_line();
        assert_eq!(WireResponse::from_json_line(&line).unwrap(), r, "{line}");
    }

    #[test]
    fn stats_roundtrip_with_fault_counters() {
        let r = WireResponse {
            v: PROTOCOL_VERSION,
            id: None,
            resp: Response::Stats(StatsResponse {
                slot: 9,
                requests: 1_234_567_890_123,
                accepted: 42,
                shed: 3,
                batches: 7,
                pending: 5,
                max_pending: 4096,
                queue_depths: vec![2, 2, 1],
                p50_decision_ms: 0.25,
                p99_decision_ms: 1.5,
                carbon_g: 10.0,
                degraded_stale: 4,
                degraded_fallback: 2,
                failovers: 1,
                rerouted: 6,
                failover_shed: 1,
            }),
        };
        let line = r.to_json_line();
        assert_eq!(WireResponse::from_json_line(&line).unwrap(), r, "{line}");
        // Absent additive fields decode as 0 (wire back-compat).
        let old = r#"{"v": 2, "ok": true, "kind": "stats", "slot": 1, "requests": 3}"#;
        match WireResponse::from_json_line(old).unwrap().resp {
            Response::Stats(s) => {
                assert_eq!(s.requests, 3);
                assert_eq!(s.degraded_stale, 0);
                assert_eq!(s.failovers, 0);
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn counter_decode_rejects_lossy_values() {
        // A lossy `as u64` cast would wrap -3 to a huge counter and truncate
        // 1.5 to 1; the checked path refuses both instead.
        for bad in ["-3", "1.5", "1e300", "\"many\""] {
            let line =
                format!(r#"{{"v": 2, "ok": true, "kind": "stats", "slot": 0, "shed": {bad}}}"#);
            let err = WireResponse::from_json_line(&line).unwrap_err();
            assert!(err.contains("shed"), "{bad}: {err}");
        }
        // Boundary: 2^53 is the largest losslessly-representable counter.
        let ok = r#"{"v": 2, "ok": true, "kind": "stats", "slot": 0, "shed": 9007199254740992}"#;
        match WireResponse::from_json_line(ok).unwrap().resp {
            Response::Stats(s) => assert_eq!(s.shed, 9_007_199_254_740_992),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn extra_envelope_keys_pass_through_the_parser() {
        let w = WireRequest::with_id(Request::Tick, "t-1");
        let line = w.to_json_line_with(&[
            ("session", Json::Num(3.0)),
            ("seq", Json::Num(17.0)),
            ("ack", Json::Num(16.0)),
        ]);
        assert!(line.contains("\"seq\""), "{line}");
        // The core parser reads only known keys: the envelope still
        // decodes, extras are invisible to session-unaware peers.
        assert_eq!(WireRequest::from_json_line(&line).unwrap(), w, "{line}");
        let r = WireResponse {
            v: PROTOCOL_VERSION,
            id: Some("t-1".into()),
            resp: Response::Ticked { slot: 4 },
        };
        let rline = r.to_json_line_with(&[("seq", Json::Num(17.0))]);
        assert_eq!(WireResponse::from_json_line(&rline).unwrap(), r, "{rline}");
        // Extras force v2 even for ops with a legacy shape.
        let legacy = WireRequest { v: 1, id: None, req: Request::Tick };
        assert!(legacy.to_json_line_with(&[("seq", Json::Num(0.0))]).contains("\"v\""));
    }

    #[test]
    fn legacy_lines_parse_as_v1() {
        let w = WireRequest::from_json_line(r#"{"op": "tick"}"#).unwrap();
        assert_eq!(w.v, 1);
        assert_eq!(w.req, Request::Tick);
        let r = WireResponse::from_json_line(r#"{"ok": true, "slot": 3}"#).unwrap();
        assert_eq!(r.v, 1);
        assert_eq!(r.resp, Response::Ticked { slot: 3 });
    }

    #[test]
    fn future_version_rejected() {
        let err = WireRequest::from_json_line(r#"{"v": 99, "id": "x", "op": "tick"}"#)
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert_eq!(err.id.as_deref(), Some("x"));
        assert!(err.message.contains("unsupported protocol version"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::from_json_line("{}").is_err());
        assert!(Request::from_json_line("not json").is_err());
        assert!(Request::from_json_line(r#"{"op": "fly"}"#).is_err());
    }

    #[test]
    fn error_code_roundtrip() {
        for c in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(c.as_str()), Some(c));
        }
        assert_eq!(ErrorCode::parse("teapot"), None);
    }
}
