//! Coordinator request/response types and their JSON line codec.
//!
//! The coordinator speaks a newline-delimited JSON protocol so external
//! clients (and the `serve` CLI subcommand) can submit jobs and poll status
//! without linking the library. The codec is built on `util::json` (no
//! serde offline).

use crate::util::json::{self, Json};

/// A job submission as it arrives over the API: the user picks a workload
/// from the catalog and a queue (paper §3: "users submit their batch jobs to
/// a specific queue according to their willingness to delay").
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    /// Catalog workload name (e.g. "ResNet18").
    pub workload: String,
    /// Base-scale length in hours.
    pub length_hours: f64,
    /// Queue index (0 = shortest slack).
    pub queue: usize,
}

/// Requests accepted by the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit(SubmitRequest),
    /// Advance one slot (virtual time).
    Tick,
    /// Current cluster status.
    Status,
    /// Finish all work and return the final report.
    Drain,
}

/// Responses produced by the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub struct StatusResponse {
    pub slot: usize,
    pub active_jobs: usize,
    pub completed: usize,
    pub provisioned: usize,
    pub used: usize,
    pub carbon_g: f64,
    pub energy_kwh: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Submitted { job_id: usize },
    Ticked { slot: usize },
    Status(StatusResponse),
    Drained { completed: usize, carbon_g: f64, mean_delay_hours: f64 },
    Error { message: String },
}

impl Request {
    pub fn to_json_line(&self) -> String {
        let v = match self {
            Request::Submit(s) => Json::obj(vec![
                ("op", Json::Str("submit".into())),
                ("workload", Json::Str(s.workload.clone())),
                ("length_hours", Json::Num(s.length_hours)),
                ("queue", Json::Num(s.queue as f64)),
            ]),
            Request::Tick => Json::obj(vec![("op", Json::Str("tick".into()))]),
            Request::Status => Json::obj(vec![("op", Json::Str("status".into()))]),
            Request::Drain => Json::obj(vec![("op", Json::Str("drain".into()))]),
        };
        v.to_string()
    }

    pub fn from_json_line(line: &str) -> Result<Request, String> {
        let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
        let op = v.get("op").and_then(Json::as_str).ok_or("missing 'op'")?;
        match op {
            "submit" => Ok(Request::Submit(SubmitRequest {
                workload: v
                    .get("workload")
                    .and_then(Json::as_str)
                    .ok_or("missing 'workload'")?
                    .to_string(),
                length_hours: v
                    .get("length_hours")
                    .and_then(Json::as_f64)
                    .ok_or("missing 'length_hours'")?,
                queue: v.get("queue").and_then(Json::as_usize).unwrap_or(0),
            })),
            "tick" => Ok(Request::Tick),
            "status" => Ok(Request::Status),
            "drain" => Ok(Request::Drain),
            other => Err(format!("unknown op '{other}'")),
        }
    }
}

impl Response {
    pub fn to_json_line(&self) -> String {
        let v = match self {
            Response::Submitted { job_id } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("job_id", Json::Num(*job_id as f64)),
            ]),
            Response::Ticked { slot } => {
                Json::obj(vec![("ok", Json::Bool(true)), ("slot", Json::Num(*slot as f64))])
            }
            Response::Status(s) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("slot", Json::Num(s.slot as f64)),
                ("active_jobs", Json::Num(s.active_jobs as f64)),
                ("completed", Json::Num(s.completed as f64)),
                ("provisioned", Json::Num(s.provisioned as f64)),
                ("used", Json::Num(s.used as f64)),
                ("carbon_g", Json::Num(s.carbon_g)),
                ("energy_kwh", Json::Num(s.energy_kwh)),
            ]),
            Response::Drained { completed, carbon_g, mean_delay_hours } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("completed", Json::Num(*completed as f64)),
                ("carbon_g", Json::Num(*carbon_g)),
                ("mean_delay_hours", Json::Num(*mean_delay_hours)),
            ]),
            Response::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(message.clone())),
            ]),
        };
        v.to_string()
    }

    pub fn from_json_line(line: &str) -> Result<Response, String> {
        let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
        let ok = v.get("ok").and_then(Json::as_bool).ok_or("missing 'ok'")?;
        if !ok {
            return Ok(Response::Error {
                message: v.get("error").and_then(Json::as_str).unwrap_or("?").to_string(),
            });
        }
        if let Some(id) = v.get("job_id").and_then(Json::as_usize) {
            return Ok(Response::Submitted { job_id: id });
        }
        if v.get("active_jobs").is_some() {
            return Ok(Response::Status(StatusResponse {
                slot: v.get("slot").and_then(Json::as_usize).unwrap_or(0),
                active_jobs: v.get("active_jobs").and_then(Json::as_usize).unwrap_or(0),
                completed: v.get("completed").and_then(Json::as_usize).unwrap_or(0),
                provisioned: v.get("provisioned").and_then(Json::as_usize).unwrap_or(0),
                used: v.get("used").and_then(Json::as_usize).unwrap_or(0),
                carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
                energy_kwh: v.get("energy_kwh").and_then(Json::as_f64).unwrap_or(0.0),
            }));
        }
        if v.get("mean_delay_hours").is_some() {
            return Ok(Response::Drained {
                completed: v.get("completed").and_then(Json::as_usize).unwrap_or(0),
                carbon_g: v.get("carbon_g").and_then(Json::as_f64).unwrap_or(0.0),
                mean_delay_hours: v.get("mean_delay_hours").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        if let Some(slot) = v.get("slot").and_then(Json::as_usize) {
            return Ok(Response::Ticked { slot });
        }
        Err("unrecognized response".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::Submit(SubmitRequest {
                workload: "ResNet18".into(),
                length_hours: 4.5,
                queue: 1,
            }),
            Request::Tick,
            Request::Status,
            Request::Drain,
        ];
        for r in reqs {
            let line = r.to_json_line();
            assert_eq!(Request::from_json_line(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Submitted { job_id: 42 },
            Response::Ticked { slot: 7 },
            Response::Status(StatusResponse {
                slot: 3,
                active_jobs: 5,
                completed: 2,
                provisioned: 100,
                used: 80,
                carbon_g: 123.5,
                energy_kwh: 4.25,
            }),
            Response::Drained { completed: 10, carbon_g: 500.0, mean_delay_hours: 2.5 },
            Response::Error { message: "nope".into() },
        ];
        for r in resps {
            let line = r.to_json_line();
            assert_eq!(Response::from_json_line(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::from_json_line("{}").is_err());
        assert!(Request::from_json_line("not json").is_err());
        assert!(Request::from_json_line(r#"{"op": "fly"}"#).is_err());
    }
}
