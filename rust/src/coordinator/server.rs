//! The coordinator leader: a long-running service that owns the cluster
//! engine and a scheduling policy, accepts job submissions over a channel,
//! and advances slots in virtual time.
//!
//! This is the deployment shape of the paper's prototype (§5): AWS
//! ParallelCluster + PySlurm replaced by our in-process cluster engine, with
//! the same separation — the policy decides, the engine actuates. The
//! leader runs on a dedicated thread (no tokio offline); clients hold a
//! cheap [`ClusterHandle`] of mpsc senders.
//!
//! Traffic-serving additions: batched ingest ([`Request::SubmitBatch`] —
//! one envelope, one backpressure consultation, many jobs), a bounded
//! submission queue with an explicit [`ShedPolicy`], and a [`Request::Stats`]
//! endpoint exposing counters plus p50/p99 decision-latency percentiles from
//! an O(1) [`LatencyHistogram`]. Admission is strictly per-member in arrival
//! order for both single and batched submits, so a drain report is bitwise
//! identical whichever ingest shape delivered the same job stream.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::carbon::forecast::Forecaster;
use crate::cluster::metrics::RunMetrics;
use crate::cluster::sim::{ClusterEngine, Simulator};
use crate::config::{ExperimentConfig, Hardware, ServiceConfig, ShedPolicy};
use crate::coordinator::api::{
    ErrorCode, Request, Response, StatsResponse, StatusResponse, SubmitOutcome, SubmitRequest,
};
use crate::sched::Policy;
use crate::util::stats::LatencyHistogram;
use crate::workload::job::Job;
use crate::workload::profile;

/// Message to the leader thread.
enum Envelope {
    /// A wire request + its reply channel.
    Api { req: Request, reply: mpsc::Sender<Response> },
    /// Out-of-band fetch of the leader's decision-latency histogram, used
    /// by the sharded frontend to merge fleet percentiles bucket-wise. Not
    /// a service request: it does not count toward the `requests` stat.
    Latency { reply: mpsc::Sender<LatencyHistogram> },
    /// Fault injection: stop immediately without draining — the shard
    /// supervisor's simulated crash (see `crate::faults`). Pending jobs stay
    /// in the write-ahead checkpoint for failover.
    Kill,
}

/// Write-ahead record of a coordinator's externally visible submission
/// state, kept exactly in step with the leader (the leader appends within
/// the same request handling that admits or completes a job). On a shard
/// kill the supervisor replays [`CheckpointState::pending`] onto surviving
/// shards, and a restarted shard rejoins empty but deterministic.
#[derive(Debug, Clone, Default)]
pub struct CheckpointState {
    /// Every admitted submission, in admission order: (job id, request).
    pub accepted: Vec<(usize, SubmitRequest)>,
    /// Job ids whose outcomes the leader has observed.
    pub completed: Vec<usize>,
    /// Fully-acknowledged entries dropped by [`CheckpointState::compact`]
    /// — each was present in both `accepted` and `completed` before the
    /// drop, so totals stay reconstructible for accounting.
    pub compacted: u64,
}

/// Completed-entry count past which the leader compacts the checkpoint
/// inline. High enough that short-lived tests and small failovers see
/// the full uncompacted log, low enough that a long session's replay
/// buffer stays bounded by pending + threshold instead of growing with
/// total throughput.
pub const CHECKPOINT_COMPACT_THRESHOLD: usize = 256;

impl CheckpointState {
    /// Submissions admitted but not yet completed, in admission order —
    /// exactly the jobs a failover must re-route.
    pub fn pending(&self) -> Vec<SubmitRequest> {
        let done: std::collections::BTreeSet<usize> = self.completed.iter().copied().collect();
        self.accepted
            .iter()
            .filter(|(id, _)| !done.contains(id))
            .map(|(_, s)| s.clone())
            .collect()
    }

    /// Lifetime admissions, including compacted-away entries.
    pub fn accepted_total(&self) -> u64 {
        self.compacted + self.accepted.len() as u64
    }

    /// Lifetime completions, including compacted-away entries.
    pub fn completed_total(&self) -> u64 {
        self.compacted + self.completed.len() as u64
    }

    /// Drop fully-acknowledged entries: every (id, request) pair that is
    /// both accepted and completed leaves both lists and bumps
    /// `compacted`. [`CheckpointState::pending`] is unchanged — only
    /// entries a failover would never re-route are removed — so long
    /// sessions keep a bounded write-ahead log instead of one that grows
    /// with total throughput.
    pub fn compact(&mut self) {
        let done: std::collections::BTreeSet<usize> = self.completed.iter().copied().collect();
        let matched: std::collections::BTreeSet<usize> = self
            .accepted
            .iter()
            .map(|(id, _)| *id)
            .filter(|id| done.contains(id))
            .collect();
        if matched.is_empty() {
            return;
        }
        self.accepted.retain(|(id, _)| !matched.contains(id));
        self.completed.retain(|id| !matched.contains(id));
        self.compacted += matched.len() as u64;
    }
}

/// Failure of an out-of-band control fetch (e.g. the latency-histogram
/// snapshot): distinguishes a leader that is gone from one that is alive
/// but not answering, instead of blocking the caller forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlError {
    /// The leader thread has stopped (drained, killed, or crashed).
    Stopped,
    /// The leader did not answer within [`CONTROL_RECV_TIMEOUT`] — it is
    /// wedged or mid-drain; treat the shard as unresponsive.
    Unresponsive,
}

impl std::fmt::Display for ControlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlError::Stopped => write!(f, "coordinator stopped"),
            ControlError::Unresponsive => write!(f, "coordinator unresponsive"),
        }
    }
}

/// How long an out-of-band control fetch waits before declaring the
/// leader unresponsive. Control fetches are O(1) snapshots, so a healthy
/// leader answers as soon as it finishes the request in flight; only a
/// wedged or killed-but-not-yet-reaped leader runs the clock out.
pub const CONTROL_RECV_TIMEOUT: Duration = Duration::from_secs(5);

/// Client handle to a running coordinator.
#[derive(Clone)]
pub struct ClusterHandle {
    tx: mpsc::Sender<Envelope>,
}

/// A running coordinator (leader thread).
pub struct Coordinator {
    handle: Option<JoinHandle<RunMetrics>>,
    tx: mpsc::Sender<Envelope>,
    checkpoint: Arc<Mutex<CheckpointState>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub max_capacity: usize,
    pub hardware: Hardware,
    pub num_queues: usize,
    /// Per-queue slack hours indexed by queue.
    pub queue_slack_hours: Vec<f64>,
    pub horizon: usize,
    /// Service limits: pending bound, batch cap, shed policy.
    pub service: ServiceConfig,
}

impl CoordinatorConfig {
    /// Derive the coordinator shape from an experiment config plus service
    /// limits — the construction every serving entrypoint shares.
    pub fn from_experiment(cfg: &ExperimentConfig, service: ServiceConfig) -> CoordinatorConfig {
        CoordinatorConfig {
            max_capacity: cfg.capacity,
            hardware: cfg.hardware,
            num_queues: cfg.queues.len(),
            queue_slack_hours: cfg.queues.iter().map(|q| q.delay_hours).collect(),
            horizon: cfg.horizon_hours,
            service,
        }
    }
}

impl Coordinator {
    /// Start the leader thread.
    pub fn start(
        cfg: CoordinatorConfig,
        forecaster: Forecaster,
        policy: Box<dyn Policy + Send>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let checkpoint = Arc::new(Mutex::new(CheckpointState::default()));
        let ck = Arc::clone(&checkpoint);
        let handle = std::thread::spawn(move || leader_loop(cfg, forecaster, policy, rx, ck));
        Coordinator { handle: Some(handle), tx, checkpoint }
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { tx: self.tx.clone() }
    }

    /// Snapshot of the write-ahead checkpoint. Exact whenever no request is
    /// in flight (every [`ClusterHandle::request`] is synchronous, so a
    /// single-threaded caller always observes a quiescent leader).
    pub fn checkpoint(&self) -> CheckpointState {
        self.checkpoint.lock().expect("checkpoint poisoned").clone()
    }

    /// Drain all jobs, stop the leader, and return the final metrics.
    pub fn shutdown(mut self) -> RunMetrics {
        let h = self.handle();
        let _ = h.request(Request::Drain);
        drop(self.tx);
        self.handle.take().expect("shutdown called once").join().expect("leader panicked")
    }

    /// Fault injection: stop the leader immediately — no drain, pending
    /// jobs abandoned (they remain visible via [`Coordinator::checkpoint`]).
    /// Returns the metrics of what the shard completed before dying.
    pub fn kill(mut self) -> RunMetrics {
        let _ = self.tx.send(Envelope::Kill);
        self.handle.take().expect("kill called once").join().expect("leader panicked")
    }
}

impl ClusterHandle {
    /// Send a request and wait for the reply. A stopped (drained)
    /// coordinator answers with [`ErrorCode::Draining`].
    pub fn request(&self, req: Request) -> Response {
        let stopped = || Response::Error {
            code: ErrorCode::Draining,
            message: "coordinator stopped".into(),
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Envelope::Api { req, reply: reply_tx }).is_err() {
            return stopped();
        }
        reply_rx.recv().unwrap_or_else(|_| stopped())
    }

    /// Snapshot of the leader's decision-latency histogram. The sharded
    /// frontend merges these bucket-wise, so fleet percentiles come from
    /// the union of samples rather than the worst shard's percentile.
    ///
    /// Bounded: a leader that has stopped reports [`ControlError::Stopped`]
    /// and one that stays silent past [`CONTROL_RECV_TIMEOUT`] reports
    /// [`ControlError::Unresponsive`] — the fetch never blocks forever on
    /// a dead or wedged shard.
    pub fn latency_histogram(&self) -> Result<LatencyHistogram, ControlError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Envelope::Latency { reply: reply_tx }).is_err() {
            return Err(ControlError::Stopped);
        }
        match reply_rx.recv_timeout(CONTROL_RECV_TIMEOUT) {
            Ok(hist) => Ok(hist),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ControlError::Unresponsive),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ControlError::Stopped),
        }
    }

    pub fn submit(&self, workload: &str, length_hours: f64, queue: usize) -> Result<usize, String> {
        match self.request(Request::Submit(SubmitRequest {
            workload: workload.to_string(),
            length_hours,
            queue,
        })) {
            Response::Submitted { job_id } => Ok(job_id),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Submit many jobs in one envelope; outcomes come back in member order.
    pub fn submit_batch(&self, jobs: Vec<SubmitRequest>) -> Result<Vec<SubmitOutcome>, String> {
        match self.request(Request::SubmitBatch(jobs)) {
            Response::Batch { results } => Ok(results),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    pub fn tick(&self) -> Result<usize, String> {
        match self.request(Request::Tick) {
            Response::Ticked { slot } => Ok(slot),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    pub fn status(&self) -> Result<StatusResponse, String> {
        match self.request(Request::Status) {
            Response::Status(s) => Ok(s),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    pub fn stats(&self) -> Result<StatsResponse, String> {
        match self.request(Request::Stats) {
            Response::Stats(s) => Ok(s),
            Response::Error { message, .. } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }
}

/// Leader-side state: engine, catalog index, service counters.
struct Leader {
    cfg: CoordinatorConfig,
    catalog: Vec<profile::WorkloadSpec>,
    /// Workload name → catalog index, built once (hot-path lookup).
    index: BTreeMap<&'static str, usize>,
    k_max: usize,
    engine: ClusterEngine,
    slot: usize,
    next_id: usize,
    /// Queue of each admitted job, indexed by job id (for depth tracking —
    /// outcomes don't carry the queue).
    queue_of: Vec<u8>,
    /// Engine outcomes already folded into `depths`.
    outcomes_seen: usize,
    /// Waiting + running jobs per queue.
    depths: Vec<usize>,
    requests: u64,
    accepted: u64,
    shed: u64,
    batches: u64,
    latency: LatencyHistogram,
    /// Write-ahead submission checkpoint shared with the supervisor side
    /// (appended within the same request handling that admits/completes).
    checkpoint: Arc<Mutex<CheckpointState>>,
}

impl Leader {
    fn new(cfg: CoordinatorConfig, checkpoint: Arc<Mutex<CheckpointState>>) -> Leader {
        let catalog = profile::catalog_for(cfg.hardware);
        let index = catalog.iter().enumerate().map(|(i, w)| (w.name, i)).collect();
        let k_max = profile::default_k_max(cfg.hardware);
        let sim = Simulator::new(
            cfg.max_capacity,
            crate::cluster::energy::EnergyModel::for_hardware(cfg.hardware),
            cfg.num_queues,
            cfg.horizon,
        );
        let depths = vec![0usize; cfg.num_queues.max(1)];
        Leader {
            cfg,
            catalog,
            index,
            k_max,
            engine: ClusterEngine::new(sim),
            slot: 0,
            next_id: 0,
            queue_of: Vec::new(),
            outcomes_seen: 0,
            depths,
            requests: 0,
            accepted: 0,
            shed: 0,
            batches: 0,
            latency: LatencyHistogram::new(),
            checkpoint,
        }
    }

    /// Remaining admission room under the pending bound.
    fn room(&self) -> usize {
        self.cfg.service.max_pending.saturating_sub(self.engine.pending_jobs())
    }

    /// Admit or reject one submission. `room` is the envelope's remaining
    /// admission budget; decrements on admit so batch members see the same
    /// decisions they would get submitted singly.
    fn admit_one(&mut self, s: &SubmitRequest, room: &mut usize) -> SubmitOutcome {
        let Some(&widx) = self.index.get(s.workload.as_str()) else {
            return SubmitOutcome::Rejected {
                code: ErrorCode::UnknownWorkload,
                message: format!("unknown workload '{}'", s.workload),
            };
        };
        if !s.length_hours.is_finite() || s.length_hours <= 0.0 {
            return SubmitOutcome::Rejected {
                code: ErrorCode::BadRequest,
                message: "length_hours must be positive and finite".into(),
            };
        }
        let queue = s.queue.min(self.cfg.num_queues.saturating_sub(1));
        if *room == 0 {
            match self.cfg.service.shed {
                ShedPolicy::RejectNewest => {
                    self.shed += 1;
                    return SubmitOutcome::Rejected {
                        code: ErrorCode::QueueFull,
                        message: format!(
                            "queue full (max_pending {})",
                            self.cfg.service.max_pending
                        ),
                    };
                }
                ShedPolicy::RejectLowestQueue if queue != 0 => {
                    self.shed += 1;
                    return SubmitOutcome::Rejected {
                        code: ErrorCode::Shed,
                        message: format!(
                            "shed under backpressure (queue {queue}; only queue 0 admits \
                             over the bound)"
                        ),
                    };
                }
                // Queue 0 (least slack) is admitted over the bound.
                ShedPolicy::RejectLowestQueue => {}
            }
        } else {
            *room -= 1;
        }
        let spec = &self.catalog[widx];
        let job = Job {
            id: self.next_id,
            workload: spec.name,
            workload_idx: widx,
            arrival: self.slot,
            length_hours: s.length_hours,
            queue,
            slack_hours: self.cfg.queue_slack_hours.get(queue).copied().unwrap_or(24.0),
            k_min: 1,
            k_max: self.k_max,
            profile: spec.profile(self.k_max),
            watts_per_unit: spec.watts_per_unit,
            deps: Vec::new(),
        };
        self.engine.add_job(job);
        self.checkpoint
            .lock()
            .expect("checkpoint poisoned")
            .accepted
            .push((self.next_id, s.clone()));
        self.queue_of.push(queue as u8);
        self.depths[queue.min(self.depths.len() - 1)] += 1;
        self.accepted += 1;
        self.next_id += 1;
        SubmitOutcome::Accepted { job_id: self.next_id - 1 }
    }

    /// Fold newly completed jobs into the per-queue depth counters (and the
    /// write-ahead checkpoint's completed set).
    fn sync_completions(&mut self) {
        let outs = self.engine.outcomes();
        if self.outcomes_seen == outs.len() {
            return;
        }
        let mut ck = self.checkpoint.lock().expect("checkpoint poisoned");
        while self.outcomes_seen < outs.len() {
            let id = outs[self.outcomes_seen].id;
            ck.completed.push(id);
            let q = self.queue_of.get(id).copied().unwrap_or(0) as usize;
            let q = q.min(self.depths.len() - 1);
            self.depths[q] = self.depths[q].saturating_sub(1);
            self.outcomes_seen += 1;
        }
        // Keep the write-ahead log bounded as acknowledgements advance:
        // fully-completed entries can never be re-routed by a failover.
        if ck.completed.len() >= CHECKPOINT_COMPACT_THRESHOLD {
            ck.compact();
        }
    }

    fn status(&self) -> StatusResponse {
        let last = self.engine.last_slot();
        StatusResponse {
            slot: self.slot,
            active_jobs: self.engine.pending_jobs(),
            completed: self.engine.outcomes().len(),
            provisioned: last.map(|s| s.provisioned).unwrap_or(0),
            used: last.map(|s| s.used).unwrap_or(0),
            carbon_g: self.engine.outcomes().iter().map(|o| o.carbon_g).sum(),
            energy_kwh: self.engine.outcomes().iter().map(|o| o.energy_kwh).sum(),
        }
    }

    fn stats(&self) -> StatsResponse {
        StatsResponse {
            slot: self.slot,
            requests: self.requests,
            accepted: self.accepted,
            shed: self.shed,
            batches: self.batches,
            pending: self.engine.pending_jobs(),
            max_pending: self.cfg.service.max_pending,
            queue_depths: self.depths.clone(),
            p50_decision_ms: self.latency.percentile_ms(50.0),
            p99_decision_ms: self.latency.percentile_ms(99.0),
            carbon_g: self.engine.outcomes().iter().map(|o| o.carbon_g).sum(),
            // Degradation counters live in the policy; `handle` patches them
            // in (the policy is not reachable from `&self` here). Supervisor
            // counters are always 0 at the single-shard leader.
            degraded_stale: 0,
            degraded_fallback: 0,
            failovers: 0,
            rerouted: 0,
            failover_shed: 0,
        }
    }

    /// Process one request; returns the response and whether the leader
    /// should stop (after a drain).
    fn handle(
        &mut self,
        req: Request,
        forecaster: &Forecaster,
        policy: &mut dyn Policy,
    ) -> (Response, bool) {
        match req {
            Request::Submit(s) => {
                let t0 = Instant::now();
                let mut room = self.room();
                let out = self.admit_one(&s, &mut room);
                self.latency.record(t0.elapsed());
                let resp = match out {
                    SubmitOutcome::Accepted { job_id } => Response::Submitted { job_id },
                    SubmitOutcome::Rejected { code, message } => {
                        Response::Error { code, message }
                    }
                };
                (resp, false)
            }
            Request::SubmitBatch(jobs) => {
                self.batches += 1;
                if jobs.is_empty() {
                    let resp = Response::Error {
                        code: ErrorCode::BadRequest,
                        message: "empty batch".into(),
                    };
                    return (resp, false);
                }
                if jobs.len() > self.cfg.service.max_batch {
                    let resp = Response::Error {
                        code: ErrorCode::BadRequest,
                        message: format!(
                            "batch of {} exceeds max_batch {}",
                            jobs.len(),
                            self.cfg.service.max_batch
                        ),
                    };
                    return (resp, false);
                }
                let t0 = Instant::now();
                // One backpressure consultation for the whole envelope.
                let mut room = self.room();
                let results: Vec<SubmitOutcome> =
                    jobs.iter().map(|s| self.admit_one(s, &mut room)).collect();
                // Amortized per-submission decision latency.
                let per = t0.elapsed() / results.len() as u32;
                for _ in 0..results.len() {
                    self.latency.record(per);
                }
                (Response::Batch { results }, false)
            }
            Request::Tick => {
                self.engine.step(self.slot, forecaster, policy);
                self.slot += 1;
                self.sync_completions();
                (Response::Ticked { slot: self.slot }, false)
            }
            Request::Status => {
                self.sync_completions();
                (Response::Status(self.status()), false)
            }
            Request::Stats => {
                self.sync_completions();
                let mut st = self.stats();
                let d = policy.degradation();
                st.degraded_stale = d.stale;
                st.degraded_fallback = d.fallback;
                (Response::Stats(st), false)
            }
            Request::Drain => {
                let mut guard = 0usize;
                while self.engine.pending_jobs() > 0 && guard < 100_000 {
                    self.engine.step(self.slot, forecaster, policy);
                    self.slot += 1;
                    guard += 1;
                }
                self.sync_completions();
                let delays: Vec<f64> =
                    self.engine.outcomes().iter().map(|o| o.delay_hours()).collect();
                let resp = Response::Drained {
                    completed: self.engine.outcomes().len(),
                    carbon_g: self.engine.outcomes().iter().map(|o| o.carbon_g).sum(),
                    mean_delay_hours: crate::util::stats::mean(&delays),
                };
                (resp, true)
            }
        }
    }
}

fn leader_loop(
    cfg: CoordinatorConfig,
    forecaster: Forecaster,
    mut policy: Box<dyn Policy + Send>,
    rx: mpsc::Receiver<Envelope>,
    checkpoint: Arc<Mutex<CheckpointState>>,
) -> RunMetrics {
    let mut leader = Leader::new(cfg, checkpoint);
    while let Ok(env) = rx.recv() {
        match env {
            Envelope::Api { req, reply } => {
                leader.requests += 1;
                let (resp, done) = leader.handle(req, &forecaster, policy.as_mut());
                let _ = reply.send(resp);
                if done {
                    break;
                }
            }
            Envelope::Latency { reply } => {
                let _ = reply.send(leader.latency.clone());
            }
            // Simulated crash: stop without draining. The checkpoint keeps
            // the pending set for the supervisor's failover.
            Envelope::Kill => break,
        }
    }
    let mut metrics = leader.engine.finish(policy.name()).metrics;
    let d = policy.degradation();
    metrics.degraded_stale = d.stale;
    metrics.degraded_fallback = d.fallback;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::trace::CarbonTrace;
    use crate::sched::carbon_agnostic::CarbonAgnostic;

    fn config() -> CoordinatorConfig {
        CoordinatorConfig {
            max_capacity: 10,
            hardware: Hardware::Cpu,
            num_queues: 3,
            queue_slack_hours: vec![6.0, 24.0, 48.0],
            horizon: 100,
            service: ServiceConfig::default(),
        }
    }

    fn start_with(cfg: CoordinatorConfig) -> Coordinator {
        let trace = CarbonTrace::new("flat", vec![100.0; 500]);
        Coordinator::start(cfg, Forecaster::perfect(trace), Box::new(CarbonAgnostic))
    }

    fn start_coordinator() -> Coordinator {
        start_with(config())
    }

    fn sub(workload: &str, length_hours: f64, queue: usize) -> SubmitRequest {
        SubmitRequest { workload: workload.to_string(), length_hours, queue }
    }

    #[test]
    fn submit_tick_status_drain() {
        let coord = start_coordinator();
        let h = coord.handle();
        let id0 = h.submit("N-body(N=100k)", 2.0, 0).unwrap();
        let id1 = h.submit("Jacobi(N=1k)", 3.0, 1).unwrap();
        assert_eq!((id0, id1), (0, 1));
        assert_eq!(h.tick().unwrap(), 1);
        let s = h.status().unwrap();
        assert_eq!(s.slot, 1);
        assert_eq!(s.used, 2);
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 2);
        assert_eq!(metrics.unfinished, 0);
        assert!(metrics.carbon_g > 0.0);
    }

    #[test]
    fn unknown_workload_rejected() {
        let coord = start_coordinator();
        let h = coord.handle();
        assert!(h.submit("NotAWorkload", 2.0, 0).is_err());
        assert!(h.submit("N-body(N=100k)", -1.0, 0).is_err());
        coord.shutdown();
    }

    #[test]
    fn late_submission_after_ticks() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.tick().unwrap();
        h.tick().unwrap();
        let id = h.submit("Heat(N=1k)", 1.0, 0).unwrap();
        assert_eq!(id, 0);
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 1);
    }

    #[test]
    fn batch_submit_outcomes_in_member_order() {
        let coord = start_coordinator();
        let h = coord.handle();
        let results = h
            .submit_batch(vec![
                sub("N-body(N=100k)", 2.0, 0),
                sub("NotAWorkload", 1.0, 0),
                sub("Jacobi(N=1k)", 3.0, 1),
            ])
            .unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0], SubmitOutcome::Accepted { job_id: 0 });
        assert!(matches!(
            results[1],
            SubmitOutcome::Rejected { code: ErrorCode::UnknownWorkload, .. }
        ));
        assert_eq!(results[2], SubmitOutcome::Accepted { job_id: 1 });
        // Empty and oversize batches are envelope-level errors.
        assert!(h.submit_batch(vec![]).is_err());
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 2);
    }

    #[test]
    fn backpressure_reject_newest() {
        let mut cfg = config();
        cfg.service.max_pending = 2;
        let coord = start_with(cfg);
        let h = coord.handle();
        h.submit("N-body(N=100k)", 2.0, 2).unwrap();
        h.submit("N-body(N=100k)", 2.0, 2).unwrap();
        match h.request(Request::Submit(sub("N-body(N=100k)", 2.0, 0))) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::QueueFull),
            other => panic!("expected queue_full, got {other:?}"),
        }
        let st = h.stats().unwrap();
        assert_eq!((st.accepted, st.shed), (2, 1));
        coord.shutdown();
    }

    #[test]
    fn backpressure_lowest_queue_admits_urgent() {
        let mut cfg = config();
        cfg.service.max_pending = 1;
        cfg.service.shed = ShedPolicy::RejectLowestQueue;
        let coord = start_with(cfg);
        let h = coord.handle();
        h.submit("N-body(N=100k)", 2.0, 2).unwrap();
        // Bound hit: delay-tolerant queues shed, queue 0 still admits.
        match h.request(Request::Submit(sub("N-body(N=100k)", 2.0, 2))) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Shed),
            other => panic!("expected shed, got {other:?}"),
        }
        assert!(h.submit("N-body(N=100k)", 1.0, 0).is_ok());
        coord.shutdown();
    }

    #[test]
    fn stats_counts_and_depths() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.submit("N-body(N=100k)", 2.0, 0).unwrap();
        h.submit_batch(vec![sub("Jacobi(N=1k)", 3.0, 1), sub("Heat(N=1k)", 1.0, 1)]).unwrap();
        let st = h.stats().unwrap();
        assert_eq!(st.accepted, 3);
        assert_eq!(st.batches, 1);
        assert_eq!(st.shed, 0);
        assert_eq!(st.pending, 3);
        assert_eq!(st.queue_depths, vec![1, 2, 0]);
        assert!(st.requests >= 3);
        assert!(st.p99_decision_ms >= st.p50_decision_ms);
        coord.shutdown();
    }

    #[test]
    fn latency_histogram_fetch_is_not_a_service_request() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.submit("N-body(N=100k)", 2.0, 0).unwrap();
        h.submit("Jacobi(N=1k)", 3.0, 1).unwrap();
        let before = h.stats().unwrap().requests;
        // The histogram snapshot carries every recorded submit decision…
        let hist = h.latency_histogram().unwrap();
        assert_eq!(hist.count(), 2);
        assert!(hist.percentile_ms(99.0) >= hist.percentile_ms(50.0));
        // …and fetching it does not bump the request counter.
        let after = h.stats().unwrap().requests;
        assert_eq!(after, before + 1, "only the Stats call itself may count");
        coord.shutdown();
    }

    #[test]
    fn kill_preserves_checkpoint_pending() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.submit("N-body(N=100k)", 1.0, 0).unwrap();
        h.submit("Jacobi(N=1k)", 30.0, 1).unwrap();
        h.submit("Heat(N=1k)", 30.0, 2).unwrap();
        // One slot: the 1 h job completes, the long jobs stay pending.
        h.tick().unwrap();
        let ck = coord.checkpoint();
        assert_eq!(ck.accepted.len(), 3);
        assert_eq!(ck.completed, vec![0]);
        let pending = ck.pending();
        assert_eq!(pending.len(), 2);
        assert_eq!(pending[0].workload, "Jacobi(N=1k)");
        assert_eq!(pending[1].workload, "Heat(N=1k)");
        // Kill without drain: only the completed job shows in the metrics.
        let metrics = coord.kill();
        assert_eq!(metrics.completed, 1);
        assert_eq!(metrics.unfinished, 2);
    }

    #[test]
    fn latency_fetch_from_dead_leader_errors_instead_of_hanging() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.submit("N-body(N=100k)", 2.0, 0).unwrap();
        // Kill the leader (joins the thread, drops the receiver): the
        // out-of-band fetch must come back as a structured error, never
        // block on a reply that cannot arrive.
        let _ = coord.kill();
        assert!(matches!(h.latency_histogram(), Err(ControlError::Stopped)));
    }

    #[test]
    fn checkpoint_compaction_preserves_pending_and_totals() {
        let mut ck = CheckpointState::default();
        for id in 0..1000usize {
            ck.accepted.push((id, sub("N-body(N=100k)", 1.0, id % 3)));
        }
        for id in 0..990usize {
            ck.completed.push(id);
        }
        let pending_before = ck.pending();
        assert_eq!(pending_before.len(), 10);
        ck.compact();
        // Only the 10 unfinished entries survive; totals reconstruct.
        assert_eq!(ck.accepted.len(), 10);
        assert!(ck.completed.is_empty());
        assert_eq!(ck.compacted, 990);
        assert_eq!(ck.accepted_total(), 1000);
        assert_eq!(ck.completed_total(), 990);
        assert_eq!(ck.pending(), pending_before);
        // Idempotent: nothing left to match.
        ck.compact();
        assert_eq!(ck.compacted, 990);
        // Completing the stragglers compacts them away too.
        ck.completed.extend(990..1000usize);
        ck.compact();
        assert!(ck.accepted.is_empty());
        assert_eq!(ck.accepted_total(), 1000);
        assert_eq!(ck.completed_total(), 1000);
        assert!(ck.pending().is_empty());
    }

    #[test]
    fn leader_auto_compacts_past_threshold() {
        let coord = start_coordinator();
        let h = coord.handle();
        // Admit and complete well past the threshold: short jobs finish
        // on the next tick, so each round's completions accumulate.
        let n = CHECKPOINT_COMPACT_THRESHOLD + 64;
        for _ in 0..n {
            h.submit("Heat(N=1k)", 1.0, 0).unwrap();
        }
        // Drain completes everything and runs sync_completions (and with
        // it the compaction) one final time.
        match h.request(Request::Drain) {
            Response::Drained { completed, .. } => assert_eq!(completed, n),
            other => panic!("expected drained, got {other:?}"),
        }
        let ck = coord.checkpoint();
        assert!(
            ck.accepted.len() < CHECKPOINT_COMPACT_THRESHOLD,
            "write-ahead log must stay bounded, kept {}",
            ck.accepted.len()
        );
        assert_eq!(ck.accepted_total(), n as u64);
        assert_eq!(ck.completed_total(), n as u64);
        coord.shutdown();
    }

    #[test]
    fn stopped_coordinator_reports_draining() {
        let coord = start_coordinator();
        let h = coord.handle();
        match h.request(Request::Drain) {
            Response::Drained { .. } => {}
            other => panic!("expected drained, got {other:?}"),
        }
        // Leader has stopped; further requests get a typed Draining error.
        match h.request(Request::Status) {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Draining),
            other => panic!("expected draining, got {other:?}"),
        }
        coord.shutdown();
    }
}
