//! The coordinator leader: a long-running service that owns the cluster
//! engine and a scheduling policy, accepts job submissions over a channel,
//! and advances slots in virtual time.
//!
//! This is the deployment shape of the paper's prototype (§5): AWS
//! ParallelCluster + PySlurm replaced by our in-process cluster engine, with
//! the same separation — the policy decides, the engine actuates. The
//! leader runs on a dedicated thread (no tokio offline); clients hold a
//! cheap [`ClusterHandle`] of mpsc senders.

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::carbon::forecast::Forecaster;
use crate::cluster::metrics::RunMetrics;
use crate::cluster::sim::{ClusterEngine, Simulator};
use crate::config::Hardware;
use crate::coordinator::api::{Request, Response, StatusResponse, SubmitRequest};
use crate::sched::Policy;
use crate::workload::job::Job;
use crate::workload::profile;

/// Message envelope: request + reply channel.
struct Envelope {
    req: Request,
    reply: mpsc::Sender<Response>,
}

/// Client handle to a running coordinator.
#[derive(Clone)]
pub struct ClusterHandle {
    tx: mpsc::Sender<Envelope>,
}

/// A running coordinator (leader thread).
pub struct Coordinator {
    handle: Option<JoinHandle<RunMetrics>>,
    tx: mpsc::Sender<Envelope>,
}

/// Coordinator configuration.
pub struct CoordinatorConfig {
    pub max_capacity: usize,
    pub hardware: Hardware,
    pub num_queues: usize,
    /// Per-queue slack hours indexed by queue.
    pub queue_slack_hours: Vec<f64>,
    pub horizon: usize,
}

impl Coordinator {
    /// Start the leader thread.
    pub fn start(
        cfg: CoordinatorConfig,
        forecaster: Forecaster,
        policy: Box<dyn Policy + Send>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let handle = std::thread::spawn(move || leader_loop(cfg, forecaster, policy, rx));
        Coordinator { handle: Some(handle), tx }
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { tx: self.tx.clone() }
    }

    /// Drain all jobs, stop the leader, and return the final metrics.
    pub fn shutdown(mut self) -> RunMetrics {
        let h = self.handle();
        let _ = h.request(Request::Drain);
        drop(self.tx);
        self.handle.take().expect("shutdown called once").join().expect("leader panicked")
    }
}

impl ClusterHandle {
    /// Send a request and wait for the reply.
    pub fn request(&self, req: Request) -> Response {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Envelope { req, reply: reply_tx }).is_err() {
            return Response::Error { message: "coordinator stopped".into() };
        }
        reply_rx.recv().unwrap_or(Response::Error { message: "coordinator stopped".into() })
    }

    pub fn submit(&self, workload: &str, length_hours: f64, queue: usize) -> Result<usize, String> {
        match self.request(Request::Submit(SubmitRequest {
            workload: workload.to_string(),
            length_hours,
            queue,
        })) {
            Response::Submitted { job_id } => Ok(job_id),
            Response::Error { message } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    pub fn tick(&self) -> Result<usize, String> {
        match self.request(Request::Tick) {
            Response::Ticked { slot } => Ok(slot),
            Response::Error { message } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    pub fn status(&self) -> Result<StatusResponse, String> {
        match self.request(Request::Status) {
            Response::Status(s) => Ok(s),
            Response::Error { message } => Err(message),
            other => Err(format!("unexpected response {other:?}")),
        }
    }
}

fn leader_loop(
    cfg: CoordinatorConfig,
    forecaster: Forecaster,
    mut policy: Box<dyn Policy + Send>,
    rx: mpsc::Receiver<Envelope>,
) -> RunMetrics {
    let catalog = profile::catalog_for(cfg.hardware);
    let k_max = profile::default_k_max(cfg.hardware);
    let sim = Simulator::new(
        cfg.max_capacity,
        crate::cluster::energy::EnergyModel::for_hardware(cfg.hardware),
        cfg.num_queues,
        cfg.horizon,
    );
    let mut engine = ClusterEngine::new(sim);
    let mut slot = 0usize;
    let mut next_id = 0usize;
    let mut drained = false;

    while let Ok(Envelope { req, reply }) = rx.recv() {
        let resp = match req {
            Request::Submit(s) => match catalog.iter().position(|w| w.name == s.workload) {
                None => Response::Error { message: format!("unknown workload '{}'", s.workload) },
                Some(widx) if s.length_hours <= 0.0 => {
                    let _ = widx;
                    Response::Error { message: "length_hours must be positive".into() }
                }
                Some(widx) => {
                    let spec = &catalog[widx];
                    let queue = s.queue.min(cfg.num_queues.saturating_sub(1));
                    let job = Job {
                        id: next_id,
                        workload: spec.name,
                        workload_idx: widx,
                        arrival: slot,
                        length_hours: s.length_hours,
                        queue,
                        slack_hours: cfg.queue_slack_hours.get(queue).copied().unwrap_or(24.0),
                        k_min: 1,
                        k_max,
                        profile: spec.profile(k_max),
                        watts_per_unit: spec.watts_per_unit,
                    };
                    engine.add_job(job);
                    next_id += 1;
                    Response::Submitted { job_id: next_id - 1 }
                }
            },
            Request::Tick => {
                engine.step(slot, &forecaster, policy.as_mut());
                slot += 1;
                Response::Ticked { slot }
            }
            Request::Status => {
                let last = engine.slots().last();
                Response::Status(StatusResponse {
                    slot,
                    active_jobs: engine.pending_jobs(),
                    completed: engine.outcomes().len(),
                    provisioned: last.map(|s| s.provisioned).unwrap_or(0),
                    used: last.map(|s| s.used).unwrap_or(0),
                    carbon_g: engine.outcomes().iter().map(|o| o.carbon_g).sum(),
                    energy_kwh: engine.outcomes().iter().map(|o| o.energy_kwh).sum(),
                })
            }
            Request::Drain => {
                let mut guard = 0usize;
                while engine.pending_jobs() > 0 && guard < 100_000 {
                    engine.step(slot, &forecaster, policy.as_mut());
                    slot += 1;
                    guard += 1;
                }
                drained = true;
                let delays: Vec<f64> =
                    engine.outcomes().iter().map(|o| o.delay_hours()).collect();
                Response::Drained {
                    completed: engine.outcomes().len(),
                    carbon_g: engine.outcomes().iter().map(|o| o.carbon_g).sum(),
                    mean_delay_hours: crate::util::stats::mean(&delays),
                }
            }
        };
        let _ = reply.send(resp);
        if drained {
            break;
        }
    }
    engine.finish(policy.name()).metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::trace::CarbonTrace;
    use crate::sched::carbon_agnostic::CarbonAgnostic;

    fn start_coordinator() -> Coordinator {
        let trace = CarbonTrace::new("flat", vec![100.0; 500]);
        Coordinator::start(
            CoordinatorConfig {
                max_capacity: 10,
                hardware: Hardware::Cpu,
                num_queues: 3,
                queue_slack_hours: vec![6.0, 24.0, 48.0],
                horizon: 100,
            },
            Forecaster::perfect(trace),
            Box::new(CarbonAgnostic),
        )
    }

    #[test]
    fn submit_tick_status_drain() {
        let coord = start_coordinator();
        let h = coord.handle();
        let id0 = h.submit("N-body(N=100k)", 2.0, 0).unwrap();
        let id1 = h.submit("Jacobi(N=1k)", 3.0, 1).unwrap();
        assert_eq!((id0, id1), (0, 1));
        assert_eq!(h.tick().unwrap(), 1);
        let s = h.status().unwrap();
        assert_eq!(s.slot, 1);
        assert_eq!(s.used, 2);
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 2);
        assert_eq!(metrics.unfinished, 0);
        assert!(metrics.carbon_g > 0.0);
    }

    #[test]
    fn unknown_workload_rejected() {
        let coord = start_coordinator();
        let h = coord.handle();
        assert!(h.submit("NotAWorkload", 2.0, 0).is_err());
        assert!(h.submit("N-body(N=100k)", -1.0, 0).is_err());
        coord.shutdown();
    }

    #[test]
    fn late_submission_after_ticks() {
        let coord = start_coordinator();
        let h = coord.handle();
        h.tick().unwrap();
        h.tick().unwrap();
        let id = h.submit("Heat(N=1k)", 1.0, 0).unwrap();
        assert_eq!(id, 0);
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 1);
    }
}
