//! CarbonFlex CLI — the launcher.
//!
//! Subcommands:
//! - `simulate --config <file> [--policy <name>]` — run one policy
//! - `compare  --config <file>` — run the headline policy comparison
//! - `sweep    [--regions a,b] [--policies x,y] [--threads N]` — parallel grid
//! - `learn    --config <file> --out kb.csv` — run the learning phase
//! - `gen-traces --region <key> --hours <n> --out <csv>` — export CI traces
//! - `catalog` — print the Table 3 workload catalog
//! - `experiment <fig5|fig6|...|fig14|overheads>` — regenerate a paper figure
//! - `serve [--policy <name>] [--shards n|a+b] [--tcp host:port]` — run the
//!   (optionally sharded) coordinator on stdin/stdout JSON lines (wire
//!   protocol v2), or as a TCP session server with resume/dedup
//! - `client --tcp host:port [--jobs n] [--drop-after k]` — drive a TCP
//!   session from the CLI, optionally forcing a mid-stream reconnect
//! - `serve-bench [--jobs n] [--batch b] [--json]` — closed-loop serving
//!   benchmark → `BENCH_serve.json`
//! - `chaos-bench [--faults light|heavy] [--json]` — fault-injection
//!   benchmark (clean vs faulted sim + shard-kill failover + session
//!   chaos cell) → `BENCH_chaos.json`
//! - `net-bench [--faults heavy] [--json]` — session/transport benchmark
//!   (stdio vs loopback vs faulted loopback vs TCP) → `BENCH_net.json`

use carbonflex::carbon::synth::{self, Region};
use carbonflex::config::{ExperimentConfig, ServiceConfig, ShedPolicy};
use carbonflex::coordinator;
use carbonflex::experiments::perf;
use carbonflex::experiments::DispatchStrategy;
use carbonflex::experiments::runner;
use carbonflex::experiments::sweep::{self, SweepRunner, SweepSpec};
use carbonflex::sched::PolicyKind;
use carbonflex::util::bench::Table;
use carbonflex::util::cli::Args;
use carbonflex::util::json::Json;
use carbonflex::workload::profile;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("bench") => cmd_bench(&args),
        Some("learn") => cmd_learn(&args),
        Some("gen-traces") => cmd_gen_traces(&args),
        Some("catalog") => cmd_catalog(),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("chaos-bench") => cmd_chaos_bench(&args),
        Some("net-bench") => cmd_net_bench(&args),
        _ => {
            print_usage();
            if args.command.is_none() || args.flag("help") {
                0
            } else {
                eprintln!("unknown command: {:?}", args.command);
                2
            }
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "carbonflex — carbon-aware provisioning and scheduling for cloud clusters\n\
         \n\
         USAGE: carbonflex <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 simulate    --config <file> [--policy carbonflex] run one policy\n\
         \x20 compare     --config <file>                       headline comparison (Fig. 6)\n\
         \x20 sweep       [--config <file>] [--regions a,b+c] [--policies x,y|all|headline]\n\
         \x20             [--dispatch rr,current,window] [--capacities 100,150]\n\
         \x20             [--horizons 168] [--weeks N|w1,w2] [--aging-window 672]\n\
         \x20             [--seeds 1,2] [--faults none,light,heavy] [--history <h>]\n\
         \x20             [--dag-shapes none,chains,fanout,mapreduce,random]\n\
         \x20             [--offsets <n>] [--threads N] [--shard i/n] [--json] [--check]\n\
         \x20             parallel cartesian grid; rows in grid order. A '+'-joined\n\
         \x20             region entry is a multi-region spatial cell (the --dispatch\n\
         \x20             axis applies); --weeks makes cells weekly continuous-learning\n\
         \x20             windows. A [sweep] table in the config file sets the same\n\
         \x20             axes declaratively; flags override it per axis. --shard i/n\n\
         \x20             runs slice i of n for multi-process grids; concatenated\n\
         \x20             shard rows equal the unsharded output bitwise\n\
         \x20 bench       [--config <file>] [--json] [--out BENCH_hotpaths.json]\n\
         \x20             [--budget-ms 2000] [--baseline <file>] [--max-regression 3.0]\n\
         \x20             hot-path timings → JSON; non-zero exit on baseline regression\n\
         \x20 learn       --config <file> [--out kb.csv]        learning phase → knowledge base\n\
         \x20 gen-traces  [--region south-australia] [--hours 8760] [--out trace.csv]\n\
         \x20 catalog                                           Table 3 workload catalog\n\
         \x20 experiment  <fig5..fig14|overheads|yearlong|noise|spatial>\n\
         \x20 serve       [--config <file>] [--policy <name>] [--shards n|a+b]\n\
         \x20             [--dispatch rr|current|window] [--max-pending N]\n\
         \x20             [--max-batch N] [--shed reject-newest|reject-lowest-queue]\n\
         \x20             [--kill-shard s@N,...] [--tcp host:port]\n\
         \x20             JSON-line coordinator on stdio (wire protocol v2; a\n\
         \x20             [service] table in the config sets the same knobs;\n\
         \x20             --kill-shard kills shard s at the N-th submission to\n\
         \x20             exercise supervisor failover). With --tcp, listens as\n\
         \x20             a session server instead: length-prefixed frames,\n\
         \x20             handshake + resume tokens, idempotent retry via\n\
         \x20             server-side dedup; exits after a drain\n\
         \x20 client      --tcp host:port [--jobs 8] [--drop-after k] [--drain]\n\
         \x20             drive a TCP session: submit a generated trace, force\n\
         \x20             one reconnect after k submissions (resume must keep\n\
         \x20             the session), print session stats\n\
         \x20 serve-bench [--config <file>] [--policy <name>] [--jobs 2000]\n\
         \x20             [--horizon <h>] [--seed <s>] [--batch 64] [--shards n|a+b]\n\
         \x20             [--json] [--out BENCH_serve.json]\n\
         \x20             closed-loop serving benchmark: single vs batched vs\n\
         \x20             sharded ingest of one generated trace\n\
         \x20 chaos-bench [--config <file>] [--faults light|heavy|none]\n\
         \x20             [--policy carbonflex] [--serve-policy agnostic]\n\
         \x20             [--jobs 120] [--shards 2] [--json] [--out BENCH_chaos.json]\n\
         \x20             fault-injection benchmark: carbon overhead of running\n\
         \x20             through a seeded fault plan, crash-recovery percentiles,\n\
         \x20             shard-kill failover with the exactly-once drain check,\n\
         \x20             and a combined kill + link-fault session cell\n\
         \x20 net-bench   [--config <file>] [--faults none|light|heavy]\n\
         \x20             [--policy agnostic] [--jobs 120] [--horizon 48]\n\
         \x20             [--seed <s>] [--window 16] [--no-tcp] [--json]\n\
         \x20             [--out BENCH_net.json]\n\
         \x20             session/transport benchmark: stdio baseline vs session\n\
         \x20             legs (clean loopback, seeded link faults, TCP) with\n\
         \x20             bitwise drain identity and exactly-once gates"
    );
}

fn load_config(args: &Args) -> Result<ExperimentConfig, String> {
    match args.get("config") {
        Some(path) => ExperimentConfig::load(path).map_err(|e| e.to_string()),
        None => Ok(ExperimentConfig::default()),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let kind = match PolicyKind::parse_or_err(args.get_or("policy", "carbonflex")) {
        Ok(k) => k,
        Err(e) => return fail(&e),
    };
    let row = runner::run_policy(&cfg, kind);
    let m = &row.result.metrics;
    println!("policy:     {}", m.policy);
    println!("carbon:     {:.2} kg", m.carbon_kg());
    println!("energy:     {:.2} kWh", m.energy_kwh);
    println!("savings:    {:.1} % vs Carbon-Agnostic", row.savings_pct);
    println!("completed:  {} ({} violations)", m.completed, m.violations);
    println!("mean delay: {:.2} h (p95 {:.2} h)", m.mean_delay_hours, m.p95_delay_hours);
    println!("util:       {:.1} %", m.mean_utilization * 100.0);
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let rows = runner::run_policies(&cfg, &PolicyKind::HEADLINE);
    let mut table =
        Table::new(&["policy", "carbon (kg)", "savings %", "mean delay (h)", "violations"]);
    for row in &rows {
        let m = &row.result.metrics;
        table.row(&[
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", m.mean_delay_hours),
            format!("{}", m.violations),
        ]);
    }
    table.print();
    0
}

/// Parse a comma-separated `--name a,b,c` option with a per-item parser;
/// `None`/empty means "axis not given".
fn parse_list<T>(
    args: &Args,
    name: &str,
    parse: impl Fn(&str) -> Result<T, String>,
) -> Result<Vec<T>, String> {
    match args.get(name) {
        None => Ok(Vec::new()),
        Some(raw) => raw
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(&parse)
            .collect(),
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let t0 = std::time::Instant::now();
    let mut base = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    // Base-config overrides useful for quick grids and CI smoke runs.
    match args.num_or::<usize>("history", base.history_hours) {
        Ok(h) => base.history_hours = h,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("offsets", base.replay_offsets) {
        Ok(o) => base.replay_offsets = o,
        Err(e) => return fail(&e),
    }

    let mut spec = SweepSpec::new(base);
    // Declarative axes from the config file's optional [sweep] table; CLI
    // flags override them per axis below.
    if let Some(path) = args.get("config") {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => return fail(&format!("reading {path}: {e}")),
        };
        if let Err(e) = spec.apply_toml_axes(&src) {
            return fail(&e);
        }
    }
    // A region entry may be a '+'-joined set ("south-australia+ontario"):
    // such points are multi-region spatial cells, multiplied by --dispatch.
    let regions = match parse_list(args, "regions", |s| {
        let keys: Result<Vec<_>, String> = s
            .split('+')
            .map(|k| {
                Region::parse(k.trim())
                    .map(|r| r.key().to_string())
                    .ok_or_else(|| format!("unknown region '{k}'"))
            })
            .collect();
        keys.map(|k| k.join("+"))
    }) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    if !regions.is_empty() {
        spec.regions = regions;
    }
    let dispatchers = match parse_list(args, "dispatch", |s| {
        carbonflex::experiments::DispatchStrategy::parse(s)
            .ok_or_else(|| format!("unknown dispatch strategy '{s}' (rr, current, window)"))
    }) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    if !dispatchers.is_empty() {
        spec.dispatchers = dispatchers;
    }
    match args.get("policies") {
        Some("all") => spec.policies = PolicyKind::ALL.to_vec(),
        Some("headline") => spec.policies = PolicyKind::HEADLINE.to_vec(),
        Some(_) => match parse_list(args, "policies", PolicyKind::parse_or_err) {
            Ok(v) => spec.policies = v,
            Err(e) => return fail(&e),
        },
        // No flag: keep the [sweep] table's axis if it set one; otherwise
        // the spec defaults to the headline set.
        None => {}
    };
    match args.num_list::<usize>("capacities") {
        Ok(v) if !v.is_empty() => spec.capacities = v,
        Ok(_) => {}
        Err(e) => return fail(&e),
    };
    match args.num_list::<usize>("horizons") {
        Ok(v) if !v.is_empty() => spec.horizons = v,
        Ok(_) => {}
        Err(e) => return fail(&e),
    };
    // --weeks N evaluates the first N weeks; --weeks w1,w2,… names specific
    // week indices (the learning chain still walks from week 0).
    if let Some(raw) = args.get("weeks") {
        if raw.contains(',') {
            match args.num_list::<usize>("weeks") {
                Ok(v) => spec.weeks = v,
                Err(e) => return fail(&e),
            }
        } else {
            match raw.trim().parse::<usize>() {
                Ok(0) => return fail("--weeks must be positive"),
                Ok(n) => spec.weeks = (0..n).collect(),
                Err(_) => return fail(&format!("invalid --weeks '{raw}'")),
            }
        }
    }
    match args.num_or::<usize>("aging-window", spec.aging_window_hours) {
        Ok(0) => return fail("--aging-window must be positive"),
        Ok(h) => spec.aging_window_hours = h,
        Err(e) => return fail(&e),
    }
    match parse_list(args, "seeds", |s| {
        s.parse::<u64>().map_err(|_| format!("invalid --seeds entry '{s}'"))
    }) {
        Ok(v) if !v.is_empty() => spec.seeds = v,
        Ok(_) => {}
        Err(e) => return fail(&e),
    };
    match parse_list(args, "faults", |s| {
        if carbonflex::faults::FaultSpec::preset(s).is_some() {
            Ok(s.to_string())
        } else {
            Err(format!("unknown fault preset '{s}' (none, light, heavy)"))
        }
    }) {
        Ok(v) if !v.is_empty() => spec.faults = v,
        Ok(_) => {}
        Err(e) => return fail(&e),
    };
    match parse_list(args, "dag-shapes", |s| {
        carbonflex::config::DagShape::parse(s)
            .map(|_| s.to_string())
            .map_err(|e| e.to_string())
    }) {
        Ok(v) if !v.is_empty() => spec.dag_shapes = v,
        Ok(_) => {}
        Err(e) => return fail(&e),
    };
    // --shard i/n runs the i-th of n contiguous slices of the point list;
    // concatenating the shards' rows in order reproduces the unsharded grid
    // bitwise (each cell is self-seeded, week chains walk from week 0).
    if let Some(raw) = args.get("shard") {
        let parsed = raw.split_once('/').and_then(|(i, n)| {
            Some((i.trim().parse::<usize>().ok()?, n.trim().parse::<usize>().ok()?))
        });
        match parsed {
            Some((i, n)) if n > 0 && i < n => spec.shard = Some((i, n)),
            Some((i, n)) => {
                return fail(&format!("--shard {i}/{n}: index must satisfy i < n, n > 0"))
            }
            None => return fail(&format!("invalid --shard '{raw}' (expected i/n, e.g. 0/4)")),
        }
    }

    let threads = match args.num_or::<usize>("threads", 0) {
        Ok(0) => sweep::auto_threads(),
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let rows = SweepRunner::new(threads).run(&spec);

    if args.flag("json") {
        let doc = Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("cells", Json::Num(rows.len() as f64)),
            ("wall_seconds", Json::Num(t0.elapsed().as_secs_f64())),
            ("rows", sweep::to_json(&rows)),
        ]);
        println!("{doc}");
    } else {
        sweep::print_table(&rows);
        println!("{} cells on {} threads in {:.2?}", rows.len(), threads, t0.elapsed());
    }

    if args.flag("check") {
        let mut bad = 0;
        for r in &rows {
            let m = &r.result.metrics;
            if m.unfinished > 0 || m.carbon_g <= 0.0 {
                eprintln!(
                    "check failed: {:?} {} — unfinished {}, carbon {:.1} g",
                    r.point, m.policy, m.unfinished, m.carbon_g
                );
                bad += 1;
            }
        }
        if bad > 0 {
            return fail(&format!("{bad} cell(s) failed the sanity check"));
        }
        println!("check passed: all {} cells drained with positive carbon", rows.len());
    }
    0
}

/// Hot-path benchmarks in machine-readable form: measure, write
/// `BENCH_hotpaths.json`, and (when a committed baseline exists) fail on
/// coarse regressions. See `benches/perf_hotpaths.rs` for the long-form
/// human bench including the PJRT backends.
fn cmd_bench(args: &Args) -> i32 {
    let t0 = std::time::Instant::now();
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let budget_ms = match args.num_or::<u64>("budget-ms", 2000) {
        Ok(b) => b.max(1),
        Err(e) => return fail(&e),
    };
    let report = perf::bench_hotpaths(&cfg, std::time::Duration::from_millis(budget_ms));
    let doc = report.to_json(t0.elapsed().as_secs_f64());

    if args.flag("json") {
        println!("{doc}");
    } else {
        for cell in &report.cells {
            match cell.slots_per_second {
                Some(sps) => println!("{}  ({sps:.0} slots/s)", cell.result),
                None => println!("{}", cell.result),
            }
        }
    }
    let out = args.get_or("out", "BENCH_hotpaths.json");
    if let Err(e) = std::fs::write(out, format!("{doc}\n")) {
        return fail(&format!("writing {out}: {e}"));
    }
    eprintln!("bench timings written to {out}");

    // Coarse regression guard against the committed baseline, if present.
    let baseline_path = args.get_or("baseline", "benches/baseline/BENCH_hotpaths.json");
    let max_ratio = match args.num_or::<f64>("max-regression", 3.0) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    match std::fs::read_to_string(baseline_path) {
        Err(_) => {
            eprintln!(
                "no committed baseline at {baseline_path}; skipping regression check \
                 (copy {out} there to start gating)"
            );
        }
        Ok(src) => match carbonflex::util::json::parse(&src) {
            Err(e) => return fail(&format!("parsing baseline {baseline_path}: {e}")),
            Ok(baseline) => {
                let violations = perf::regression_check(&doc, &baseline, max_ratio);
                if !violations.is_empty() {
                    for v in &violations {
                        eprintln!("perf regression: {v}");
                    }
                    return fail(&format!(
                        "{} cell(s) regressed more than {max_ratio:.1}x vs {baseline_path}",
                        violations.len()
                    ));
                }
                eprintln!(
                    "regression check passed: all cells within {max_ratio:.1}x of {baseline_path}"
                );
            }
        },
    }
    0
}

fn cmd_learn(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let prep = runner::PreparedExperiment::prepare(&cfg);
    let n_hist = prep.hist_jobs.len();
    let kb = prep.knowledge_base();
    println!("learned {} cases from {} historical jobs", kb.cases().len(), n_hist);
    if let Some(out) = args.get("out") {
        if let Err(e) = kb.save_csv(out) {
            return fail(&format!("saving {out}: {e}"));
        }
        println!("knowledge base written to {out}");
    }
    0
}

fn cmd_gen_traces(args: &Args) -> i32 {
    let region_key = args.get_or("region", "south-australia");
    let Some(region) = Region::parse(region_key) else {
        return fail(&format!(
            "unknown region '{region_key}'; known: {}",
            Region::ALL.map(|r| r.key()).join(", ")
        ));
    };
    let hours = match args.num_or::<usize>("hours", 8760) {
        Ok(h) => h,
        Err(e) => return fail(&e),
    };
    let seed = match args.num_or::<u64>("seed", 42) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let trace = synth::synthesize(region, hours, seed);
    let out = args.get_or("out", "trace.csv");
    if let Err(e) = carbonflex::carbon::io::save_csv(&trace, out) {
        return fail(&format!("saving {out}: {e}"));
    }
    println!(
        "wrote {} hours for {} (mean {:.0} g/kWh, daily CoV {:.2}) to {out}",
        hours,
        region.key(),
        trace.mean(),
        trace.daily_cov()
    );
    0
}

fn cmd_catalog() -> i32 {
    let mut table =
        Table::new(&["workload", "impl", "comm (MB)", "GFLOPs", "scalability", "W/unit"]);
    for w in profile::catalog() {
        table.row(&[
            w.name.to_string(),
            w.hardware.as_str().to_string(),
            format!("{:.2}", w.comm_mb),
            format!("{:.2}", w.gflops),
            w.scalability.as_str().to_string(),
            format!("{:.0}", w.watts_per_unit),
        ]);
    }
    table.print();
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(which) = args.positional.first() else {
        return fail(
            "experiment requires an id (fig2, fig5..fig14, overheads, yearlong, noise, spatial)",
        );
    };
    carbonflex::experiments::figures::run_by_name(which, args.get("config"))
}

/// Service knobs for `serve`/`serve-bench`: the optional `[service]` table
/// of `--config`, overridden by `--max-pending`, `--max-batch`, `--shed`.
fn load_service(args: &Args) -> Result<ServiceConfig, String> {
    let mut service = match args.get("config") {
        Some(path) => ServiceConfig::load(path).map_err(|e| e.to_string())?,
        None => ServiceConfig::default(),
    };
    service.max_pending = args.num_or("max-pending", service.max_pending)?;
    service.max_batch = args.num_or("max-batch", service.max_batch)?;
    if service.max_pending == 0 {
        return Err("--max-pending must be positive".into());
    }
    if service.max_batch == 0 {
        return Err("--max-batch must be positive".into());
    }
    if let Some(raw) = args.get("shed") {
        service.shed = ShedPolicy::parse(raw).ok_or_else(|| {
            format!(
                "unknown shed policy '{raw}' (valid: {})",
                ShedPolicy::ALL.map(|p| p.as_str()).join(", ")
            )
        })?;
    }
    Ok(service)
}

/// Resolve `--shards` (count or '+'-joined regions), defaulting to the
/// service config's shard count anchored at the experiment's region.
fn serve_regions(
    args: &Args,
    cfg: &ExperimentConfig,
    service: &ServiceConfig,
) -> Result<Vec<Region>, String> {
    let raw = args
        .get("shards")
        .map(str::to_string)
        .unwrap_or_else(|| service.shards.to_string());
    coordinator::shard_regions(&raw, &cfg.region)
}

fn serve_strategy(args: &Args) -> Result<DispatchStrategy, String> {
    let raw = args.get_or("dispatch", "rr");
    DispatchStrategy::parse(raw)
        .ok_or_else(|| format!("unknown dispatch strategy '{raw}' (rr, current, window)"))
}

fn cmd_serve(args: &Args) -> i32 {
    use carbonflex::coordinator::{ErrorCode, Request, Response, WireRequest, WireResponse};
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let service = match load_service(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let kind = match PolicyKind::parse_or_err(args.get_or("policy", "agnostic")) {
        Ok(k) => k,
        Err(e) => return fail(&e),
    };
    let regions = match serve_regions(args, &cfg, &service) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let strategy = match serve_strategy(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let mut cluster =
        coordinator::ShardedCoordinator::start(&cfg, &service, kind, &regions, strategy);
    // Deterministic fault injection: kill shard s once the N-th submission
    // arrives; the supervisor fails pending jobs over and restarts it.
    let kills = match parse_list(args, "kill-shard", |s| {
        s.split_once('@')
            .and_then(|(a, b)| {
                Some(carbonflex::faults::ShardKill {
                    shard: a.trim().parse().ok()?,
                    at_submission: b.trim().parse().ok()?,
                })
            })
            .ok_or_else(|| format!("invalid --kill-shard entry '{s}' (expected s@N, e.g. 0@50)"))
    }) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    if !kills.is_empty() {
        if cluster.num_shards() < 2 {
            return fail("--kill-shard needs at least 2 shards (a survivor to fail over to)");
        }
        cluster.set_kill_plan(&kills);
    }
    // --tcp: serve sessions over real sockets instead of stdio lines. The
    // session layer adds handshake/resume/dedup on top of the same wire
    // requests; a drain shuts the listener down.
    if let Some(addr) = args.get("tcp") {
        use carbonflex::coordinator::session::{take_cluster, SessionConfig, SessionServer};
        use carbonflex::coordinator::transport::{bind_tcp, serve_on, FrameHandler};
        use std::sync::{Arc, Mutex};
        let (listener, local) = match bind_tcp(addr) {
            Ok(x) => x,
            Err(e) => return fail(&format!("binding {addr}: {e}")),
        };
        let server =
            Arc::new(Mutex::new(SessionServer::new(cluster, SessionConfig::default())));
        let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
        eprintln!(
            "carbonflex coordinator listening on {local} (policy: {}, session protocol \
             over TCP: length-prefixed v2 frames, resume tokens, idempotent retry)",
            kind.key()
        );
        if let Err(e) = serve_on(listener, handler) {
            return fail(&format!("tcp serve failed: {e}"));
        }
        match take_cluster(server) {
            Some(c) => {
                c.shutdown();
            }
            None => return fail("session server still shared after serve"),
        }
        return 0;
    }
    eprintln!(
        "carbonflex coordinator ready (policy: {}, shards: {}, max_pending: {}, shed: {}); \
         JSON lines on stdin (protocol v2; un-versioned lines read as legacy v1)",
        kind.key(),
        cluster.num_shards(),
        service.max_pending,
        service.shed.as_str()
    );
    let bad_line = |code: ErrorCode, message: String, id: Option<String>| {
        let wire = WireResponse {
            v: carbonflex::coordinator::PROTOCOL_VERSION,
            id,
            resp: Response::Error { code, message },
        };
        println!("{}", wire.to_json_line());
    };
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        match stdin.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {}
            // A malformed byte sequence consumes the line; answer and keep
            // serving. Real I/O errors end the session.
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                bad_line(ErrorCode::BadRequest, "line is not valid UTF-8".into(), None);
                continue;
            }
            Err(_) => break,
        }
        if line.trim().is_empty() {
            continue;
        }
        match WireRequest::from_json_line(&line) {
            Ok(wire) => {
                let drain = matches!(wire.req, Request::Drain);
                let resp = cluster.handle_request(wire.req);
                let out = WireResponse { v: wire.v, id: wire.id, resp };
                println!("{}", out.to_json_line());
                if drain {
                    cluster.shutdown();
                    return 0;
                }
            }
            Err(pf) => bad_line(pf.code, pf.message, pf.id),
        }
    }
    // EOF without an explicit drain: drain for the caller, then report.
    if let Response::Drained { completed, carbon_g, .. } = cluster.drain() {
        eprintln!("coordinator done: {} jobs, {:.2} kg CO2", completed, carbon_g / 1000.0);
    }
    cluster.shutdown();
    0
}

/// Drive a TCP session server from the CLI: submit a generated trace one
/// job per request, optionally force a disconnect after `--drop-after`
/// submissions (the resume handshake must keep the session), tick once per
/// slot, and optionally drain. Non-zero exit if a forced drop did not
/// produce a surviving reconnect.
fn cmd_client(args: &Args) -> i32 {
    use carbonflex::coordinator::client::SessionClient;
    use carbonflex::coordinator::loadgen::submissions_of;
    use carbonflex::coordinator::transport::TcpTransport;
    use carbonflex::coordinator::{Request, Response};
    use carbonflex::workload::tracegen;
    let Some(addr) = args.get("tcp") else {
        return fail("client requires --tcp host:port");
    };
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let jobs = match args.num_or::<usize>("jobs", 8) {
        Ok(0) => return fail("--jobs must be positive"),
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let drop_after = match args.num_or::<usize>("drop-after", 0) {
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let seed = match args.num_or::<u64>("seed", cfg.seed) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let trace = tracegen::generate_n(&cfg, cfg.horizon_hours, seed, jobs);
    let arrivals = submissions_of(&trace);
    let mut client =
        SessionClient::new(Box::new(TcpTransport::new(addr)), "carbonflex-cli", seed);
    let (mut accepted, mut shed) = (0usize, 0usize);
    let mut slot = 0usize;
    for (i, (arrival, sub)) in arrivals.iter().enumerate() {
        if drop_after > 0 && i == drop_after {
            eprintln!("client: forcing a disconnect before submission {i}");
            client.force_disconnect();
        }
        // Advance the cluster clock to this job's arrival slot.
        while slot < *arrival {
            if let Err(e) = client.request(Request::Tick) {
                return fail(&format!("tick failed: {e}"));
            }
            slot += 1;
        }
        match client.request(Request::Submit(sub.clone())) {
            Ok(Response::Submitted { .. }) => accepted += 1,
            Ok(_) => shed += 1,
            Err(e) => return fail(&format!("submission {i} failed: {e}")),
        }
    }
    let mut drained = None;
    if args.flag("drain") {
        match client.request(Request::Drain) {
            Ok(Response::Drained { completed, carbon_g, .. }) => {
                drained = Some((completed, carbon_g));
            }
            Ok(other) => return fail(&format!("unexpected drain response: {other:?}")),
            Err(e) => return fail(&format!("drain failed: {e}")),
        }
    }
    client.bye();
    let st = client.stats();
    println!(
        "client: {accepted} accepted, {shed} shed of {} submitted; \
         reconnects {}, retries {}, handshakes {}",
        arrivals.len(),
        st.reconnects,
        st.retries,
        st.handshakes
    );
    if let Some((completed, carbon_g)) = drained {
        println!("drained: {} jobs, {:.2} kg CO2", completed, carbon_g / 1000.0);
    }
    if drop_after > 0 && st.reconnects == 0 {
        return fail("forced disconnect did not produce a reconnect");
    }
    0
}

fn cmd_serve_bench(args: &Args) -> i32 {
    use carbonflex::coordinator::{run_serve_bench, ServeBenchOpts};
    use carbonflex::util::bench::fmt_rate;
    let mut cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let service = match load_service(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let kind = match PolicyKind::parse_or_err(args.get_or("policy", "agnostic")) {
        Ok(k) => k,
        Err(e) => return fail(&e),
    };
    let horizon = match args.num_or::<usize>("horizon", cfg.horizon_hours) {
        Ok(0) => return fail("--horizon must be positive"),
        Ok(h) => h,
        Err(e) => return fail(&e),
    };
    // Keep the prepared traces long enough for the benched horizon.
    cfg.horizon_hours = cfg.horizon_hours.max(horizon);
    cfg.history_hours = cfg.history_hours.max(cfg.horizon_hours);
    let jobs = match args.num_or::<usize>("jobs", 2000) {
        Ok(0) => return fail("--jobs must be positive"),
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let seed = match args.num_or::<u64>("seed", cfg.seed) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let batch = match args.num_or::<usize>("batch", 64) {
        Ok(b) => b,
        Err(e) => return fail(&e),
    };
    let regions = match serve_regions(args, &cfg, &service) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let strategy = match serve_strategy(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };

    let opts = ServeBenchOpts { cfg, service, kind, jobs, horizon, seed, batch, regions, strategy };
    let (reports, doc) = run_serve_bench(&opts);

    if args.flag("json") {
        println!("{doc}");
    } else {
        let mut table = Table::new(&[
            "mode",
            "submissions/s",
            "p50 (ms)",
            "p99 (ms)",
            "shed %",
            "completed",
            "carbon (kg)",
        ]);
        for r in &reports {
            table.row(&[
                r.mode.clone(),
                fmt_rate(r.submissions_per_sec),
                format!("{:.3}", r.p50_decision_ms),
                format!("{:.3}", r.p99_decision_ms),
                format!("{:.1}", r.shed_rate * 100.0),
                format!("{}", r.completed),
                format!("{:.2}", r.carbon_g / 1000.0),
            ]);
        }
        table.print();
    }
    let identical = doc.get("reports_identical").and_then(Json::as_bool).unwrap_or(false);
    if !identical {
        eprintln!("warning: drain reports differ across ingest shapes (see modes in the JSON)");
    }
    let out = args.get_or("out", "BENCH_serve.json");
    if let Err(e) = std::fs::write(out, format!("{doc}\n")) {
        return fail(&format!("writing {out}: {e}"));
    }
    eprintln!("serve bench written to {out}");
    0
}

/// Fault-injection benchmark: clean vs faulted simulation plus a shard-kill
/// failover drive, written as `BENCH_chaos.json`. Exits non-zero when the
/// exactly-once drain identity fails — accepted work was lost or duplicated.
fn cmd_chaos_bench(args: &Args) -> i32 {
    use carbonflex::experiments::chaos::{run_chaos_bench, ChaosBenchOpts};
    let t0 = std::time::Instant::now();
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let service = match load_service(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let mut opts = ChaosBenchOpts::new(cfg, service);
    opts.preset = args.get_or("faults", "light").to_string();
    match PolicyKind::parse_or_err(args.get_or("policy", "carbonflex")) {
        Ok(k) => opts.kind = k,
        Err(e) => return fail(&e),
    }
    match PolicyKind::parse_or_err(args.get_or("serve-policy", "agnostic")) {
        Ok(k) => opts.serve_kind = k,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("jobs", opts.serve_jobs) {
        Ok(0) => return fail("--jobs must be positive"),
        Ok(n) => opts.serve_jobs = n,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("shards", opts.shards) {
        Ok(n) if n >= 2 => opts.shards = n,
        Ok(_) => return fail("--shards must be at least 2 (kills need a survivor)"),
        Err(e) => return fail(&e),
    }
    let report = match run_chaos_bench(&opts) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let doc = report.to_json(&opts, t0.elapsed().as_secs_f64());
    if args.flag("json") {
        println!("{doc}");
    } else {
        println!("preset:            {}", report.preset);
        println!(
            "carbon:            {:.2} kg clean, {:.2} kg faulted ({:+.2} %)",
            report.carbon_clean_g / 1000.0,
            report.carbon_faulted_g / 1000.0,
            report.carbon_overhead_pct
        );
        println!(
            "crashes:           {} restarts, {:.1} h lost work, recovery p50/p99 {:.0}/{:.0} slots",
            report.restarts, report.lost_work_hours, report.recovery_p50_slots,
            report.recovery_p99_slots
        );
        println!(
            "degradation:       {} stale slots, {} fallback slots",
            report.degraded_stale, report.degraded_fallback
        );
        println!(
            "failover:          {} kills, {} rerouted, {} shed ({:.1} % of failed-over)",
            report.failovers,
            report.rerouted,
            report.failover_shed,
            report.shed_during_failover_rate * 100.0
        );
        println!(
            "exactly-once:      {}",
            if report.drained_exactly_once { "ok" } else { "VIOLATED" }
        );
        println!(
            "session cell:      {} link events, {} reconnects, {} retries, {} dedup hits — {}",
            report.session_link_events,
            report.session_reconnects,
            report.session_retries,
            report.session_dedup_hits,
            if report.session_exactly_once { "ok" } else { "VIOLATED" }
        );
    }
    let out = args.get_or("out", "BENCH_chaos.json");
    if let Err(e) = std::fs::write(out, format!("{doc}\n")) {
        return fail(&format!("writing {out}: {e}"));
    }
    eprintln!("chaos bench written to {out}");
    if !report.drained_exactly_once {
        return fail("exactly-once drain identity violated: accepted work lost or duplicated");
    }
    if !report.session_exactly_once {
        return fail(
            "session exactly-once identity violated under combined shard kills + link faults",
        );
    }
    0
}

/// Session/transport benchmark: the stdio baseline against session legs
/// over clean loopback, a seeded link-fault plan, and real TCP — written
/// as `BENCH_net.json`. Exits non-zero when a fault-free leg diverges from
/// the stdio drain or the faulted leg breaks exactly-once.
fn cmd_net_bench(args: &Args) -> i32 {
    use carbonflex::experiments::net::{run_net_bench, NetBenchOpts};
    let t0 = std::time::Instant::now();
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let service = match load_service(args) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    let mut opts = NetBenchOpts::new(cfg, service);
    opts.preset = args.get_or("faults", "heavy").to_string();
    match PolicyKind::parse_or_err(args.get_or("policy", "agnostic")) {
        Ok(k) => opts.kind = k,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("jobs", opts.jobs) {
        Ok(0) => return fail("--jobs must be positive"),
        Ok(n) => opts.jobs = n,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("horizon", opts.horizon) {
        Ok(0) => return fail("--horizon must be positive"),
        Ok(h) => opts.horizon = h,
        Err(e) => return fail(&e),
    }
    match args.num_or::<u64>("seed", opts.cfg.seed) {
        Ok(s) => opts.seed = s,
        Err(e) => return fail(&e),
    }
    match args.num_or::<usize>("window", opts.window) {
        Ok(0) => return fail("--window must be positive"),
        Ok(w) => opts.window = w,
        Err(e) => return fail(&e),
    }
    opts.skip_tcp = args.flag("no-tcp");
    let report = match run_net_bench(&opts) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let doc = report.to_json(&opts, t0.elapsed().as_secs_f64());
    if args.flag("json") {
        println!("{doc}");
    } else {
        println!("preset:              {}", report.preset);
        println!(
            "stdio submit:        p50 {:.3} ms, p99 {:.3} ms",
            report.stdio.p50_decision_ms, report.stdio.p99_decision_ms
        );
        if let Some(t) = &report.tcp {
            println!(
                "tcp submit:          p50 {:.3} ms, p99 {:.3} ms",
                t.p50_decision_ms, t.p99_decision_ms
            );
        }
        println!(
            "faulted leg:         {} link events, {} reconnects, {} retries, {} dedup hits",
            report.plan_events, report.reconnects, report.retries, report.dedup_hits
        );
        println!(
            "fault-free identity: {}",
            if report.fault_free_identical { "ok" } else { "VIOLATED" }
        );
        println!(
            "exactly-once:        {}",
            if report.exactly_once { "ok" } else { "VIOLATED" }
        );
    }
    let out = args.get_or("out", "BENCH_net.json");
    if let Err(e) = std::fs::write(out, format!("{doc}\n")) {
        return fail(&format!("writing {out}: {e}"));
    }
    eprintln!("net bench written to {out}");
    if !report.fault_free_identical {
        return fail("fault-free session drain diverged from the stdio baseline");
    }
    if !report.exactly_once {
        return fail("exactly-once violated under the seeded link-fault plan");
    }
    0
}

fn fail(msg: &str) -> i32 {
    eprintln!("error: {msg}");
    1
}
