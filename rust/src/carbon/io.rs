//! CSV persistence for carbon traces (`hour,ci` rows with a header), so
//! synthesized traces can be exported, inspected, or replaced with real
//! ElectricityMaps exports of the same shape.

use std::io::Write;
use std::path::Path;

use crate::carbon::trace::CarbonTrace;

/// IO error for trace files.
#[derive(Debug)]
pub enum TraceIoError {
    Io(std::io::Error),
    Malformed(usize, String),
}

impl std::fmt::Display for TraceIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceIoError::Io(e) => write!(f, "io: {e}"),
            TraceIoError::Malformed(line, msg) => write!(f, "csv line {line}: {msg}"),
        }
    }
}

impl std::error::Error for TraceIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TraceIoError::Io(e) => Some(e),
            TraceIoError::Malformed(..) => None,
        }
    }
}

impl From<std::io::Error> for TraceIoError {
    fn from(e: std::io::Error) -> Self {
        TraceIoError::Io(e)
    }
}

/// Save a trace as `hour,carbon_intensity` CSV.
pub fn save_csv(trace: &CarbonTrace, path: impl AsRef<Path>) -> Result<(), TraceIoError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "hour,carbon_intensity")?;
    for (h, ci) in trace.hourly.iter().enumerate() {
        writeln!(f, "{h},{ci:.4}")?;
    }
    Ok(())
}

/// Load a trace saved by [`save_csv`] (or any `hour,ci` CSV; hours must be
/// contiguous from 0).
pub fn load_csv(region: &str, path: impl AsRef<Path>) -> Result<CarbonTrace, TraceIoError> {
    let src = std::fs::read_to_string(path)?;
    let mut hourly = Vec::new();
    for (i, line) in src.lines().enumerate() {
        if i == 0 && line.to_ascii_lowercase().starts_with("hour") {
            continue; // header
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let hour: usize = parts
            .next()
            .and_then(|s| s.trim().parse().ok())
            .ok_or_else(|| TraceIoError::Malformed(i + 1, format!("bad hour in '{line}'")))?;
        let ci: f64 = parts
            .next()
            .and_then(|s| s.trim().parse().ok())
            .ok_or_else(|| TraceIoError::Malformed(i + 1, format!("bad ci in '{line}'")))?;
        if hour != hourly.len() {
            return Err(TraceIoError::Malformed(
                i + 1,
                format!("non-contiguous hour {hour}, expected {}", hourly.len()),
            ));
        }
        if !(ci.is_finite() && ci >= 0.0) {
            return Err(TraceIoError::Malformed(i + 1, format!("invalid ci {ci}")));
        }
        hourly.push(ci);
    }
    Ok(CarbonTrace::new(region, hourly))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::synth::{synthesize, Region};

    #[test]
    fn roundtrip() {
        let t = synthesize(Region::Germany, 100, 1);
        let dir = std::env::temp_dir().join("carbonflex_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("germany.csv");
        save_csv(&t, &path).unwrap();
        let loaded = load_csv("germany", &path).unwrap();
        assert_eq!(loaded.len(), t.len());
        for i in 0..t.len() {
            assert!((loaded.at(i) - t.at(i)).abs() < 1e-3);
        }
    }

    #[test]
    fn rejects_gaps_and_garbage() {
        let dir = std::env::temp_dir().join("carbonflex_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad1 = dir.join("bad1.csv");
        std::fs::write(&bad1, "hour,carbon_intensity\n0,100\n2,200\n").unwrap();
        assert!(load_csv("x", &bad1).is_err());
        let bad2 = dir.join("bad2.csv");
        std::fs::write(&bad2, "hour,carbon_intensity\n0,not-a-number\n").unwrap();
        assert!(load_csv("x", &bad2).is_err());
        let bad3 = dir.join("bad3.csv");
        std::fs::write(&bad3, "hour,carbon_intensity\n0,-5.0\n").unwrap();
        assert!(load_csv("x", &bad3).is_err());
    }
}
