//! Carbon-intensity substrate: hourly traces, a parametric synthesizer for
//! the ten evaluation regions (calibrated to the paper's Fig. 5), day-ahead
//! forecasting, and CSV IO.

pub mod forecast;
pub mod io;
pub mod synth;
pub mod trace;

pub use forecast::Forecaster;
pub use synth::Region;
pub use trace::CarbonTrace;
