//! Hourly carbon-intensity time series.
//!
//! A [`CarbonTrace`] is the substrate every policy consumes: an hourly
//! sequence of grid carbon intensity in g·CO₂eq/kWh (paper §2.1). Slot `t`
//! indexes hours from the trace start.

use crate::util::stats;

/// Hourly carbon-intensity series for one region.
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonTrace {
    /// Region key (e.g. "south-australia").
    pub region: String,
    /// Carbon intensity per hour, g·CO₂eq/kWh.
    pub hourly: Vec<f64>,
}

impl CarbonTrace {
    pub fn new(region: impl Into<String>, hourly: Vec<f64>) -> Self {
        let trace = CarbonTrace { region: region.into(), hourly };
        debug_assert!(trace.hourly.iter().all(|&c| c >= 0.0 && c.is_finite()));
        trace
    }

    pub fn len(&self) -> usize {
        self.hourly.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hourly.is_empty()
    }

    /// CI at slot `t`; clamps to the last value if `t` runs past the end
    /// (keeps long feasibility-repair runs well-defined).
    pub fn at(&self, t: usize) -> f64 {
        if self.hourly.is_empty() {
            return 0.0;
        }
        let i = t.min(self.hourly.len() - 1);
        self.hourly[i]
    }

    /// Slice `[t, t+n)` clamped to the trace end (may be shorter than `n`).
    pub fn window(&self, t: usize, n: usize) -> &[f64] {
        if t >= self.hourly.len() {
            return &[];
        }
        let end = (t + n).min(self.hourly.len());
        &self.hourly[t..end]
    }

    /// Sub-trace starting at `offset` with length `n` (clamped).
    pub fn slice(&self, offset: usize, n: usize) -> CarbonTrace {
        CarbonTrace::new(self.region.clone(), self.window(offset, n).to_vec())
    }

    /// Mean CI over the whole trace.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.hourly)
    }

    /// Mean within-day coefficient of variation (Fig. 5's variability axis).
    pub fn daily_cov(&self) -> f64 {
        stats::daily_cov(&self.hourly)
    }

    /// p-th percentile of the window `[t, t+n)` — Wait Awhile's threshold
    /// uses the 30th percentile of the next 24 h.
    pub fn window_percentile(&self, t: usize, n: usize, p: f64) -> f64 {
        let w = self.window(t, n);
        if w.is_empty() {
            return self.at(t);
        }
        stats::percentile(w, p)
    }

    /// Rank (fraction in [0,1], 0 = cleanest hour) of slot `t` within the
    /// day-ahead window `[t, t+24)` — the CI^R state feature of Table 2.
    pub fn day_ahead_rank(&self, t: usize) -> f64 {
        let w = self.window(t, 24);
        stats::rank_fraction(self.at(t), w)
    }

    /// Signed gradient CI_t − CI_{t−1} (0 at t = 0) — the ∇CI feature.
    pub fn gradient(&self, t: usize) -> f64 {
        if t == 0 || self.hourly.is_empty() {
            return 0.0;
        }
        self.at(t) - self.at(t - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> CarbonTrace {
        CarbonTrace::new("test", vec![100.0, 200.0, 50.0, 300.0, 150.0])
    }

    #[test]
    fn indexing_and_clamping() {
        let t = trace();
        assert_eq!(t.at(0), 100.0);
        assert_eq!(t.at(4), 150.0);
        assert_eq!(t.at(99), 150.0); // clamps
    }

    #[test]
    fn windows() {
        let t = trace();
        assert_eq!(t.window(1, 2), &[200.0, 50.0]);
        assert_eq!(t.window(3, 10), &[300.0, 150.0]); // clamped
        assert!(t.window(99, 4).is_empty());
    }

    #[test]
    fn slice_roundtrip() {
        let t = trace();
        let s = t.slice(1, 3);
        assert_eq!(s.hourly, vec![200.0, 50.0, 300.0]);
        assert_eq!(s.region, "test");
    }

    #[test]
    fn gradient_signs() {
        let t = trace();
        assert_eq!(t.gradient(0), 0.0);
        assert_eq!(t.gradient(1), 100.0);
        assert_eq!(t.gradient(2), -150.0);
    }

    #[test]
    fn rank_in_window() {
        let t = trace();
        // at t=2 value 50 is the lowest of [50,300,150] → rank 0
        assert_eq!(t.day_ahead_rank(2), 0.0);
    }

    #[test]
    fn percentile_of_window() {
        let t = trace();
        let p0 = t.window_percentile(0, 5, 0.0);
        assert_eq!(p0, 50.0);
        let p100 = t.window_percentile(0, 5, 100.0);
        assert_eq!(p100, 300.0);
    }

    #[test]
    fn empty_trace_is_safe() {
        let t = CarbonTrace::new("e", vec![]);
        assert_eq!(t.at(3), 0.0);
        assert!(t.window(0, 5).is_empty());
        assert_eq!(t.gradient(2), 0.0);
    }
}
