//! Parametric carbon-intensity synthesizer for ten grid regions.
//!
//! **Substitution note (DESIGN.md §3):** the paper uses ElectricityMaps
//! hourly traces (Dec 2021 – Dec 2022) which are not redistributable; we
//! synthesize traces from a generative model with per-region parameters
//! calibrated to the (mean CI, daily CoV) scatter of the paper's Fig. 5 and
//! the qualitative shapes of Fig. 1:
//!
//! `CI(t) ∝ demand(t) · (1 − a_solar·duck(t)) · (1 − a_wind·wind(t))`
//!
//! - `duck(t)`: flat-bottomed midday solar depression (renewable-heavy
//!   grids: South Australia, California); deepens in summer.
//! - `evening(t)`: demand-driven evening peak (fossil-marginal grids).
//! - `weekly(t)`: weekday/weekend demand difference.
//! - `weather(t)`: slow AR(1) noise with ~2-day correlation (wind fronts).
//! - `jitter(t)`: small iid noise.
//!
//! Savings in the paper are "strictly a function of the carbon-intensity
//! variability" (§6.5), so matching (mean, CoV, diurnal structure) preserves
//! the result shape.

use crate::carbon::trace::CarbonTrace;
use crate::util::rng::Rng;

/// One of the ten evaluation regions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Region {
    SouthAustralia,
    California,
    Germany,
    Texas,
    GreatBritain,
    Netherlands,
    Ontario,
    Sweden,
    Virginia,
    India,
}

impl Region {
    /// All ten regions, in the paper's rough high→low savings order (Fig. 12).
    pub const ALL: [Region; 10] = [
        Region::SouthAustralia,
        Region::California,
        Region::Germany,
        Region::GreatBritain,
        Region::Netherlands,
        Region::Texas,
        Region::Ontario,
        Region::Sweden,
        Region::India,
        Region::Virginia,
    ];

    pub fn key(&self) -> &'static str {
        match self {
            Region::SouthAustralia => "south-australia",
            Region::California => "california",
            Region::Germany => "germany",
            Region::Texas => "texas",
            Region::GreatBritain => "great-britain",
            Region::Netherlands => "netherlands",
            Region::Ontario => "ontario",
            Region::Sweden => "sweden",
            Region::Virginia => "virginia",
            Region::India => "india",
        }
    }

    /// Parse a region key (as appears in configs).
    pub fn parse(s: &str) -> Option<Region> {
        Region::ALL.iter().copied().find(|r| r.key() == s)
    }

    /// Generative parameters for this region.
    #[rustfmt::skip] // keep each region's quantile table on one line
    pub fn params(&self) -> RegionParams {
        match self {
            // Renewable-heavy, very spiky: deep solar duck + strong wind noise.
            Region::SouthAustralia => RegionParams {
                mean: 250.0,
                solar_amp: 1.00,
                evening_amp: 0.22,
                weekly_amp: 0.05,
                weather_sigma: 0.10,
                wind_amp: 0.80,
                jitter_sigma: 0.07,
                floor: 10.0,
                quantiles: [10.0, 30.0, 55.0, 90.0, 135.0, 180.0, 245.0, 320.0, 420.0, 530.0, 660.0],
            },
            Region::California => RegionParams {
                mean: 230.0,
                solar_amp: 0.65,
                evening_amp: 0.16,
                weekly_amp: 0.04,
                weather_sigma: 0.08,
                wind_amp: 0.45,
                jitter_sigma: 0.04,
                floor: 55.0,
                quantiles: [55.0, 90.0, 110.0, 135.0, 165.0, 200.0, 245.0, 290.0, 340.0, 400.0, 480.0],
            },
            Region::Germany => RegionParams {
                mean: 380.0,
                solar_amp: 0.38,
                evening_amp: 0.13,
                weekly_amp: 0.08,
                weather_sigma: 0.10,
                wind_amp: 0.55,
                jitter_sigma: 0.04,
                floor: 120.0,
                quantiles: [120.0, 190.0, 240.0, 290.0, 330.0, 370.0, 420.0, 470.0, 520.0, 580.0, 680.0],
            },
            Region::GreatBritain => RegionParams {
                mean: 220.0,
                solar_amp: 0.22,
                evening_amp: 0.20,
                weekly_amp: 0.06,
                weather_sigma: 0.10,
                wind_amp: 0.55,
                jitter_sigma: 0.04,
                floor: 60.0,
                quantiles: [60.0, 110.0, 140.0, 170.0, 200.0, 225.0, 255.0, 285.0, 320.0, 370.0, 450.0],
            },
            Region::Netherlands => RegionParams {
                mean: 350.0,
                solar_amp: 0.24,
                evening_amp: 0.15,
                weekly_amp: 0.06,
                weather_sigma: 0.08,
                wind_amp: 0.45,
                jitter_sigma: 0.04,
                floor: 180.0,
                quantiles: [180.0, 240.0, 280.0, 310.0, 335.0, 355.0, 380.0, 410.0, 440.0, 480.0, 550.0],
            },
            Region::Texas => RegionParams {
                mean: 400.0,
                solar_amp: 0.16,
                evening_amp: 0.15,
                weekly_amp: 0.04,
                weather_sigma: 0.06,
                wind_amp: 0.35,
                jitter_sigma: 0.03,
                floor: 220.0,
                quantiles: [220.0, 290.0, 330.0, 360.0, 385.0, 405.0, 425.0, 450.0, 475.0, 510.0, 570.0],
            },
            // Hydro/nuclear grids: low mean, little variation.
            Region::Ontario => RegionParams {
                mean: 35.0,
                solar_amp: 0.06,
                evening_amp: 0.14,
                weekly_amp: 0.04,
                weather_sigma: 0.05,
                wind_amp: 0.10,
                jitter_sigma: 0.03,
                floor: 15.0,
                quantiles: [15.0, 22.0, 26.0, 29.0, 32.0, 35.0, 38.0, 42.0, 46.0, 52.0, 65.0],
            },
            Region::Sweden => RegionParams {
                mean: 25.0,
                solar_amp: 0.02,
                evening_amp: 0.07,
                weekly_amp: 0.03,
                weather_sigma: 0.04,
                wind_amp: 0.05,
                jitter_sigma: 0.02,
                floor: 10.0,
                quantiles: [10.0, 15.0, 18.0, 21.0, 23.0, 25.0, 27.0, 29.0, 32.0, 36.0, 45.0],
            },
            // Fossil-baseload grids: high mean, flat (85% non-variable in VA).
            Region::Virginia => RegionParams {
                mean: 380.0,
                solar_amp: 0.02,
                evening_amp: 0.04,
                weekly_amp: 0.02,
                weather_sigma: 0.02,
                wind_amp: 0.02,
                jitter_sigma: 0.02,
                floor: 330.0,
                quantiles: [330.0, 355.0, 365.0, 372.0, 378.0, 382.0, 387.0, 392.0, 398.0, 406.0, 430.0],
            },
            Region::India => RegionParams {
                mean: 630.0,
                solar_amp: 0.04,
                evening_amp: 0.04,
                weekly_amp: 0.02,
                weather_sigma: 0.03,
                wind_amp: 0.03,
                jitter_sigma: 0.02,
                floor: 560.0,
                quantiles: [560.0, 600.0, 615.0, 625.0, 632.0, 638.0, 645.0, 652.0, 660.0, 672.0, 700.0],
            },
        }
    }
}

/// Generative-model parameters (relative amplitudes unless noted).
#[derive(Debug, Clone, Copy)]
pub struct RegionParams {
    /// Annual mean CI, g·CO₂eq/kWh.
    pub mean: f64,
    /// Depth of the midday solar depression.
    pub solar_amp: f64,
    /// Height of the evening demand peak.
    pub evening_amp: f64,
    /// Weekday/weekend modulation.
    pub weekly_amp: f64,
    /// AR(1) weather-noise stddev.
    pub weather_sigma: f64,
    /// Wind-generation depth: multiplicative CI reduction during windy
    /// spells (multi-day correlated). Wind-heavy grids (SA, DE, GB) are
    /// clean around the clock when fronts pass — not just at solar noon.
    pub wind_amp: f64,
    /// iid jitter stddev.
    pub jitter_sigma: f64,
    /// Hard lower bound on CI (g·CO₂eq/kWh) — equals the p0 quantile.
    pub floor: f64,
    /// Reference CI distribution (p0, p10, …, p100) the generative model is
    /// calibrated against — approximate 2022 per-region shapes. Used by
    /// calibration tests, not by the generator itself.
    pub quantiles: [f64; 11],
}

/// Midday solar depression: ≈ 0 at night, −1 across a wide plateau around
/// solar noon. High-penetration solar grids (SA, CAISO) pin midday CI near
/// the floor for 5–7 hours — the flat-bottomed duck curve — not a narrow dip.
fn duck(hour_of_day: f64) -> f64 {
    // Raised-cosine window over 07:00–19:00, overdriven ×1.6 and clamped so
    // the bottom flattens at −1 for ≈ 5.5 h.
    if !(7.0..=19.0).contains(&hour_of_day) {
        return 0.0;
    }
    let x = (hour_of_day - 13.0) / 6.0; // −1..1 across the window
    -(1.6 * 0.5 * (1.0 + (std::f64::consts::PI * x).cos())).min(1.0)
}

/// Evening demand peak centered at 19:00, morning shoulder at 08:00.
fn evening(hour_of_day: f64) -> f64 {
    let bump = |center: f64, width: f64, h: f64| {
        let d = (h - center) / width;
        (-0.5 * d * d).exp()
    };
    0.8 * bump(19.0, 2.5, hour_of_day) + 0.4 * bump(8.0, 2.0, hour_of_day) - 0.35
}

/// Weekly modulation: +1 weekdays, −1 weekend (smoothed at boundaries).
fn weekly(hour: usize) -> f64 {
    let day = (hour / 24) % 7;
    if day < 5 {
        1.0
    } else {
        -1.0
    }
}

/// Synthesize `hours` of hourly CI for `region`, deterministically from `seed`.
pub fn synthesize(region: Region, hours: usize, seed: u64) -> CarbonTrace {
    let p = region.params();
    // Per-region stream so regions are independent but reproducible.
    let mut rng = Rng::new(seed ^ fnv1a(region.key()));
    let mut weather = 0.0f64;
    // AR(1) with ~48 h correlation time: x' = ρx + σ√(1−ρ²)·ε
    let rho: f64 = (-1.0f64 / 48.0).exp();
    let innovation = p.weather_sigma * (1.0 - rho * rho).sqrt();
    // Wind process: unit-variance AR(1) (~36 h fronts) squashed to [0, 1].
    let mut wind_state = 0.0f64;
    let wind_rho: f64 = (-1.0f64 / 36.0).exp();
    let wind_innov = (1.0 - wind_rho * wind_rho).sqrt();

    // Center the additive demand components so normalization is stable.
    let evening_mean: f64 = (0..24).map(|h| evening(h as f64)).sum::<f64>() / 24.0;
    let weekly_mean: f64 = 3.0 / 7.0;

    // Multiplicative composition: CI ∝ demand(t) · (1 − solar(t)) · (1 − wind(t)).
    // Solar displaces fossil generation *unconditionally* every day (deep
    // midday valleys even in calm weeks); wind fronts scale the whole curve
    // down for days at a time. This is what makes renewable-heavy grids
    // deeply bimodal (paper Fig. 1's South Australia panel).
    let mut hourly = Vec::with_capacity(hours);
    for t in 0..hours {
        let hod = (t % 24) as f64;
        weather = rho * weather + innovation * rng.normal();
        wind_state = wind_rho * wind_state + wind_innov * rng.normal();
        // Logistic squash → windiness in (0, 1), mean ≈ 0.5.
        let windiness = 1.0 / (1.0 + (-1.7 * wind_state).exp());
        // Seasonal solar strength: ±25% over the year (peak mid-trace).
        let season = 1.0 + 0.25 * (std::f64::consts::TAU * t as f64 / 8760.0).sin();
        let demand = (1.0
            + p.evening_amp * (evening(hod) - evening_mean)
            + p.weekly_amp * (weekly(t) - weekly_mean)
            + weather
            + p.jitter_sigma * rng.normal())
        .max(0.05);
        let solar_term = (1.0 - (p.solar_amp * season).min(0.97) * (-duck(hod))).max(0.03);
        let wind_term = (1.0 - p.wind_amp * windiness).max(0.05);
        hourly.push(demand * solar_term * wind_term);
    }
    // Normalize the mean to the regional target and clamp at the floor.
    let raw_mean = hourly.iter().sum::<f64>() / hourly.len().max(1) as f64;
    let scale = p.mean / raw_mean.max(1e-9);
    for v in hourly.iter_mut() {
        *v = (*v * scale).max(p.floor);
    }
    CarbonTrace::new(region.key(), hourly)
}

/// Synthesize a full year (8760 h).
pub fn synthesize_year(region: Region, seed: u64) -> CarbonTrace {
    synthesize(region, 8760, seed)
}

/// FNV-1a hash for stable per-region seed derivation.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = synthesize(Region::California, 500, 1);
        let b = synthesize(Region::California, 500, 1);
        assert_eq!(a, b);
        let c = synthesize(Region::California, 500, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn mean_matches_target() {
        for region in Region::ALL {
            let t = synthesize_year(region, 7);
            let target = region.params().mean;
            let err = (t.mean() - target).abs() / target;
            assert!(err < 0.12, "{}: mean {} vs target {}", region.key(), t.mean(), target);
        }
    }

    #[test]
    fn variability_ordering_matches_fig5() {
        // High-renewable regions must be much more variable than baseload ones.
        let sa = synthesize_year(Region::SouthAustralia, 3).daily_cov();
        let ca = synthesize_year(Region::California, 3).daily_cov();
        let va = synthesize_year(Region::Virginia, 3).daily_cov();
        let on = synthesize_year(Region::Ontario, 3).daily_cov();
        assert!(sa > ca, "SA {sa} vs CA {ca}");
        assert!(ca > va, "CA {ca} vs VA {va}");
        assert!(sa > 0.20, "SA CoV too low: {sa}");
        assert!(va < 0.08, "VA CoV too high: {va}");
        assert!(on < 0.15, "Ontario CoV too high: {on}");
    }

    #[test]
    fn positive_and_floored() {
        for region in [Region::SouthAustralia, Region::Sweden] {
            let t = synthesize_year(region, 5);
            let floor = region.params().floor;
            assert!(t.hourly.iter().all(|&c| c >= floor), "{} went below floor", region.key());
        }
    }

    #[test]
    fn solar_region_has_midday_dip() {
        let t = synthesize_year(Region::SouthAustralia, 11);
        // Average by hour-of-day over the year.
        let mut by_hod = [0.0f64; 24];
        let mut counts = [0usize; 24];
        for (i, &c) in t.hourly.iter().enumerate() {
            by_hod[i % 24] += c;
            counts[i % 24] += 1;
        }
        for h in 0..24 {
            by_hod[h] /= counts[h] as f64;
        }
        let midday = (by_hod[12] + by_hod[13]) / 2.0;
        let night = (by_hod[2] + by_hod[3]) / 2.0;
        assert!(midday < night * 0.75, "no duck curve: midday {midday} night {night}");
    }

    #[test]
    fn region_parse_roundtrip() {
        for r in Region::ALL {
            assert_eq!(Region::parse(r.key()), Some(r));
        }
        assert_eq!(Region::parse("atlantis"), None);
    }

    #[test]
    fn requested_length() {
        assert_eq!(synthesize(Region::Texas, 123, 9).len(), 123);
        assert_eq!(synthesize_year(Region::Texas, 9).len(), 8760);
    }
}
