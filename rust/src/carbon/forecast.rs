//! Day-ahead carbon-intensity forecasts.
//!
//! The paper assumes a carbon-information service (ElectricityMaps) with
//! accurate day-ahead forecasts (footnote 3, citing CarbonCast). We model a
//! forecast as the true future window plus optional multiplicative noise, so
//! experiments can probe forecast-error sensitivity.

use crate::carbon::trace::CarbonTrace;
use crate::faults::SignalOutage;
use crate::util::rng::Rng;
use crate::util::stats;

/// Availability of the carbon signal at a slot — the input to CarbonFlex's
/// degradation ladder (see `crate::faults`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignalState {
    /// Signal is live: forecasts for this slot are trustworthy.
    Fresh,
    /// Signal is out, but a last-known-good slot exists within the bounded
    /// staleness window — decide as if it were still slot `last_good`.
    Stale { last_good: usize },
    /// Signal is out and too stale (or never seen) — fall back to the
    /// carbon-agnostic policy.
    Dark,
}

/// Day-ahead forecast provider over a ground-truth trace.
#[derive(Debug, Clone)]
pub struct Forecaster {
    truth: CarbonTrace,
    /// Relative (multiplicative) forecast noise σ; 0 = perfect forecast.
    noise_sigma: f64,
    /// Pre-drawn noise per hour so repeated queries are consistent.
    noise: Vec<f64>,
    /// Fault injection: `outage_mask[t] == true` means the signal is out at
    /// slot `t`. Empty (the constructors' default) = always fresh, so every
    /// existing call path is untouched bit for bit.
    outage_mask: Vec<bool>,
    /// Bounded-staleness knob: how many slots a last-known-good forecast
    /// may be reused before the ladder drops to the carbon-agnostic rung.
    max_stale: usize,
}

impl Forecaster {
    /// Perfect day-ahead forecast (the paper's assumption).
    pub fn perfect(truth: CarbonTrace) -> Self {
        Forecaster { noise_sigma: 0.0, noise: vec![], truth, outage_mask: vec![], max_stale: 0 }
    }

    /// Noisy forecast with relative error σ (e.g. 0.05 ≈ CarbonCast-level).
    pub fn noisy(truth: CarbonTrace, sigma: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let noise = (0..truth.len()).map(|_| 1.0 + sigma * rng.normal()).collect();
        Forecaster { noise_sigma: sigma, noise, truth, outage_mask: vec![], max_stale: 0 }
    }

    /// Overlay signal outages from a fault plan: during `[start, start+len)`
    /// the signal reads as out, and [`Forecaster::signal_state`] walks the
    /// degradation ladder with staleness bound `max_stale`.
    pub fn with_outages(
        mut self,
        outages: &[SignalOutage],
        max_stale: usize,
        horizon: usize,
    ) -> Self {
        if outages.is_empty() {
            return self;
        }
        let len = outages
            .iter()
            .map(|o| o.start.saturating_add(o.len))
            .max()
            .unwrap_or(0)
            .max(horizon);
        let mut mask = vec![false; len];
        for o in outages {
            for slot in mask.iter_mut().skip(o.start).take(o.len) {
                *slot = true;
            }
        }
        self.outage_mask = mask;
        self.max_stale = max_stale;
        self
    }

    /// Degradation-ladder state of the signal at slot `t`. Fresh whenever no
    /// outage covers `t` (always, if no outages were overlaid).
    pub fn signal_state(&self, t: usize) -> SignalState {
        if t >= self.outage_mask.len() || !self.outage_mask[t] {
            return SignalState::Fresh;
        }
        // Scan back for the last fresh slot, bounded by the staleness knob.
        let mut u = t;
        while u > 0 && t - u < self.max_stale {
            u -= 1;
            if !self.outage_mask[u] {
                return SignalState::Stale { last_good: u };
            }
        }
        SignalState::Dark
    }

    pub fn noise_sigma(&self) -> f64 {
        self.noise_sigma
    }

    /// Forecast CI for slot `t` (as seen from any slot ≤ t).
    pub fn predict(&self, t: usize) -> f64 {
        let base = self.truth.at(t);
        if self.noise_sigma == 0.0 || self.noise.is_empty() {
            return base;
        }
        let i = t.min(self.noise.len() - 1);
        (base * self.noise[i]).max(1.0)
    }

    /// Forecast window `[t, t+n)`.
    pub fn predict_window(&self, t: usize, n: usize) -> Vec<f64> {
        (t..t + n).map(|i| self.predict(i)).collect()
    }

    /// Rank of slot `t` within its day-ahead window (Table 2's CI^R): 0 means
    /// the current slot is forecast to be the cleanest of the next 24 h.
    /// §Perf: counts directly instead of materializing the forecast window —
    /// this sits on CarbonFlex's per-slot state path, which must stay
    /// allocation-free (`rust/tests/zero_alloc.rs`). Same arithmetic as
    /// `stats::rank_fraction` over `predict_window(t, 24)`, bit for bit.
    pub fn day_ahead_rank(&self, t: usize) -> f64 {
        let x = self.predict(t);
        let below = (t..t + 24).filter(|&i| self.predict(i) < x).count();
        below as f64 / 24.0
    }

    /// p-th percentile of the next-24h forecast — Wait Awhile's threshold.
    pub fn day_ahead_percentile(&self, t: usize, p: f64) -> f64 {
        let w = self.predict_window(t, 24);
        stats::percentile(&w, p)
    }

    /// Access the underlying ground truth (for accounting, never for
    /// policy decisions in online schedulers).
    pub fn truth(&self) -> &CarbonTrace {
        &self.truth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::synth::{synthesize, Region};

    #[test]
    fn perfect_forecast_is_truth() {
        let t = synthesize(Region::California, 200, 1);
        let f = Forecaster::perfect(t.clone());
        for i in 0..200 {
            assert_eq!(f.predict(i), t.at(i));
        }
    }

    #[test]
    fn noisy_forecast_bounded_error() {
        let t = synthesize(Region::California, 2000, 2);
        let f = Forecaster::noisy(t.clone(), 0.05, 3);
        let mut rel_errs = Vec::new();
        for i in 0..2000 {
            rel_errs.push((f.predict(i) - t.at(i)).abs() / t.at(i));
        }
        let mean_err = stats::mean(&rel_errs);
        assert!(mean_err > 0.01 && mean_err < 0.10, "mean rel err {mean_err}");
    }

    #[test]
    fn noisy_is_consistent_across_queries() {
        let t = synthesize(Region::Texas, 100, 4);
        let f = Forecaster::noisy(t, 0.1, 5);
        assert_eq!(f.predict(42), f.predict(42));
    }

    #[test]
    fn rank_detects_cleanest_hour() {
        let hourly: Vec<f64> = (0..48).map(|i| if i == 10 { 10.0 } else { 100.0 }).collect();
        let f = Forecaster::perfect(CarbonTrace::new("x", hourly));
        assert_eq!(f.day_ahead_rank(10), 0.0);
        // Slot 9's window still contains the clean hour → its own rank > 0.
        assert!(f.day_ahead_rank(9) > 0.0);
    }

    #[test]
    fn signal_state_ladder() {
        let trace = CarbonTrace::new("x", vec![100.0; 48]);
        // No outages overlaid → always fresh.
        let clean = Forecaster::perfect(trace.clone());
        assert_eq!(clean.signal_state(0), SignalState::Fresh);
        assert_eq!(clean.signal_state(1000), SignalState::Fresh);
        // Outage over [10, 20) with staleness bound 4.
        let outage = SignalOutage { start: 10, len: 10 };
        let f = Forecaster::perfect(trace.clone()).with_outages(&[outage], 4, 48);
        assert_eq!(f.signal_state(9), SignalState::Fresh);
        assert_eq!(f.signal_state(10), SignalState::Stale { last_good: 9 });
        assert_eq!(f.signal_state(13), SignalState::Stale { last_good: 9 });
        // t=14: last good slot 9 is 5 slots back > max_stale 4 → dark.
        assert_eq!(f.signal_state(14), SignalState::Dark);
        assert_eq!(f.signal_state(19), SignalState::Dark);
        assert_eq!(f.signal_state(20), SignalState::Fresh);
        // Outage from slot 0: no last-known-good exists at all → dark.
        let from_zero = Forecaster::perfect(trace)
            .with_outages(&[SignalOutage { start: 0, len: 5 }], 8, 48);
        assert_eq!(from_zero.signal_state(0), SignalState::Dark);
        assert_eq!(from_zero.signal_state(3), SignalState::Dark);
        assert_eq!(from_zero.signal_state(5), SignalState::Fresh);
    }

    #[test]
    fn percentile_threshold() {
        let hourly: Vec<f64> = (1..=24).map(|i| i as f64 * 10.0).collect();
        let f = Forecaster::perfect(CarbonTrace::new("x", hourly));
        let p30 = f.day_ahead_percentile(0, 30.0);
        assert!(p30 > 60.0 && p30 < 90.0, "p30 {p30}");
    }
}
