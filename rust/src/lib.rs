//! # CarbonFlex
//!
//! A from-scratch reproduction of *CarbonFlex: Enabling Carbon-aware
//! Provisioning and Scheduling for Cloud Clusters* (Hanafy et al., 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the coordinator: cluster simulator, the
//!   offline oracle (Alg. 1), runtime provisioning (Alg. 2) and scheduling
//!   (Alg. 3), five baseline policies, the case-based-reasoning knowledge
//!   base, trace synthesizers, and energy/carbon accounting.
//! - **Layer 2 (JAX, `python/compile/model.py`)** — the state-match and
//!   oracle-score compute graphs, AOT-lowered to HLO text.
//! - **Layer 1 (Pallas, `python/compile/kernels/`)** — tiled distance and
//!   score kernels called from Layer 2.
//!
//! The Rust binary loads the AOT artifacts via PJRT (`runtime::engine`) and
//! never invokes Python at runtime.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod carbon;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod learning;
pub mod runtime;
pub mod sched;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::carbon::{synth::Region, trace::CarbonTrace};
    pub use crate::cluster::metrics::RunMetrics;
    pub use crate::cluster::sim::Simulator;
    pub use crate::config::{ExperimentConfig, Hardware, TraceFamily};
    pub use crate::sched::{Policy, PolicyKind};
    pub use crate::util::rng::Rng;
    pub use crate::workload::{job::Job, tracegen};
}
