//! Deterministic fault injection: seeded plans of slot crashes,
//! carbon-signal outages, and coordinator shard kills.
//!
//! A [`FaultSpec`] describes *how much* chaos to inject (event counts and
//! ranges); [`FaultPlan::generate`] expands it into a concrete, fully
//! reproducible event list from `(seed, spec, horizon, capacity, shards)`
//! via the crate RNG. Three independent forked sub-streams (crashes,
//! outages, shard kills) keep each event family's draw sequence stable
//! when the other families' counts change.
//!
//! The cardinal contract: an **empty plan injects nothing**. Every
//! consumer guards its fault logic behind [`FaultPlan::is_empty`], so a
//! fault-free run executes the exact instruction sequence it did before
//! this module existed — golden fingerprints stay bitwise identical.

use crate::config::toml::{self, Value};
use crate::util::rng::Rng;

pub mod net;

/// How much chaos to inject. Counts of three event families plus the
/// ranges their parameters are drawn from; all-zero counts mean "no
/// faults". Ships with named presets (`none`, `light`, `heavy`) usable as
/// sweep-axis values, and parses from an optional `[faults]` TOML table.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Number of slot-crash events over the horizon.
    pub slot_crashes: usize,
    /// Fraction of max capacity taken down per crash, drawn from
    /// `[crash_frac_min, crash_frac_max]`.
    pub crash_frac_min: f64,
    pub crash_frac_max: f64,
    /// Repair time in slots per crash, drawn from `[repair_min, repair_max]`
    /// (inclusive; clamped to at least 1).
    pub repair_min: usize,
    pub repair_max: usize,
    /// Progress a suspended victim loses at crash onset, hours (capped at
    /// the work it has actually done).
    pub rework_hours: f64,
    /// Number of carbon-signal outages.
    pub signal_outages: usize,
    /// Outage length in slots, drawn from `[outage_min, outage_max]`.
    pub outage_min: usize,
    pub outage_max: usize,
    /// Degradation-ladder knob: a last-known-good forecast older than this
    /// many slots is unusable and the policy falls through to the
    /// carbon-agnostic rung.
    pub max_stale_slots: usize,
    /// Number of coordinator shard kills (capped so at least one shard
    /// survives; ignored for single-shard deployments).
    pub shard_kills: usize,
    /// Fleet-wide submission count at which each kill fires, drawn from
    /// `[kill_after_min, kill_after_max]`.
    pub kill_after_min: u64,
    pub kill_after_max: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

impl FaultSpec {
    /// Preset names accepted by [`FaultSpec::preset`] and the sweep's
    /// `faults` axis.
    pub const PRESETS: [&'static str; 3] = ["none", "light", "heavy"];

    /// No faults at all; generates an empty plan.
    pub fn none() -> FaultSpec {
        FaultSpec {
            slot_crashes: 0,
            crash_frac_min: 0.0,
            crash_frac_max: 0.0,
            repair_min: 0,
            repair_max: 0,
            rework_hours: 0.0,
            signal_outages: 0,
            outage_min: 0,
            outage_max: 0,
            max_stale_slots: 6,
            shard_kills: 0,
            kill_after_min: 0,
            kill_after_max: 0,
        }
    }

    /// A mild failure regime: a couple of partial-capacity crashes, one
    /// short signal outage, one shard kill.
    pub fn light() -> FaultSpec {
        FaultSpec {
            slot_crashes: 2,
            crash_frac_min: 0.10,
            crash_frac_max: 0.25,
            repair_min: 2,
            repair_max: 6,
            rework_hours: 1.0,
            signal_outages: 1,
            outage_min: 4,
            outage_max: 12,
            max_stale_slots: 6,
            shard_kills: 1,
            kill_after_min: 32,
            kill_after_max: 96,
        }
    }

    /// An aggressive regime: repeated deep crashes, long outages with a
    /// tight staleness bound, multiple shard kills.
    pub fn heavy() -> FaultSpec {
        FaultSpec {
            slot_crashes: 6,
            crash_frac_min: 0.25,
            crash_frac_max: 0.50,
            repair_min: 4,
            repair_max: 12,
            rework_hours: 2.0,
            signal_outages: 3,
            outage_min: 12,
            outage_max: 24,
            max_stale_slots: 4,
            shard_kills: 2,
            kill_after_min: 16,
            kill_after_max: 128,
        }
    }

    /// Look up a named preset.
    pub fn preset(name: &str) -> Option<FaultSpec> {
        match name.to_ascii_lowercase().as_str() {
            "none" => Some(FaultSpec::none()),
            "light" => Some(FaultSpec::light()),
            "heavy" => Some(FaultSpec::heavy()),
            _ => None,
        }
    }

    /// Parse the optional `[faults]` table from TOML source. `preset`
    /// names a baseline (default `none`); the remaining keys override
    /// individual fields, so a config can say `preset = "light"` and then
    /// tighten just `max_stale_slots`.
    pub fn from_toml_str(src: &str) -> Result<FaultSpec, String> {
        let root = toml::parse(src).map_err(|e| e.to_string())?;
        let mut spec = match root.get_path("faults.preset") {
            Some(v) => {
                let name =
                    v.as_str().ok_or_else(|| "faults.preset: expected string".to_string())?;
                FaultSpec::preset(name).ok_or_else(|| {
                    format!(
                        "faults.preset: unknown preset '{name}' (valid: {})",
                        FaultSpec::PRESETS.join(", ")
                    )
                })?
            }
            None => FaultSpec::none(),
        };
        if let Some(v) = root.get_path("faults.slot_crashes") {
            spec.slot_crashes = count_field(v, "faults.slot_crashes")?;
        }
        if let Some(v) = root.get_path("faults.crash_frac_min") {
            spec.crash_frac_min = frac_field(v, "faults.crash_frac_min")?;
        }
        if let Some(v) = root.get_path("faults.crash_frac_max") {
            spec.crash_frac_max = frac_field(v, "faults.crash_frac_max")?;
        }
        if let Some(v) = root.get_path("faults.repair_min") {
            spec.repair_min = count_field(v, "faults.repair_min")?;
        }
        if let Some(v) = root.get_path("faults.repair_max") {
            spec.repair_max = count_field(v, "faults.repair_max")?;
        }
        if let Some(v) = root.get_path("faults.rework_hours") {
            spec.rework_hours = nonneg_field(v, "faults.rework_hours")?;
        }
        if let Some(v) = root.get_path("faults.signal_outages") {
            spec.signal_outages = count_field(v, "faults.signal_outages")?;
        }
        if let Some(v) = root.get_path("faults.outage_min") {
            spec.outage_min = count_field(v, "faults.outage_min")?;
        }
        if let Some(v) = root.get_path("faults.outage_max") {
            spec.outage_max = count_field(v, "faults.outage_max")?;
        }
        if let Some(v) = root.get_path("faults.max_stale_slots") {
            spec.max_stale_slots = count_field(v, "faults.max_stale_slots")?;
        }
        if let Some(v) = root.get_path("faults.shard_kills") {
            spec.shard_kills = count_field(v, "faults.shard_kills")?;
        }
        if let Some(v) = root.get_path("faults.kill_after_min") {
            spec.kill_after_min = count_field(v, "faults.kill_after_min")? as u64;
        }
        if let Some(v) = root.get_path("faults.kill_after_max") {
            spec.kill_after_max = count_field(v, "faults.kill_after_max")? as u64;
        }
        Ok(spec)
    }
}

fn count_field(v: &Value, field: &str) -> Result<usize, String> {
    match v.as_int() {
        Some(i) if i >= 0 => Ok(i as usize),
        _ => Err(format!("{field}: expected non-negative integer")),
    }
}

fn frac_field(v: &Value, field: &str) -> Result<f64, String> {
    match v.as_f64() {
        Some(f) if (0.0..=1.0).contains(&f) => Ok(f),
        _ => Err(format!("{field}: expected number in [0, 1]")),
    }
}

fn nonneg_field(v: &Value, field: &str) -> Result<f64, String> {
    match v.as_f64() {
        Some(f) if f >= 0.0 => Ok(f),
        _ => Err(format!("{field}: expected non-negative number")),
    }
}

/// At slot `at`, `down` servers crash and stay down for `repair_slots`
/// slots. Running jobs displaced by the capacity loss suspend through the
/// engine's ordinary suspend/resume path and lose up to `rework_hours` of
/// progress.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlotCrash {
    pub at: usize,
    pub down: usize,
    pub repair_slots: usize,
    pub rework_hours: f64,
}

/// The carbon signal is unavailable for slots `start .. start + len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SignalOutage {
    pub start: usize,
    pub len: usize,
}

/// Coordinator shard `shard` is killed once the fleet has seen
/// `at_submission` submissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardKill {
    pub shard: usize,
    pub at_submission: u64,
}

/// A concrete, reproducible schedule of fault events. Everything that
/// consumes a plan treats it as immutable data; re-running with the same
/// plan replays the identical failure history.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Slot crashes, sorted by onset slot (at most one per slot).
    pub crashes: Vec<SlotCrash>,
    /// Signal outages, sorted by start (may overlap; the mask is a union).
    pub outages: Vec<SignalOutage>,
    /// Shard kills, sorted by trigger submission count (at most one per
    /// shard; always leaves at least one survivor).
    pub shard_kills: Vec<ShardKill>,
    /// Staleness bound for the degradation ladder, slots.
    pub max_stale_slots: usize,
}

impl FaultPlan {
    /// The empty plan: injects nothing anywhere.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when the plan carries no events at all — the guard every
    /// fault hook checks before touching any state.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.outages.is_empty() && self.shard_kills.is_empty()
    }

    /// Expand a spec into a concrete plan. Deterministic in all five
    /// arguments; independent sub-streams per event family.
    pub fn generate(
        seed: u64,
        spec: &FaultSpec,
        horizon: usize,
        max_capacity: usize,
        num_shards: usize,
    ) -> FaultPlan {
        let mut root = Rng::new(seed ^ 0xFA17_5EED);
        let mut crash_rng = root.fork(0xC4A5);
        let mut outage_rng = root.fork(0x0A7A);
        let mut kill_rng = root.fork(0x517D);
        let span = horizon.max(1);

        let mut crashes: Vec<SlotCrash> = Vec::with_capacity(spec.slot_crashes);
        if max_capacity > 0 {
            for _ in 0..spec.slot_crashes {
                let at = crash_rng.below(span);
                let hi_frac = spec.crash_frac_max.max(spec.crash_frac_min);
                let frac = crash_rng.range(spec.crash_frac_min, hi_frac);
                // Never take the whole cluster down: overdue jobs must keep
                // a server to run on, so cap at capacity - 1.
                let down = ((max_capacity as f64 * frac).round() as usize)
                    .clamp(1, max_capacity.saturating_sub(1).max(1));
                let lo = spec.repair_min.max(1) as i64;
                let hi = (spec.repair_max.max(1) as i64).max(lo);
                let repair_slots = crash_rng.int_range(lo, hi) as usize;
                crashes.push(SlotCrash {
                    at,
                    down,
                    repair_slots,
                    rework_hours: spec.rework_hours,
                });
            }
        }
        crashes.sort_by_key(|c| c.at);
        crashes.dedup_by_key(|c| c.at);

        let mut outages: Vec<SignalOutage> = Vec::with_capacity(spec.signal_outages);
        for _ in 0..spec.signal_outages {
            let start = outage_rng.below(span);
            let lo = spec.outage_min.max(1) as i64;
            let hi = (spec.outage_max.max(1) as i64).max(lo);
            let len = outage_rng.int_range(lo, hi) as usize;
            outages.push(SignalOutage { start, len });
        }
        outages.sort_by_key(|o| (o.start, o.len));

        let mut shard_kills: Vec<ShardKill> = Vec::new();
        if num_shards > 1 {
            for _ in 0..spec.shard_kills {
                if shard_kills.len() + 1 >= num_shards {
                    break; // at least one shard must survive
                }
                let shard = kill_rng.below(num_shards);
                let lo = spec.kill_after_min.max(1) as i64;
                let hi = (spec.kill_after_max.max(1) as i64).max(lo);
                let at_submission = kill_rng.int_range(lo, hi) as u64;
                if !shard_kills.iter().any(|k| k.shard == shard) {
                    shard_kills.push(ShardKill { shard, at_submission });
                }
            }
            shard_kills.sort_by_key(|k| (k.at_submission, k.shard));
        }

        FaultPlan { crashes, outages, shard_kills, max_stale_slots: spec.max_stale_slots }
    }

    /// Servers held down by in-repair crashes at slot `t`.
    pub fn capacity_down_at(&self, t: usize) -> usize {
        self.crashes
            .iter()
            .filter(|c| c.at <= t && t < c.at + c.repair_slots)
            .map(|c| c.down)
            .sum()
    }

    /// Crashes whose onset is exactly slot `t`.
    pub fn crashes_at(&self, t: usize) -> impl Iterator<Item = &SlotCrash> {
        self.crashes.iter().filter(move |c| c.at == t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_generates_empty_plan() {
        let plan = FaultPlan::generate(42, &FaultSpec::none(), 168, 100, 4);
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::none());
        assert_eq!(plan.capacity_down_at(0), 0);
    }

    #[test]
    fn generation_is_deterministic() {
        for preset in FaultSpec::PRESETS {
            let spec = FaultSpec::preset(preset).unwrap();
            let a = FaultPlan::generate(7, &spec, 168, 150, 3);
            let b = FaultPlan::generate(7, &spec, 168, 150, 3);
            assert_eq!(a, b, "preset {preset} not reproducible");
            let c = FaultPlan::generate(8, &spec, 168, 150, 3);
            if !a.is_empty() {
                assert_ne!(a, c, "preset {preset} ignores the seed");
            }
        }
    }

    #[test]
    fn plan_events_respect_bounds() {
        let spec = FaultSpec::heavy();
        let plan = FaultPlan::generate(3, &spec, 168, 150, 4);
        assert!(!plan.crashes.is_empty());
        for c in &plan.crashes {
            assert!(c.at < 168);
            assert!(c.down >= 1 && c.down < 150);
            assert!(c.repair_slots >= spec.repair_min && c.repair_slots <= spec.repair_max);
        }
        // Crashes are sorted and unique per slot.
        for w in plan.crashes.windows(2) {
            assert!(w[0].at < w[1].at);
        }
        for o in &plan.outages {
            assert!(o.start < 168);
            assert!(o.len >= spec.outage_min && o.len <= spec.outage_max);
        }
        // At most one kill per shard, and at least one survivor.
        let mut shards: Vec<usize> = plan.shard_kills.iter().map(|k| k.shard).collect();
        shards.sort_unstable();
        shards.dedup();
        assert_eq!(shards.len(), plan.shard_kills.len());
        assert!(plan.shard_kills.len() < 4);
        for k in &plan.shard_kills {
            assert!(k.shard < 4);
            assert!(k.at_submission >= spec.kill_after_min);
            assert!(k.at_submission <= spec.kill_after_max);
        }
    }

    #[test]
    fn single_shard_deployments_never_get_kills() {
        let plan = FaultPlan::generate(11, &FaultSpec::heavy(), 168, 150, 1);
        assert!(plan.shard_kills.is_empty());
    }

    #[test]
    fn capacity_down_window() {
        let plan = FaultPlan {
            crashes: vec![
                SlotCrash { at: 4, down: 10, repair_slots: 3, rework_hours: 1.0 },
                SlotCrash { at: 6, down: 5, repair_slots: 2, rework_hours: 1.0 },
            ],
            ..FaultPlan::default()
        };
        assert_eq!(plan.capacity_down_at(3), 0);
        assert_eq!(plan.capacity_down_at(4), 10);
        assert_eq!(plan.capacity_down_at(6), 15); // overlap sums
        assert_eq!(plan.capacity_down_at(7), 5);
        assert_eq!(plan.capacity_down_at(8), 0);
        assert_eq!(plan.crashes_at(4).count(), 1);
        assert_eq!(plan.crashes_at(5).count(), 0);
    }

    #[test]
    fn toml_table_overrides_preset() {
        let src = r#"
[faults]
preset = "light"
max_stale_slots = 3
slot_crashes = 4
"#;
        let spec = FaultSpec::from_toml_str(src).unwrap();
        let light = FaultSpec::light();
        assert_eq!(spec.max_stale_slots, 3);
        assert_eq!(spec.slot_crashes, 4);
        assert_eq!(spec.signal_outages, light.signal_outages);
        // Missing table → none; bad preset / bad values are errors.
        assert_eq!(FaultSpec::from_toml_str("").unwrap(), FaultSpec::none());
        assert!(FaultSpec::from_toml_str("[faults]\npreset = \"apocalypse\"\n").is_err());
        assert!(FaultSpec::from_toml_str("[faults]\nslot_crashes = -1\n").is_err());
        assert!(FaultSpec::from_toml_str("[faults]\ncrash_frac_min = 1.5\n").is_err());
    }
}
