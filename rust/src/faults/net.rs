//! Seeded network link faults for the session transport.
//!
//! A [`LinkFaultSpec`] describes *how much* link chaos to inject (counts
//! per fault family); [`LinkPlan::generate`] expands it into a concrete,
//! fully reproducible per-message fault map from `(seed, spec,
//! msg_horizon)` via the crate RNG, mirroring [`super::FaultPlan`]'s
//! forked sub-stream discipline so each family's draw sequence is stable
//! when the other families' counts change.
//!
//! Faults key on the *send index* of a request frame: the `i`-th frame a
//! client pushes into a faulty link hits at most one [`LinkFault`]. The
//! cardinal contract carries over from the parent module: an **empty plan
//! injects nothing**, and every consumer guards behind
//! [`LinkPlan::is_empty`] so a fault-free session run executes the exact
//! byte sequence of a clean one.

use std::collections::BTreeMap;

use crate::util::rng::Rng;

/// How much link chaos to inject: counts of five fault families applied
/// to client request frames. All-zero counts mean "clean link". Ships
/// with named presets (`none`, `light`, `heavy`) matching the
/// [`super::FaultSpec`] vocabulary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinkFaultSpec {
    /// Request frames silently dropped in flight (client must retry).
    pub drops: usize,
    /// Request frames delivered twice (server dedup must absorb).
    pub dups: usize,
    /// Request frames held back and delivered late (reordering).
    pub delays: usize,
    /// Maximum frames a delayed frame is held past its send index
    /// (delay drawn from `1..=delay_max`; clamped to at least 1).
    pub delay_max: usize,
    /// Requests delivered whose *response* frame is lost (client sees a
    /// timeout and retries an already-applied operation).
    pub resp_drops: usize,
    /// Mid-session disconnects fired just before a frame is sent
    /// (client must reconnect and resume).
    pub disconnects: usize,
}

impl Default for LinkFaultSpec {
    fn default() -> Self {
        LinkFaultSpec::none()
    }
}

impl LinkFaultSpec {
    /// Preset names accepted by [`LinkFaultSpec::preset`].
    pub const PRESETS: [&'static str; 3] = ["none", "light", "heavy"];

    /// Clean link; generates an empty plan.
    pub fn none() -> LinkFaultSpec {
        LinkFaultSpec { drops: 0, dups: 0, delays: 0, delay_max: 0, resp_drops: 0, disconnects: 0 }
    }

    /// A mild regime: a few drops and duplicates, light reordering, one
    /// lost response, one mid-session disconnect.
    pub fn light() -> LinkFaultSpec {
        LinkFaultSpec { drops: 3, dups: 3, delays: 2, delay_max: 4, resp_drops: 1, disconnects: 1 }
    }

    /// An aggressive regime: heavy loss and duplication, deep
    /// reordering, several lost responses and disconnects.
    pub fn heavy() -> LinkFaultSpec {
        LinkFaultSpec {
            drops: 10,
            dups: 8,
            delays: 6,
            delay_max: 8,
            resp_drops: 4,
            disconnects: 3,
        }
    }

    /// Look up a named preset.
    pub fn preset(name: &str) -> Option<LinkFaultSpec> {
        match name.to_ascii_lowercase().as_str() {
            "none" => Some(LinkFaultSpec::none()),
            "light" => Some(LinkFaultSpec::light()),
            "heavy" => Some(LinkFaultSpec::heavy()),
            _ => None,
        }
    }
}

/// What happens to the request frame at one send index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkFault {
    /// The frame is lost in flight; the server never sees it.
    DropReq,
    /// The frame is delivered twice back to back.
    DupReq,
    /// The frame is held and delivered after `n` more frames have been
    /// sent (or at the next receive flush, whichever comes first).
    Delay(usize),
    /// The frame is delivered and applied, but its response is lost.
    DropResp,
    /// The connection breaks before this frame is sent; the frame stays
    /// with the client for replay after reconnect.
    Disconnect,
}

/// A concrete, reproducible map from request send index to link fault.
/// Consumers treat it as immutable data; re-running the same plan
/// replays the identical loss/duplication/reorder history.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LinkPlan {
    /// At most one fault per send index.
    pub faults: BTreeMap<usize, LinkFault>,
}

impl LinkPlan {
    /// The empty plan: a perfectly clean link.
    pub fn none() -> LinkPlan {
        LinkPlan::default()
    }

    /// True when the link carries no faults — the guard the loopback
    /// transport checks before touching any fault state.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The fault scheduled for send index `i`, if any.
    pub fn fault_at(&self, i: usize) -> Option<LinkFault> {
        self.faults.get(&i).copied()
    }

    /// Number of scheduled fault events.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Expand a spec into a concrete plan over `msg_horizon` send
    /// indices. Deterministic in all three arguments; independent forked
    /// sub-streams per fault family, first-writer-wins on index
    /// collisions (family order: disconnects, drops, dups, delays,
    /// resp_drops — rarer, more disruptive families claim slots first).
    pub fn generate(seed: u64, spec: &LinkFaultSpec, msg_horizon: usize) -> LinkPlan {
        let mut root = Rng::new(seed ^ 0x4E7F_A175);
        let mut disc_rng = root.fork(0xD15C);
        let mut drop_rng = root.fork(0xD40F);
        let mut dup_rng = root.fork(0xD0B1);
        let mut delay_rng = root.fork(0xDE1A);
        let mut resp_rng = root.fork(0x4E55);
        let span = msg_horizon.max(1);

        let mut faults: BTreeMap<usize, LinkFault> = BTreeMap::new();
        // Keep index 0 clean for disconnects/drops: the first frame of a
        // session is the handshake, and losing it before any state exists
        // exercises nothing the later indices don't.
        for _ in 0..spec.disconnects {
            let at = 1 + disc_rng.below(span);
            faults.entry(at).or_insert(LinkFault::Disconnect);
        }
        for _ in 0..spec.drops {
            let at = 1 + drop_rng.below(span);
            faults.entry(at).or_insert(LinkFault::DropReq);
        }
        for _ in 0..spec.dups {
            let at = 1 + dup_rng.below(span);
            faults.entry(at).or_insert(LinkFault::DupReq);
        }
        for _ in 0..spec.delays {
            let at = 1 + delay_rng.below(span);
            let hi = spec.delay_max.max(1) as i64;
            let by = delay_rng.int_range(1, hi) as usize;
            faults.entry(at).or_insert(LinkFault::Delay(by));
        }
        for _ in 0..spec.resp_drops {
            let at = 1 + resp_rng.below(span);
            faults.entry(at).or_insert(LinkFault::DropResp);
        }
        LinkPlan { faults }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_generates_empty_plan() {
        let plan = LinkPlan::generate(42, &LinkFaultSpec::none(), 256);
        assert!(plan.is_empty());
        assert_eq!(plan, LinkPlan::none());
        assert_eq!(plan.fault_at(0), None);
    }

    #[test]
    fn generation_is_deterministic() {
        for preset in LinkFaultSpec::PRESETS {
            let spec = LinkFaultSpec::preset(preset).unwrap();
            let a = LinkPlan::generate(7, &spec, 256);
            let b = LinkPlan::generate(7, &spec, 256);
            assert_eq!(a, b, "preset {preset} not reproducible");
            let c = LinkPlan::generate(8, &spec, 256);
            if !a.is_empty() {
                assert_ne!(a, c, "preset {preset} ignores the seed");
            }
        }
    }

    #[test]
    fn plan_events_respect_bounds() {
        let spec = LinkFaultSpec::heavy();
        let plan = LinkPlan::generate(3, &spec, 200);
        assert!(!plan.is_empty());
        let mut counts = [0usize; 5];
        for (&at, fault) in &plan.faults {
            assert!(at >= 1 && at <= 200, "index {at} outside 1..=200");
            match fault {
                LinkFault::Disconnect => counts[0] += 1,
                LinkFault::DropReq => counts[1] += 1,
                LinkFault::DupReq => counts[2] += 1,
                LinkFault::Delay(by) => {
                    assert!(*by >= 1 && *by <= spec.delay_max);
                    counts[3] += 1;
                }
                LinkFault::DropResp => counts[4] += 1,
            }
        }
        // First-writer-wins can only shrink family counts, never grow.
        assert!(counts[0] <= spec.disconnects && counts[0] >= 1);
        assert!(counts[1] <= spec.drops);
        assert!(counts[2] <= spec.dups);
        assert!(counts[3] <= spec.delays);
        assert!(counts[4] <= spec.resp_drops);
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(LinkFaultSpec::preset("apocalypse").is_none());
        assert_eq!(LinkFaultSpec::preset("LIGHT"), Some(LinkFaultSpec::light()));
    }
}
