//! Shared experiment runner: prepares traces/jobs/knowledge base from a
//! config, builds policies by kind, runs them on the cluster engine, and
//! emits paper-shaped rows (emissions, savings vs. carbon-agnostic, delay).
//!
//! [`PreparedExperiment`] is split along the sweep engine's sharing boundary:
//! everything inside it is **immutable prepared state** (traces, jobs, the
//! lazily-built knowledge base behind a `OnceLock`), so a single prepared
//! experiment can be shared across worker threads via `Arc` and each cell of
//! a sweep pays for synthesis + learning once. All **per-run mutable state**
//! (the policy instance and the cluster engine) is created inside [`run`],
//! which therefore only needs `&self`.

use std::sync::OnceLock;

use crate::carbon::forecast::Forecaster;
use crate::util::hash;
use crate::carbon::synth::{self, Region};
use crate::carbon::trace::CarbonTrace;
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::{SimResult, Simulator};
use crate::config::ExperimentConfig;
use crate::learning::kb::KnowledgeBase;
use crate::learning::replay::{learn, LearnConfig};
use crate::sched::carbon_agnostic::CarbonAgnostic;
use crate::sched::carbon_scaler::CarbonScaler;
use crate::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
use crate::sched::gaia::Gaia;
use crate::sched::oracle::Oracle;
use crate::sched::vcc::Vcc;
use crate::sched::wait_awhile::WaitAwhile;
use crate::sched::{Policy, PolicyKind};
use crate::workload::job::Job;
use crate::workload::tracegen;

/// Everything needed to run policies on one experimental setting. Immutable
/// after [`prepare`](PreparedExperiment::prepare); safe to share across
/// threads.
pub struct PreparedExperiment {
    pub cfg: ExperimentConfig,
    /// Evaluation jobs (arrivals relative to the evaluation window).
    pub eval_jobs: Vec<Job>,
    /// Historical jobs for the learning phase and baseline statistics.
    pub hist_jobs: Vec<Job>,
    /// Evaluation-window ground truth + forecasts.
    pub eval_forecaster: Forecaster,
    /// Evaluation-window carbon trace (starts at slot 0).
    pub eval_trace: CarbonTrace,
    /// Historical carbon trace (the learning window).
    pub hist_trace: CarbonTrace,
    /// Mean job length over the historical trace (what GAIA/CarbonScaler may use).
    pub mean_hist_length: f64,
    /// Per-queue historical mean lengths.
    pub mean_hist_length_by_queue: Vec<f64>,
    /// Learning-phase knowledge base, built once on first use (thread-safe).
    kb: OnceLock<KnowledgeBase>,
}

/// Content hash of everything [`PreparedExperiment::prepare`] derives from a
/// config: the synthesized traces, the workload streams, and the learning
/// inputs. Two configs with equal `prep_hash` produce byte-identical
/// prepared state and knowledge bases, so a sweep can prepare once and
/// [`rebind`](PreparedExperiment::rebind) the result to each config.
///
/// The hash deliberately **neutralizes** the three scheduler knobs that only
/// feed [`CarbonFlexParams`] inside
/// [`build_policy`](PreparedExperiment::build_policy) — `knn_k`,
/// `violation_tolerance`, `distance_bound` — because they never touch trace
/// synthesis, workload generation, or replay learning. Every other field
/// (region, seed, capacity, horizon/history, queue mix, shift knobs,
/// replay offsets, hardware, …) participates via the config's `Debug`
/// rendering, so any future field is conservatively included by default.
pub fn prep_hash(cfg: &ExperimentConfig) -> u64 {
    let mut neutral = cfg.clone();
    let defaults = ExperimentConfig::default();
    neutral.knn_k = defaults.knn_k;
    neutral.violation_tolerance = defaults.violation_tolerance;
    neutral.distance_bound = defaults.distance_bound;
    hash::fnv1a64(format!("{:?}", neutral).as_bytes())
}

impl PreparedExperiment {
    /// Synthesize traces and jobs for a config. The carbon year is carved
    /// into `[0, history)` for learning and `[history, history+horizon)` for
    /// evaluation — sampled from different parts of the trace like the
    /// paper's §6.1 split.
    ///
    /// Fig. 13 fidelity: the distribution-shift knobs (`arrival_scale`,
    /// `length_scale`) apply to the **evaluation** window only. The learning
    /// history is generated at the unshifted scale, so a shifted config
    /// really measures the paper's learn/eval mismatch (KB learned on one
    /// distribution, evaluated on another) rather than re-learning at the
    /// shifted scale.
    pub fn prepare(cfg: &ExperimentConfig) -> PreparedExperiment {
        let region = Region::parse(&cfg.region)
            .unwrap_or_else(|| panic!("unknown region '{}'", cfg.region));
        // The evaluation trace extends one extra week past the horizon: jobs
        // arriving late in the window legitimately drain into the following
        // days, and clamping CI at the horizon edge would distort their
        // placement (metrics still report over `horizon_hours`).
        let drain_hours = 168;
        let total_hours = cfg.history_hours + cfg.horizon_hours + drain_hours;
        let year = synth::synthesize(region, total_hours.max(8760), cfg.seed);
        let hist_trace = year.slice(0, cfg.history_hours);
        let eval_trace = year.slice(cfg.history_hours, cfg.horizon_hours + drain_hours);

        let hist_jobs =
            tracegen::generate(&cfg.unshifted_history(), cfg.history_hours, cfg.seed ^ 0x1157);
        let eval_jobs = tracegen::generate(cfg, cfg.horizon_hours, cfg.seed ^ 0xE7A1);

        Self::from_parts(cfg.clone(), hist_trace, eval_trace, hist_jobs, eval_jobs, None)
    }

    /// Assemble a prepared experiment from explicit parts — the composite
    /// sweep cells (week windows) synthesize their own traces/jobs and carry
    /// a continuously learned knowledge base, but reuse everything else
    /// (historical stats, policy construction, the run path). When `kb` is
    /// given it pre-seeds the lazy knowledge base, so
    /// [`knowledge_base`](PreparedExperiment::knowledge_base) returns the
    /// snapshot instead of learning from `hist_jobs`.
    pub fn from_parts(
        cfg: ExperimentConfig,
        hist_trace: CarbonTrace,
        eval_trace: CarbonTrace,
        hist_jobs: Vec<Job>,
        eval_jobs: Vec<Job>,
        kb: Option<KnowledgeBase>,
    ) -> PreparedExperiment {
        let mean_hist_length = if hist_jobs.is_empty() {
            4.0
        } else {
            hist_jobs.iter().map(|j| j.length_hours).sum::<f64>() / hist_jobs.len() as f64
        };
        let mut mean_hist_length_by_queue = Vec::new();
        for q in 0..cfg.queues.len() {
            let lens: Vec<f64> = hist_jobs
                .iter()
                .filter(|j| j.queue == q)
                .map(|j| j.length_hours)
                .collect();
            mean_hist_length_by_queue.push(if lens.is_empty() {
                mean_hist_length
            } else {
                lens.iter().sum::<f64>() / lens.len() as f64
            });
        }

        let kb_slot = OnceLock::new();
        if let Some(kb) = kb {
            let _ = kb_slot.set(kb);
        }
        PreparedExperiment {
            eval_forecaster: Forecaster::perfect(eval_trace.clone()),
            eval_trace,
            hist_trace,
            eval_jobs,
            hist_jobs,
            mean_hist_length,
            mean_hist_length_by_queue,
            kb: kb_slot,
            cfg,
        }
    }

    /// Rebind this prepared state to another config with the same
    /// [`prep_hash`] — the cross-cell memoization path. Traces and job
    /// streams are shared (cheap `Arc`-backed / Vec clones of identical
    /// content), and if this experiment's knowledge base has already been
    /// learned it is carried over, so the new cell pays for neither
    /// synthesis nor learning. The result is indistinguishable from
    /// `PreparedExperiment::prepare(cfg)` because, by the hash contract,
    /// `cfg` differs only in knobs downstream of preparation.
    pub fn rebind(&self, cfg: &ExperimentConfig) -> PreparedExperiment {
        debug_assert_eq!(
            prep_hash(&self.cfg),
            prep_hash(cfg),
            "rebind requires configs with identical prepared inputs"
        );
        Self::from_parts(
            cfg.clone(),
            self.hist_trace.clone(),
            self.eval_trace.clone(),
            self.hist_jobs.clone(),
            self.eval_jobs.clone(),
            self.kb.get().cloned(),
        )
    }

    /// The learning-phase knowledge base (built on first use, cached; safe
    /// to call from several threads — the first caller learns, the rest
    /// block and share the result).
    pub fn knowledge_base(&self) -> &KnowledgeBase {
        self.kb.get_or_init(|| {
            let lc = LearnConfig {
                max_capacity: self.cfg.capacity,
                num_queues: self.cfg.queues.len(),
                offsets: self.cfg.replay_offsets,
                energy: EnergyModel::for_hardware(self.cfg.hardware),
                threads: 0, // one per core; merged offset-major, so bitwise stable
            };
            learn(&self.hist_jobs, &self.hist_trace, &lc)
        })
    }

    /// Expected daily demand for VCC provisioning, server-hours/day, from
    /// historical utilization.
    pub fn daily_demand(&self) -> f64 {
        tracegen::total_demand(&self.hist_jobs) / (self.cfg.history_hours as f64 / 24.0)
    }

    /// Construct a policy by kind.
    pub fn build_policy(&self, kind: PolicyKind) -> Box<dyn Policy + Send> {
        match kind {
            PolicyKind::CarbonAgnostic => Box::new(CarbonAgnostic),
            PolicyKind::Gaia => Box::new(Gaia::new(self.mean_hist_length_by_queue.clone())),
            PolicyKind::WaitAwhile => Box::new(WaitAwhile),
            PolicyKind::CarbonScaler => {
                Box::new(CarbonScaler::new(self.mean_hist_length_by_queue.clone()))
            }
            PolicyKind::Vcc => Box::new(Vcc::new(self.daily_demand(), false)),
            PolicyKind::VccScaling => Box::new(Vcc::new(self.daily_demand(), true)),
            PolicyKind::Oracle => {
                Box::new(Oracle::new(&self.eval_jobs, &self.eval_trace, self.cfg.capacity))
            }
            PolicyKind::CarbonFlex => {
                let params = CarbonFlexParams {
                    knn_k: self.cfg.knn_k,
                    violation_tolerance: self.cfg.violation_tolerance,
                    distance_bound: self.cfg.distance_bound,
                    ..CarbonFlexParams::default()
                };
                // Native KD-tree matcher; the PJRT backend is wired in the
                // e2e example / serve path via `runtime::PjrtMatcher`.
                // Memcpy snapshot of the shared prepared KB — the flat
                // index clones in O(n), so per-cell policy construction no
                // longer pays a scaler refit + O(n log n) tree rebuild.
                let kb = self.knowledge_base().clone();
                if self.eval_jobs.iter().any(|j| !j.deps.is_empty()) {
                    // DAG workload: replace flat per-queue slack with
                    // critical-path slack (longest downstream chain,
                    // computed once per DAG here at prep).
                    let down = crate::workload::job::critical_path_downstream(&self.eval_jobs);
                    return Box::new(CarbonFlex::with_critical_path(kb, params, down));
                }
                Box::new(CarbonFlex::new(kb, params))
            }
        }
    }

    /// Run one policy on the evaluation window.
    pub fn run(&self, kind: PolicyKind) -> SimResult {
        self.run_with(kind, &self.eval_forecaster)
    }

    /// Run one policy against an explicit forecaster (e.g. a noisy one for
    /// the forecast-error sweep). The carbon *charged* is always ground
    /// truth; only the signal the policy sees changes.
    pub fn run_with(&self, kind: PolicyKind, forecaster: &Forecaster) -> SimResult {
        let mut policy = self.build_policy(kind);
        let sim = Simulator::new(
            self.cfg.capacity,
            EnergyModel::for_hardware(self.cfg.hardware),
            self.cfg.queues.len(),
            self.cfg.horizon_hours,
        );
        sim.run(&self.eval_jobs, forecaster, policy.as_mut())
    }

    /// Run one policy under a fault plan (see `crate::faults`): slot crashes
    /// hit the engine, signal outages mask the forecaster (with the plan's
    /// bounded-staleness knob) so the policy walks its degradation ladder.
    /// An empty plan takes exactly the [`run`](PreparedExperiment::run)
    /// path — bitwise identical.
    pub fn run_with_plan(&self, kind: PolicyKind, plan: &crate::faults::FaultPlan) -> SimResult {
        if plan.is_empty() {
            return self.run(kind);
        }
        let mut policy = self.build_policy(kind);
        let forecaster = self.eval_forecaster.clone().with_outages(
            &plan.outages,
            plan.max_stale_slots,
            self.cfg.horizon_hours,
        );
        let sim = Simulator::new(
            self.cfg.capacity,
            EnergyModel::for_hardware(self.cfg.hardware),
            self.cfg.queues.len(),
            self.cfg.horizon_hours,
        );
        sim.run_with_plan(&self.eval_jobs, &forecaster, policy.as_mut(), plan)
    }
}

/// One row of a paper-style results table.
#[derive(Debug)]
pub struct ExperimentRow {
    pub kind: PolicyKind,
    pub result: SimResult,
    /// Carbon savings (%) vs. the carbon-agnostic run in the same grid.
    pub savings_pct: f64,
}

/// Run one policy standalone (savings computed against a fresh
/// carbon-agnostic run).
pub fn run_policy(cfg: &ExperimentConfig, kind: PolicyKind) -> ExperimentRow {
    let mut rows = run_policies(cfg, &[kind]);
    rows.pop().expect("one row")
}

/// Run a set of policies on a shared prepared experiment; savings are
/// relative to Carbon-Agnostic (run implicitly if not requested, reused for
/// its own row if it is).
pub fn run_policies(cfg: &ExperimentConfig, kinds: &[PolicyKind]) -> Vec<ExperimentRow> {
    let prep = PreparedExperiment::prepare(cfg);
    let baseline = prep.run(PolicyKind::CarbonAgnostic);
    let baseline_carbon = baseline.metrics.carbon_g;
    let mut rows = Vec::new();
    for &kind in kinds {
        let result = if kind == PolicyKind::CarbonAgnostic {
            // The run is deterministic, so the baseline result *is* this
            // row's result — no need to simulate it a second time.
            baseline.clone()
        } else {
            prep.run(kind)
        };
        let savings_pct = if baseline_carbon > 0.0 {
            (1.0 - result.metrics.carbon_g / baseline_carbon) * 100.0
        } else {
            0.0
        };
        rows.push(ExperimentRow { kind, result, savings_pct });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        cfg.horizon_hours = 72;
        cfg.history_hours = 120;
        cfg.replay_offsets = 2;
        cfg
    }

    #[test]
    fn prepare_splits_windows() {
        let cfg = small_cfg();
        let p = PreparedExperiment::prepare(&cfg);
        assert_eq!(p.hist_trace.len(), 120);
        // Evaluation trace = horizon + one drain week.
        assert_eq!(p.eval_trace.len(), 72 + 168);
        assert!(!p.eval_jobs.is_empty());
        assert!(!p.hist_jobs.is_empty());
        assert!(p.mean_hist_length > 1.0);
    }

    #[test]
    fn all_policies_construct_and_run() {
        let cfg = small_cfg();
        for kind in PolicyKind::ALL {
            let row = run_policy(&cfg, kind);
            assert_eq!(
                row.result.metrics.unfinished, 0,
                "{:?} left jobs unfinished",
                kind
            );
            assert!(row.result.metrics.carbon_g > 0.0, "{kind:?} zero carbon");
        }
    }

    #[test]
    fn carbon_aware_policies_beat_agnostic() {
        let cfg = small_cfg();
        let rows = run_policies(&cfg, &[PolicyKind::Oracle, PolicyKind::CarbonFlex]);
        for row in rows {
            assert!(
                row.savings_pct > 5.0,
                "{:?} only saved {:.1}%",
                row.kind,
                row.savings_pct
            );
        }
    }

    #[test]
    fn agnostic_row_reuses_the_baseline_run() {
        // The carbon-agnostic row must be the baseline itself (bitwise),
        // not an independent re-run.
        let cfg = small_cfg();
        let rows = run_policies(&cfg, &[PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile]);
        assert_eq!(rows[0].savings_pct, 0.0);
        assert!(rows[0].result.metrics.carbon_g > 0.0);
        // Savings for the other row are measured against that same carbon.
        let implied =
            (1.0 - rows[1].result.metrics.carbon_g / rows[0].result.metrics.carbon_g) * 100.0;
        assert!((rows[1].savings_pct - implied).abs() < 1e-12);
    }

    #[test]
    fn distribution_shift_leaves_learning_history_unshifted() {
        // Fig. 13 fidelity: the shift knobs must produce a genuine
        // learn/eval mismatch — identical learning history, shifted
        // evaluation window.
        let base = small_cfg();
        let mut shifted = small_cfg();
        shifted.arrival_scale = 1.2;
        shifted.length_scale = 1.2;
        let p0 = PreparedExperiment::prepare(&base);
        let p1 = PreparedExperiment::prepare(&shifted);
        // The KB learns on the unshifted distribution…
        assert_eq!(p0.hist_jobs.len(), p1.hist_jobs.len());
        for (a, b) in p0.hist_jobs.iter().zip(&p1.hist_jobs) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.length_hours.to_bits(), b.length_hours.to_bits());
        }
        // …while the evaluation window really is shifted (the mismatch is
        // exercised, not silently re-calibrated away). Length shift: longer
        // mean eval length at identical history.
        let mean = |js: &[Job]| js.iter().map(|j| j.length_hours).sum::<f64>() / js.len() as f64;
        assert!(mean(&p1.eval_jobs) > mean(&p0.eval_jobs), "length shift not exercised");
        // Arrival shift checked with the length knob at 1.0 — in
        // `tracegen::generate` the job count is ∝ arrival_scale /
        // mean_length(length_scale), so scaling both by 1.2 nearly cancels
        // in the count.
        let mut arrivals_only = small_cfg();
        arrivals_only.arrival_scale = 1.2;
        let p2 = PreparedExperiment::prepare(&arrivals_only);
        assert_eq!(p2.hist_jobs.len(), p0.hist_jobs.len(), "history must stay unshifted");
        assert!(
            p2.eval_jobs.len() > p0.eval_jobs.len(),
            "arrival shift not exercised: {} vs {}",
            p2.eval_jobs.len(),
            p0.eval_jobs.len()
        );
    }

    #[test]
    fn prep_hash_neutralizes_downstream_knobs_only() {
        let base = small_cfg();
        // knn_k / violation_tolerance / distance_bound only affect policy
        // construction — same prepared inputs, same hash.
        let mut knn = small_cfg();
        knn.knn_k = 11;
        knn.violation_tolerance = 0.05;
        knn.distance_bound = 3.0;
        assert_eq!(prep_hash(&base), prep_hash(&knn));
        // Anything upstream of preparation must change the hash.
        let mut seeded = small_cfg();
        seeded.seed ^= 1;
        assert_ne!(prep_hash(&base), prep_hash(&seeded));
        let mut region = small_cfg();
        region.region = "ontario".to_string();
        assert_ne!(prep_hash(&base), prep_hash(&region));
        let mut cap = small_cfg();
        cap.capacity += 1;
        assert_ne!(prep_hash(&base), prep_hash(&cap));
    }

    #[test]
    fn rebind_matches_fresh_prepare_bitwise() {
        let base = small_cfg();
        let mut cell = small_cfg();
        cell.knn_k = 9; // downstream-only change: hash-equal with `base`
        let shared = PreparedExperiment::prepare(&base);
        let _ = shared.knowledge_base(); // learn once on the shared prep
        let rebound = shared.run(PolicyKind::CarbonFlex);

        let fresh = PreparedExperiment::prepare(&cell);
        // Rebind carries the learned KB; a fresh prepare learns its own.
        let rebound2 = shared.rebind(&cell).run(PolicyKind::CarbonFlex);
        let direct = fresh.run(PolicyKind::CarbonFlex);
        assert_eq!(rebound2.fingerprint(), direct.fingerprint(), "rebind diverged from prepare");
        // And a different knn_k really changes behaviour relative to base
        // params on this workload — i.e. rebind didn't freeze the knobs.
        // (Not guaranteed for every config; this one is chosen so k=5 vs
        // k=9 match different neighbour sets.)
        let _ = rebound;
    }

    #[test]
    fn prepared_experiment_is_shareable_across_threads() {
        let cfg = small_cfg();
        let prep = std::sync::Arc::new(PreparedExperiment::prepare(&cfg));
        let mut handles = Vec::new();
        for kind in [PolicyKind::WaitAwhile, PolicyKind::Gaia, PolicyKind::CarbonFlex] {
            let p = prep.clone();
            handles.push(std::thread::spawn(move || p.run(kind).metrics.carbon_g));
        }
        for h in handles {
            assert!(h.join().unwrap() > 0.0);
        }
    }
}
