//! Network/session benchmark (§Robustness): what the session layer costs
//! and whether it keeps its promises.
//!
//! Four deterministic legs over one generated arrival stream:
//!
//! - **stdio leg** — the in-process [`drive`] baseline: submit latency
//!   percentiles and the reference drain report.
//! - **loopback clean leg** — the same stream through a
//!   [`SessionClient`] over a fault-free in-process loopback: must drain
//!   bitwise identical to stdio (the session layer adds no behavior).
//! - **loopback faulted leg** — the stream through a seeded
//!   [`LinkPlan`] (drops, dups, delays, disconnects): the client retries
//!   and reconnects, the server dedups, and the drain must still account
//!   for every accepted submission exactly once.
//! - **TCP leg** — the clean stream over real `std::net` sockets on
//!   localhost: identity again, plus TCP submit percentiles.
//!
//! Emitted as the `BENCH_net.json` document; the CI `net-smoke` job runs
//! the smoke config, asserts the headline fields, and uploads the JSON.

use std::sync::{Arc, Mutex};

use crate::carbon::synth::Region;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::client::SessionClient;
use crate::coordinator::loadgen::{drive, drive_session, submissions_of, DriveReport};
use crate::coordinator::session::{take_cluster, SessionConfig, SessionCounters, SessionServer};
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::transport::{
    bind_tcp, serve_on, FrameHandler, LoopbackTransport, TcpTransport,
};
use crate::experiments::cells::DispatchStrategy;
use crate::faults::net::{LinkFaultSpec, LinkPlan};
use crate::sched::PolicyKind;
use crate::util::json::Json;
use crate::workload::tracegen;

/// Options for [`run_net_bench`].
#[derive(Debug, Clone)]
pub struct NetBenchOpts {
    pub cfg: ExperimentConfig,
    pub service: ServiceConfig,
    pub kind: PolicyKind,
    /// Arrival count per leg.
    pub jobs: usize,
    /// Trace horizon, hours.
    pub horizon: usize,
    pub seed: u64,
    /// Link-fault preset for the faulted leg (see [`LinkFaultSpec::preset`]).
    pub preset: String,
    /// Pipeline window (frames in flight per client window).
    pub window: usize,
    /// Skip the TCP leg (for environments without localhost sockets).
    pub skip_tcp: bool,
}

impl NetBenchOpts {
    pub fn new(cfg: ExperimentConfig, service: ServiceConfig) -> NetBenchOpts {
        NetBenchOpts {
            cfg,
            service,
            kind: PolicyKind::CarbonAgnostic,
            jobs: 120,
            horizon: 48,
            seed: 0,
            preset: "heavy".to_string(),
            window: 16,
            skip_tcp: false,
        }
    }
}

/// The measured network/session document.
#[derive(Debug, Clone)]
pub struct NetReport {
    pub preset: String,
    pub stdio: DriveReport,
    pub loopback: DriveReport,
    pub faulted: DriveReport,
    pub tcp: Option<DriveReport>,
    /// Fault-free legs (loopback, and TCP when run) drain bitwise
    /// identical to the stdio baseline.
    pub fault_free_identical: bool,
    /// Faulted leg: every accepted submission completed exactly once and
    /// the server-side session ledger agrees with the client's count.
    pub exactly_once: bool,
    /// Faulted-leg client telemetry.
    pub reconnects: u64,
    pub retries: u64,
    pub timeouts: u64,
    /// Faulted-leg server telemetry.
    pub dedup_hits: u64,
    pub resumes: u64,
    /// Events in the generated link plan (0 for preset "none").
    pub plan_events: usize,
}

fn session_pair(
    cfg: &ExperimentConfig,
    service: &ServiceConfig,
    kind: PolicyKind,
    region: Region,
) -> Arc<Mutex<SessionServer>> {
    let cluster = ShardedCoordinator::start(
        cfg,
        service,
        kind,
        &[region],
        DispatchStrategy::RoundRobin,
    );
    Arc::new(Mutex::new(SessionServer::new(cluster, SessionConfig::default())))
}

/// Recover the cluster from a served session server and shut it down,
/// returning the server-side session counters.
fn finish(server: Arc<Mutex<SessionServer>>) -> Result<SessionCounters, String> {
    let counters = server.lock().map_err(|_| "session server poisoned")?.counters();
    let cluster = take_cluster(server).ok_or("session server still shared after serve")?;
    cluster.shutdown();
    Ok(counters)
}

/// Run all legs. Deterministic in `(cfg.seed, preset)` for everything but
/// wall-clock latency numbers.
pub fn run_net_bench(opts: &NetBenchOpts) -> Result<NetReport, String> {
    let spec = LinkFaultSpec::preset(&opts.preset)
        .ok_or_else(|| format!("unknown link-fault preset '{}'", opts.preset))?;
    let cfg = &opts.cfg;
    let region = Region::parse(&cfg.region).unwrap_or(Region::ALL[0]);
    let trace = tracegen::generate_n(cfg, opts.horizon, cfg.seed, opts.jobs);
    let arrivals = submissions_of(&trace);

    // --- stdio leg: the in-process baseline. ---
    let mut base = ShardedCoordinator::start(
        cfg,
        &opts.service,
        opts.kind,
        &[region],
        DispatchStrategy::RoundRobin,
    );
    let stdio = drive(&mut base, &arrivals, 1, "stdio");
    base.shutdown();

    // --- loopback clean leg: session framing, no faults. ---
    let server = session_pair(cfg, &opts.service, opts.kind, region);
    let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
    let mut client = SessionClient::new(
        Box::new(LoopbackTransport::new(handler, LinkPlan::none())),
        "net-bench-clean",
        opts.seed,
    );
    let loopback = drive_session(&mut client, &arrivals, opts.window, "loopback")
        .map_err(|e| format!("clean loopback leg failed: {e}"))?;
    drop(client);
    finish(server)?;

    // --- loopback faulted leg: seeded link faults, retry + dedup. ---
    // Size the plan horizon to the frame budget: one frame per submit,
    // plus a tick per slot, a drain, the handshake, and retry headroom.
    let msg_horizon = arrivals.len() + opts.horizon + 16;
    let plan = LinkPlan::generate(opts.seed, &spec, msg_horizon);
    let plan_events = plan.len();
    let server = session_pair(cfg, &opts.service, opts.kind, region);
    let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
    let mut client = SessionClient::new(
        Box::new(LoopbackTransport::new(handler, plan)),
        "net-bench-faulted",
        opts.seed,
    );
    let faulted = drive_session(&mut client, &arrivals, opts.window, "faulted")
        .map_err(|e| format!("faulted loopback leg failed: {e}"))?;
    let cstats = client.stats();
    drop(client);
    let scounters = finish(server)?;

    // --- TCP leg: clean stream over real localhost sockets. ---
    let tcp = if opts.skip_tcp {
        None
    } else {
        let server = session_pair(cfg, &opts.service, opts.kind, region);
        let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
        let (listener, addr) =
            bind_tcp("127.0.0.1:0").map_err(|e| format!("tcp bind failed: {e}"))?;
        let serve_handle = std::thread::spawn(move || serve_on(listener, handler));
        let mut client = SessionClient::new(
            Box::new(TcpTransport::new(&addr)),
            "net-bench-tcp",
            opts.seed,
        );
        let report = drive_session(&mut client, &arrivals, opts.window, "tcp")
            .map_err(|e| format!("tcp leg failed: {e}"))?;
        drop(client);
        serve_handle
            .join()
            .map_err(|_| "tcp server thread panicked")?
            .map_err(|e| format!("tcp serve failed: {e}"))?;
        finish(server)?;
        Some(report)
    };

    let mut fault_free_identical = stdio.drain_matches(&loopback);
    if let Some(t) = &tcp {
        fault_free_identical = fault_free_identical && stdio.drain_matches(t);
    }
    // Exactly-once under faults: the drain completed everything the
    // cluster accepted, the server's per-session ledger agrees with the
    // client's observed accepts, and nothing was double-applied (a
    // faulted run must also match the stdio drain bitwise, because
    // dedup'd retries never reach the cluster).
    let exactly_once = faulted.completed == faulted.accepted
        && scounters.accepted == faulted.accepted as u64
        && stdio.drain_matches(&faulted);

    Ok(NetReport {
        preset: opts.preset.clone(),
        stdio,
        loopback,
        faulted,
        tcp,
        fault_free_identical,
        exactly_once,
        reconnects: cstats.reconnects,
        retries: cstats.retries,
        timeouts: cstats.timeouts,
        dedup_hits: scounters.dedup_hits,
        resumes: scounters.resumes,
        plan_events,
    })
}

impl NetReport {
    /// The `BENCH_net.json` document.
    pub fn to_json(&self, opts: &NetBenchOpts, wall_seconds: f64) -> Json {
        let mut modes = vec![
            ("stdio", self.stdio.to_json()),
            ("loopback", self.loopback.to_json()),
            ("faulted", self.faulted.to_json()),
        ];
        if let Some(t) = &self.tcp {
            modes.push(("tcp", t.to_json()));
        }
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("region", Json::str(opts.cfg.region.clone())),
                    ("capacity", Json::num(opts.cfg.capacity as f64)),
                    ("policy", Json::str(opts.kind.key())),
                    ("jobs", Json::num(opts.jobs as f64)),
                    ("horizon_hours", Json::num(opts.horizon as f64)),
                    ("seed", Json::num(opts.seed as f64)),
                    ("preset", Json::str(self.preset.clone())),
                    ("window", Json::num(opts.window as f64)),
                ]),
            ),
            ("fault_free_identical", Json::Bool(self.fault_free_identical)),
            ("exactly_once", Json::Bool(self.exactly_once)),
            ("reconnects", Json::num(self.reconnects as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("timeouts", Json::num(self.timeouts as f64)),
            ("dedup_hits", Json::num(self.dedup_hits as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("plan_events", Json::num(self.plan_events as f64)),
            ("stdio_p50_ms", Json::num(self.stdio.p50_decision_ms)),
            ("stdio_p99_ms", Json::num(self.stdio.p99_decision_ms)),
            (
                "tcp_p50_ms",
                self.tcp.as_ref().map_or(Json::Null, |t| Json::num(t.p50_decision_ms)),
            ),
            (
                "tcp_p99_ms",
                self.tcp.as_ref().map_or(Json::Null, |t| Json::num(t.p99_decision_ms)),
            ),
            ("modes", Json::obj(modes)),
            ("wall_seconds", Json::num(wall_seconds)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_opts() -> NetBenchOpts {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 10;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let mut opts = NetBenchOpts::new(cfg, ServiceConfig::default());
        opts.jobs = 60;
        opts
    }

    #[test]
    fn net_bench_heavy_keeps_identity_and_exactly_once() {
        let r = run_net_bench(&smoke_opts()).unwrap();
        assert!(r.plan_events > 0, "heavy preset generated an empty plan");
        assert!(r.fault_free_identical, "clean session legs diverged from stdio");
        assert!(r.exactly_once, "faulted leg lost or duplicated submissions");
        assert!(
            r.retries + r.reconnects > 0,
            "heavy plan never exercised the retry path"
        );
    }

    #[test]
    fn net_bench_none_preset_is_faultless() {
        let mut opts = smoke_opts();
        opts.preset = "none".to_string();
        opts.skip_tcp = true;
        let r = run_net_bench(&opts).unwrap();
        assert_eq!(r.plan_events, 0);
        assert_eq!(r.reconnects + r.retries + r.dedup_hits, 0);
        assert!(r.fault_free_identical && r.exactly_once);
        assert!(r.tcp.is_none());
    }

    #[test]
    fn net_bench_rejects_unknown_preset() {
        let mut opts = smoke_opts();
        opts.preset = "carrier-pigeon".to_string();
        assert!(run_net_bench(&opts).is_err());
    }

    #[test]
    fn net_json_has_headline_fields() {
        let mut opts = smoke_opts();
        opts.skip_tcp = true;
        let doc = run_net_bench(&opts).unwrap().to_json(&opts, 2.0);
        for field in [
            "fault_free_identical",
            "exactly_once",
            "reconnects",
            "dedup_hits",
            "stdio_p50_ms",
            "stdio_p99_ms",
            "tcp_p50_ms",
            "tcp_p99_ms",
        ] {
            assert!(doc.get(field).is_some(), "missing headline field '{field}'");
        }
        assert_eq!(doc.get("schema").and_then(Json::as_f64), Some(1.0));
        // TCP skipped → latency fields are null, not absent.
        assert!(matches!(doc.get("tcp_p50_ms"), Some(Json::Null)));
    }
}
