//! Forecast-error sensitivity (extension; paper footnote 3 assumes accurate
//! day-ahead forecasts, citing CarbonCast's ~5% error).
//!
//! All online policies consult the [`Forecaster`]; this driver injects
//! multiplicative forecast noise (σ ∈ {0, 2%, 5%, 10%, 20%}) while the
//! carbon *charged* remains ground truth, quantifying how much of
//! CarbonFlex's advantage survives realistic forecast quality. The oracle
//! keeps perfect knowledge by definition, bounding the achievable savings.
//!
//! The (σ × policy) cells are independent given the shared prepared
//! experiment, so they run in parallel on the sweep engine's thread pool.

use crate::carbon::forecast::Forecaster;
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::experiments::sweep::{auto_threads, par_map};
use crate::sched::PolicyKind;

/// Savings of `kind` under forecast noise `sigma`.
#[derive(Debug, Clone)]
pub struct NoiseResult {
    pub sigma: f64,
    pub kind: PolicyKind,
    pub savings_pct: f64,
    pub violations: usize,
}

/// Sweep forecast noise for a set of policies. Cells run in parallel and
/// come back in (σ-major, policy-minor) order; every cell derives its noise
/// stream from the config seed and its σ, never from scheduling order.
pub fn run_noise_sweep(
    cfg: &ExperimentConfig,
    sigmas: &[f64],
    kinds: &[PolicyKind],
) -> Vec<NoiseResult> {
    let prep = PreparedExperiment::prepare(cfg);
    if kinds.contains(&PolicyKind::CarbonFlex) {
        // Learn once up front so parallel cells share the knowledge base.
        let _ = prep.knowledge_base();
    }
    let baseline = prep.run(PolicyKind::CarbonAgnostic);
    let base_carbon = baseline.metrics.carbon_g;

    let cells: Vec<(f64, PolicyKind)> = sigmas
        .iter()
        .flat_map(|&sigma| kinds.iter().map(move |&kind| (sigma, kind)))
        .collect();
    par_map(auto_threads(), &cells, |&(sigma, kind), _| {
        let forecaster = if sigma == 0.0 {
            Forecaster::perfect(prep.eval_trace.clone())
        } else {
            Forecaster::noisy(prep.eval_trace.clone(), sigma, cfg.seed ^ 0x4F0C)
        };
        let r = prep.run_with(kind, &forecaster);
        NoiseResult {
            sigma,
            kind,
            savings_pct: (1.0 - r.metrics.carbon_g / base_carbon) * 100.0,
            violations: r.metrics.violations,
        }
    })
}

/// Print the sweep as a paper-style table.
pub fn print_noise_sweep(cfg: &ExperimentConfig) {
    use crate::util::bench::Table;
    println!("\n== Extension: day-ahead forecast error sensitivity ==");
    let kinds = [PolicyKind::CarbonFlex, PolicyKind::WaitAwhile, PolicyKind::Gaia];
    let rows = run_noise_sweep(cfg, &[0.0, 0.02, 0.05, 0.10, 0.20], &kinds);
    let mut t = Table::new(&["forecast σ", "policy", "savings %", "violations"]);
    for r in rows {
        t.row(&[
            format!("{:.0}%", r.sigma * 100.0),
            r.kind.as_str().to_string(),
            format!("{:.1}", r.savings_pct),
            format!("{}", r.violations),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carboncast_level_noise_is_tolerable() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 24;
        cfg.horizon_hours = 96;
        cfg.history_hours = 168;
        cfg.replay_offsets = 2;
        let rows = run_noise_sweep(&cfg, &[0.0, 0.05], &[PolicyKind::CarbonFlex]);
        let perfect = rows[0].savings_pct;
        let noisy = rows[1].savings_pct;
        // CarbonCast-level error (~5%) must not destroy the savings (the
        // paper's assumption that forecasts are "highly accurate" is safe).
        assert!(
            noisy > perfect * 0.6,
            "5% forecast noise collapsed savings: {perfect:.1}% → {noisy:.1}%"
        );
    }
}
