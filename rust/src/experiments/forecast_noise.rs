//! Forecast-error sensitivity (extension; paper footnote 3 assumes accurate
//! day-ahead forecasts, citing CarbonCast's ~5% error).
//!
//! All online policies consult the [`Forecaster`]; this driver injects
//! multiplicative forecast noise (σ ∈ {0, 2%, 5%, 10%, 20%}) while the
//! carbon *charged* remains ground truth, quantifying how much of
//! CarbonFlex's advantage survives realistic forecast quality. The oracle
//! keeps perfect knowledge by definition, bounding the achievable savings.

use crate::carbon::forecast::Forecaster;
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::Simulator;
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::sched::PolicyKind;

/// Savings of `kind` under forecast noise `sigma`.
#[derive(Debug, Clone)]
pub struct NoiseResult {
    pub sigma: f64,
    pub kind: PolicyKind,
    pub savings_pct: f64,
    pub violations: usize,
}

/// Sweep forecast noise for a set of policies.
pub fn run_noise_sweep(
    cfg: &ExperimentConfig,
    sigmas: &[f64],
    kinds: &[PolicyKind],
) -> Vec<NoiseResult> {
    let mut prep = PreparedExperiment::prepare(cfg);
    let baseline = prep.run(PolicyKind::CarbonAgnostic);
    let base_carbon = baseline.metrics.carbon_g;
    let sim = Simulator::new(
        cfg.capacity,
        EnergyModel::for_hardware(cfg.hardware),
        cfg.queues.len(),
        cfg.horizon_hours,
    );
    let mut out = Vec::new();
    for &sigma in sigmas {
        let forecaster = if sigma == 0.0 {
            Forecaster::perfect(prep.eval_trace.clone())
        } else {
            Forecaster::noisy(prep.eval_trace.clone(), sigma, cfg.seed ^ 0x4F0C)
        };
        for &kind in kinds {
            let mut policy = prep.build_policy(kind);
            let r = sim.run(&prep.eval_jobs, &forecaster, policy.as_mut());
            out.push(NoiseResult {
                sigma,
                kind,
                savings_pct: (1.0 - r.metrics.carbon_g / base_carbon) * 100.0,
                violations: r.metrics.violations,
            });
        }
    }
    out
}

/// Print the sweep as a paper-style table.
pub fn print_noise_sweep(cfg: &ExperimentConfig) {
    use crate::util::bench::Table;
    println!("\n== Extension: day-ahead forecast error sensitivity ==");
    let kinds = [PolicyKind::CarbonFlex, PolicyKind::WaitAwhile, PolicyKind::Gaia];
    let rows = run_noise_sweep(cfg, &[0.0, 0.02, 0.05, 0.10, 0.20], &kinds);
    let mut t = Table::new(&["forecast σ", "policy", "savings %", "violations"]);
    for r in rows {
        t.row(&[
            format!("{:.0}%", r.sigma * 100.0),
            r.kind.as_str().to_string(),
            format!("{:.1}", r.savings_pct),
            format!("{}", r.violations),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carboncast_level_noise_is_tolerable() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 24;
        cfg.horizon_hours = 96;
        cfg.history_hours = 168;
        cfg.replay_offsets = 2;
        let rows =
            run_noise_sweep(&cfg, &[0.0, 0.05], &[PolicyKind::CarbonFlex]);
        let perfect = rows[0].savings_pct;
        let noisy = rows[1].savings_pct;
        // CarbonCast-level error (~5%) must not destroy the savings (the
        // paper's assumption that forecasts are "highly accurate" is safe).
        assert!(
            noisy > perfect * 0.6,
            "5% forecast noise collapsed savings: {perfect:.1}% → {noisy:.1}%"
        );
    }
}
