//! Parallel sweep engine for paper-scale experiment grids.
//!
//! Every headline figure of the paper (Figs. 5–14) is a cartesian grid of
//! (region × policy × capacity × …) simulations. [`SweepSpec`] describes
//! such a grid declaratively; [`SweepRunner`] executes it on a scoped
//! `std::thread` pool (the crate is dependency-free, so no rayon):
//!
//! - **Phase 1** prepares each grid *point* — trace synthesis, workload
//!   generation, and the learning phase — exactly once, in parallel, and
//!   wraps the immutable [`PreparedExperiment`] in an `Arc`. The
//!   carbon-agnostic baseline also runs here, once per point.
//! - **Phase 2** runs every *cell* (point × policy) in parallel, sharing
//!   the prepared state via `Arc` instead of re-synthesizing or re-learning
//!   per policy.
//!
//! Results are bitwise deterministic regardless of thread count: each cell
//! simulates with the seed from its spec entry (nothing derived from thread
//! or completion order ever enters), so a single-cell sweep reproduces
//! `compare` on the same config exactly, and rows are emitted in grid
//! order. The grid order is region → capacity → horizon → variant → seed,
//! with policy innermost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::sim::SimResult;
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::sched::PolicyKind;
use crate::util::bench::Table;
use crate::util::json::Json;

/// A named config mutation — the generic sweep axis for knobs that are not
/// first-class (delay, elasticity, trace family, utilization, …). The label
/// is the variant's identity: rows report it and [`SweepSpec::config_for`]
/// resolves the mutation by it, so labels must be distinct within a spec
/// ([`SweepSpec::points`] panics on duplicates).
pub struct SweepVariant {
    pub label: String,
    f: Box<dyn Fn(&mut ExperimentConfig) + Send + Sync>,
}

impl SweepVariant {
    pub fn new(
        label: impl Into<String>,
        f: impl Fn(&mut ExperimentConfig) + Send + Sync + 'static,
    ) -> SweepVariant {
        SweepVariant { label: label.into(), f: Box::new(f) }
    }

    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        (self.f)(cfg)
    }
}

impl std::fmt::Debug for SweepVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SweepVariant({:?})", self.label)
    }
}

/// Declarative cartesian grid over experiment settings. Empty axes default
/// to the corresponding value from `base` (and `policies` to the paper's
/// headline set), so a fresh spec describes a single-cell grid.
pub struct SweepSpec {
    pub base: ExperimentConfig,
    /// Carbon-region keys (see `carbon::synth::Region`).
    pub regions: Vec<String>,
    /// Maximum cluster capacities M.
    pub capacities: Vec<usize>,
    /// Evaluation horizons, hours (history is clamped to ≥ horizon).
    pub horizons: Vec<usize>,
    /// Named config mutations (applied after the first-class axes).
    pub variants: Vec<SweepVariant>,
    /// Workload/trace seeds; each is mixed into a per-cell seed.
    pub seeds: Vec<u64>,
    /// Policies to run at every point.
    pub policies: Vec<PolicyKind>,
}

/// One grid point: a fully pinned experimental setting (everything except
/// the policy, which all shares this point's prepared state).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub region: String,
    pub capacity: usize,
    pub horizon_hours: usize,
    /// Label of the variant applied ("" when the axis is unused).
    pub variant: String,
    /// The spec-level seed entry this point simulates with (the config's
    /// seed, verbatim — so a single-cell sweep reproduces `compare`
    /// bitwise). Region/capacity/variant rows deliberately share their seed
    /// entry's draw: rows that differ in one knob then compare the same
    /// workload stream (common random numbers) instead of confounding the
    /// trend with resampling noise.
    pub seed: u64,
}

/// One result cell, in grid order.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub point: SweepPoint,
    pub kind: PolicyKind,
    pub result: SimResult,
    /// Carbon savings (%) vs. this point's carbon-agnostic baseline.
    pub savings_pct: f64,
}

fn axis_or<T: Clone>(axis: &[T], default: T) -> Vec<T> {
    if axis.is_empty() {
        vec![default]
    } else {
        axis.to_vec()
    }
}

impl SweepSpec {
    /// A single-cell spec over `base`; push onto the axis vectors to grow
    /// the grid.
    pub fn new(base: ExperimentConfig) -> SweepSpec {
        SweepSpec {
            base,
            regions: Vec::new(),
            capacities: Vec::new(),
            horizons: Vec::new(),
            variants: Vec::new(),
            seeds: Vec::new(),
            policies: Vec::new(),
        }
    }

    /// The policy axis (defaults to the paper's headline six).
    pub fn policies(&self) -> Vec<PolicyKind> {
        if self.policies.is_empty() {
            PolicyKind::HEADLINE.to_vec()
        } else {
            self.policies.clone()
        }
    }

    /// All grid points, in grid order (region → capacity → horizon →
    /// variant → seed).
    pub fn points(&self) -> Vec<SweepPoint> {
        let regions = axis_or(&self.regions, self.base.region.clone());
        let capacities = axis_or(&self.capacities, self.base.capacity);
        let horizons = axis_or(&self.horizons, self.base.horizon_hours);
        let variant_labels: Vec<String> = if self.variants.is_empty() {
            vec![String::new()]
        } else {
            self.variants.iter().map(|v| v.label.clone()).collect()
        };
        // Labels are identities ([`config_for`] resolves by label); a
        // duplicate would silently simulate the first variant twice.
        for (i, label) in variant_labels.iter().enumerate() {
            assert!(
                !variant_labels[..i].contains(label),
                "duplicate sweep variant label '{label}'"
            );
        }
        let seeds = axis_or(&self.seeds, self.base.seed);

        let mut points = Vec::new();
        for region in &regions {
            for &capacity in &capacities {
                for &horizon_hours in &horizons {
                    for variant in &variant_labels {
                        for &seed in &seeds {
                            points.push(SweepPoint {
                                region: region.clone(),
                                capacity,
                                horizon_hours,
                                variant: variant.clone(),
                                seed,
                            });
                        }
                    }
                }
            }
        }
        points
    }

    /// Total cells (points × policies).
    pub fn num_cells(&self) -> usize {
        self.points().len() * self.policies().len()
    }

    /// Materialize the config for one point.
    pub fn config_for(&self, point: &SweepPoint) -> ExperimentConfig {
        let mut cfg = self.base.clone();
        cfg.region = point.region.clone();
        cfg.capacity = point.capacity;
        cfg.horizon_hours = point.horizon_hours;
        if let Some(v) = self.variants.iter().find(|v| v.label == point.variant) {
            v.apply(&mut cfg);
        }
        // The learning window must cover at least the evaluation horizon.
        cfg.history_hours = cfg.history_hours.max(cfg.horizon_hours);
        cfg.seed = point.seed;
        cfg
    }
}

/// Executes a [`SweepSpec`] on a scoped thread pool.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    pub threads: usize,
}

impl SweepRunner {
    pub fn new(threads: usize) -> SweepRunner {
        SweepRunner { threads: threads.max(1) }
    }

    /// One worker per available core.
    pub fn auto() -> SweepRunner {
        SweepRunner::new(auto_threads())
    }

    /// Run the grid; rows come back in grid order (policy innermost)
    /// regardless of which worker finished which cell first.
    pub fn run(&self, spec: &SweepSpec) -> Vec<SweepRow> {
        let points = spec.points();
        let policies = spec.policies();
        let needs_kb = policies.contains(&PolicyKind::CarbonFlex);

        struct PreparedPoint {
            prep: Arc<PreparedExperiment>,
            baseline: Arc<SimResult>,
        }

        // Phase 1: prepare each point once (synthesis + learning + the
        // shared carbon-agnostic baseline), in parallel across points.
        let prepared: Vec<PreparedPoint> = par_map(self.threads, &points, |point, _| {
            let cfg = spec.config_for(point);
            let prep = PreparedExperiment::prepare(&cfg);
            if needs_kb {
                // Force the learning phase here so phase 2 cells only pay
                // for their own simulation.
                let _ = prep.knowledge_base();
            }
            let baseline = prep.run(PolicyKind::CarbonAgnostic);
            PreparedPoint { prep: Arc::new(prep), baseline: Arc::new(baseline) }
        });

        // Phase 2: every cell (point × policy) in parallel, sharing the
        // point's prepared state via Arc.
        let cells: Vec<(usize, PolicyKind)> = (0..points.len())
            .flat_map(|pi| policies.iter().map(move |&kind| (pi, kind)))
            .collect();
        par_map(self.threads, &cells, |&(pi, kind), _| {
            let pp = &prepared[pi];
            let result = if kind == PolicyKind::CarbonAgnostic {
                // Reuse the baseline run instead of simulating it again.
                (*pp.baseline).clone()
            } else {
                pp.prep.run(kind)
            };
            let savings_pct = result.metrics.savings_vs(&pp.baseline.metrics);
            SweepRow { point: points[pi].clone(), kind, result, savings_pct }
        })
    }
}

/// Number of workers to use when the caller does not say: one per core.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Order-preserving parallel map on a scoped thread pool. Workers pull
/// indices from a shared counter, so slow items never stall unrelated ones;
/// output slot `i` always holds `f(&items[i], i)`. With `threads <= 1` the
/// map runs inline on the caller's thread.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T, usize) -> R + Sync,
{
    let threads = usize::min(threads, items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, item)| f(item, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i], i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots.into_inner().unwrap().into_iter().map(|r| r.expect("every cell completed")).collect()
}

/// Print rows as a fixed-width table (the CLI's default output). The
/// variant column only appears when the spec used that axis.
pub fn print_table(rows: &[SweepRow]) {
    let with_variant = rows.iter().any(|r| !r.point.variant.is_empty());
    let mut headers = vec!["region", "M", "h", "seed"];
    if with_variant {
        headers.insert(3, "variant");
    }
    headers.extend_from_slice(&[
        "policy",
        "carbon (kg)",
        "savings %",
        "delay (h)",
        "viol",
        "unfin",
    ]);
    let mut t = Table::new(&headers);
    for r in rows {
        let m = &r.result.metrics;
        let mut cells = vec![
            r.point.region.clone(),
            format!("{}", r.point.capacity),
            format!("{}", r.point.horizon_hours),
            format!("{}", r.point.seed),
        ];
        if with_variant {
            cells.insert(3, r.point.variant.clone());
        }
        cells.extend([
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", r.savings_pct),
            format!("{:.2}", m.mean_delay_hours),
            format!("{}", m.violations),
            format!("{}", m.unfinished),
        ]);
        t.row(&cells);
    }
    t.print();
}

/// Rows as a JSON array (the CLI's `--json` output). Seeds are emitted as
/// strings: the JSON substrate stores numbers as f64, which cannot hold all
/// 64 bits.
pub fn to_json(rows: &[SweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let m = &r.result.metrics;
                Json::obj(vec![
                    ("region", Json::Str(r.point.region.clone())),
                    ("capacity", Json::Num(r.point.capacity as f64)),
                    ("horizon_hours", Json::Num(r.point.horizon_hours as f64)),
                    ("variant", Json::Str(r.point.variant.clone())),
                    ("seed", Json::Str(format!("{}", r.point.seed))),
                    ("policy", Json::Str(m.policy.clone())),
                    ("carbon_g", Json::Num(m.carbon_g)),
                    ("energy_kwh", Json::Num(m.energy_kwh)),
                    ("savings_pct", Json::Num(r.savings_pct)),
                    ("completed", Json::Num(m.completed as f64)),
                    ("unfinished", Json::Num(m.unfinished as f64)),
                    ("violations", Json::Num(m.violations as f64)),
                    ("mean_delay_hours", Json::Num(m.mean_delay_hours)),
                    ("p95_delay_hours", Json::Num(m.p95_delay_hours)),
                    ("mean_utilization", Json::Num(m.mean_utilization)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 10;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        cfg
    }

    #[test]
    fn empty_axes_default_to_base() {
        let spec = SweepSpec::new(tiny_base());
        let points = spec.points();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].region, "south-australia");
        assert_eq!(points[0].capacity, 10);
        assert_eq!(points[0].seed, 42);
        assert_eq!(spec.policies(), PolicyKind::HEADLINE.to_vec());
    }

    #[test]
    fn grid_order_is_region_major_policy_minor() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia".into(), "ontario".into()];
        spec.seeds = vec![1, 2];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let points = spec.points();
        assert_eq!(points.len(), 4);
        assert_eq!(points[0].region, "south-australia");
        assert_eq!(points[0].seed, 1);
        assert_eq!(points[1].seed, 2);
        assert_eq!(points[2].region, "ontario");
        assert_eq!(spec.num_cells(), 8);
    }

    #[test]
    fn seeds_are_verbatim_and_reorder_stable() {
        let mut a = SweepSpec::new(tiny_base());
        a.regions = vec!["south-australia".into(), "ontario".into()];
        a.seeds = vec![1, 2];
        let mut b = SweepSpec::new(tiny_base());
        b.regions = vec!["ontario".into(), "south-australia".into()];
        b.seeds = vec![2, 1];
        // A setting's config does not depend on where it sits in the grid,
        // and the simulated seed is the spec entry itself.
        for p in b.points() {
            let cfg = b.config_for(&p);
            assert_eq!(cfg.seed, p.seed);
            assert_eq!(cfg.region, p.region);
        }
        let a_pts: std::collections::BTreeSet<_> =
            a.points().iter().map(|p| (p.region.clone(), p.seed)).collect();
        let b_pts: std::collections::BTreeSet<_> =
            b.points().iter().map(|p| (p.region.clone(), p.seed)).collect();
        assert_eq!(a_pts, b_pts);
    }

    #[test]
    fn variants_share_the_draw_but_not_the_config() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.variants = vec![
            SweepVariant::new("d6", |cfg| cfg.uniform_delay_hours = Some(6.0)),
            SweepVariant::new("d24", |cfg| cfg.uniform_delay_hours = Some(24.0)),
        ];
        let points = spec.points();
        assert_eq!(points.len(), 2);
        // Common random numbers: single-knob rows compare the same draw.
        assert_eq!(points[0].seed, points[1].seed);
        let cfg = spec.config_for(&points[1]);
        assert_eq!(cfg.uniform_delay_hours, Some(24.0));
        assert_eq!(cfg.seed, points[1].seed);
    }

    #[test]
    #[should_panic(expected = "duplicate sweep variant label")]
    fn duplicate_variant_labels_panic() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.variants =
            vec![SweepVariant::new("x", |_| {}), SweepVariant::new("x", |_| {})];
        let _ = spec.points();
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let doubled = par_map(8, &items, |&x, i| {
            assert_eq!(x, i);
            x * 2
        });
        assert_eq!(doubled, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        // Serial path agrees.
        assert_eq!(par_map(1, &items, |&x, _| x * 2), doubled);
        // Empty input is fine.
        assert_eq!(par_map(4, &[] as &[usize], |&x, _| x), Vec::<usize>::new());
    }

    #[test]
    fn runner_emits_grid_order_with_shared_baseline() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia".into(), "ontario".into()];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let rows = SweepRunner::new(4).run(&spec);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].point.region, "south-australia");
        assert_eq!(rows[0].kind, PolicyKind::CarbonAgnostic);
        assert_eq!(rows[1].kind, PolicyKind::WaitAwhile);
        assert_eq!(rows[2].point.region, "ontario");
        for r in &rows {
            assert_eq!(r.result.metrics.unfinished, 0, "{:?}", r.point);
            assert!(r.result.metrics.carbon_g > 0.0);
        }
        // The agnostic rows are their own baselines.
        assert_eq!(rows[0].savings_pct, 0.0);
        assert_eq!(rows[2].savings_pct, 0.0);
    }
}
