//! Parallel sweep engine for paper-scale experiment grids.
//!
//! Every headline figure of the paper (Figs. 5–14) is a cartesian grid of
//! (region × policy × capacity × …) simulations. [`SweepSpec`] describes
//! such a grid declaratively; [`SweepRunner`] executes it on a scoped
//! `std::thread` pool (the crate is dependency-free, so no rayon):
//!
//! - **Phase 1** prepares each grid *point* — trace synthesis, workload
//!   generation, and the learning phase — exactly once, in parallel, and
//!   wraps the immutable prepared state in an `Arc`. The carbon-agnostic
//!   baseline also runs here, once per point.
//! - **Phase 2** runs every *cell* (point × policy) in parallel, sharing
//!   the prepared state via `Arc` instead of re-synthesizing or re-learning
//!   per policy.
//!
//! Two axes produce **composite cells** (see `experiments/cells.rs`):
//!
//! - A `regions` entry may be a `+`-joined **region set**
//!   ("south-australia+ontario"): the point becomes a multi-region spatial
//!   deployment — capacity split evenly, per-region carbon traces and
//!   knowledge bases, and a geo-dispatcher routing each arrival. The
//!   [`dispatchers`](SweepSpec::dispatchers) axis multiplies such points
//!   (single-region points ignore it); each dispatch strategy at a point
//!   prepares its own regional state, because the per-region knowledge
//!   bases are learned from that strategy's dispatch-skewed historical
//!   split (see `cells::prepare_spatial`).
//! - The [`weeks`](SweepSpec::weeks) axis turns points into **week-window
//!   cells** (the paper's year-long continuous-learning mode): weeks at the
//!   same point form a sequential learning chain — learn on the trailing
//!   history, push into a carried knowledge base, slide the rolling window
//!   with `KnowledgeBase::advance_window` — and each requested week gets an
//!   immutable snapshot, so its policy runs still execute in parallel. The
//!   chain always walks weeks `0..=max`, which makes any subset sweep
//!   bitwise identical to the same weeks of a full sweep.
//!
//! Results are bitwise deterministic regardless of thread count: each cell
//! simulates with the seed from its spec entry (nothing derived from thread
//! or completion order ever enters), so a single-cell sweep reproduces
//! `compare` on the same config exactly — and a single spatial or week cell
//! reproduces the legacy `run_spatial_prepared` / `run_yearlong` outputs
//! (pinned by their in-test reference implementations). Rows are emitted in
//! grid order: region → dispatch → capacity → horizon → week → variant →
//! dag shape → faults → seed, with policy innermost.
//!
//! Two further batching features (§Perf):
//!
//! - **Cross-cell memoized preparation**: plain points whose configs share a
//!   [`prep_hash`](crate::experiments::runner::prep_hash) — i.e. differ only
//!   in knobs downstream of preparation, such as `knn_k` variants — form one
//!   phase-1a group. The first point synthesizes and learns; the rest
//!   [`rebind`](PreparedExperiment::rebind) the shared state, so a k-sweep
//!   over one workload pays for synthesis + learning exactly once
//!   ([`SweepRunner::run_with_stats`] exposes the counters).
//! - **Multi-process sharding**: [`SweepSpec::shard`] = `(i, n)` restricts a
//!   run to the `i`-th of `n` contiguous slices of the point list. Because
//!   every cell is self-seeded and week chains always walk from week 0,
//!   concatenating the rows of shards `0/n .. (n-1)/n` is bitwise identical
//!   to the unsharded grid — the contract behind `carbonflex sweep --shard`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::sim::SimResult;
use crate::config::{DagShape, ExperimentConfig};
use crate::experiments::cells::{self, DispatchStrategy, SpatialPrep, WeekCell};
use crate::experiments::runner::{prep_hash, PreparedExperiment};
use crate::faults::{FaultPlan, FaultSpec};
use crate::sched::PolicyKind;
use crate::util::bench::Table;
use crate::util::json::Json;

/// Default knowledge-base aging window for week-window cells (paper §4.2:
/// a rolling window; ~4 weeks).
pub const DEFAULT_AGING_WINDOW_HOURS: usize = 24 * 28;

/// A named config mutation — the generic sweep axis for knobs that are not
/// first-class (delay, elasticity, trace family, utilization, …). The label
/// is the variant's identity: rows report it and [`SweepSpec::config_for`]
/// resolves the mutation by it, so labels must be distinct within a spec
/// ([`SweepSpec::points`] panics on duplicates).
pub struct SweepVariant {
    pub label: String,
    f: Box<dyn Fn(&mut ExperimentConfig) + Send + Sync>,
}

impl SweepVariant {
    pub fn new(
        label: impl Into<String>,
        f: impl Fn(&mut ExperimentConfig) + Send + Sync + 'static,
    ) -> SweepVariant {
        SweepVariant { label: label.into(), f: Box::new(f) }
    }

    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        (self.f)(cfg)
    }
}

impl std::fmt::Debug for SweepVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SweepVariant({:?})", self.label)
    }
}

/// Declarative cartesian grid over experiment settings. Empty axes default
/// to the corresponding value from `base` (and `policies` to the paper's
/// headline set), so a fresh spec describes a single-cell grid.
pub struct SweepSpec {
    pub base: ExperimentConfig,
    /// Carbon-region keys (see `carbon::synth::Region`). An entry may be a
    /// `+`-joined set ("south-australia+ontario"), which makes its points
    /// multi-region spatial cells (capacity split evenly, geo-dispatched
    /// arrivals, per-region knowledge bases).
    pub regions: Vec<String>,
    /// Geo-dispatch strategies for region-*set* entries (defaults to
    /// round-robin). Single-region points ignore this axis.
    pub dispatchers: Vec<DispatchStrategy>,
    /// Maximum cluster capacities M.
    pub capacities: Vec<usize>,
    /// Evaluation horizons, hours (history is clamped to ≥ horizon).
    pub horizons: Vec<usize>,
    /// Week-window indices for continuous-learning cells. When non-empty,
    /// every point evaluates 168 h weekly windows (the horizons axis must
    /// stay empty) after a sequential learning chain over weeks `0..=max`;
    /// multi-region `+` sets cannot combine with this axis.
    pub weeks: Vec<usize>,
    /// Knowledge-base rolling window for the week-window axis, hours.
    pub aging_window_hours: usize,
    /// Named config mutations (applied after the first-class axes).
    pub variants: Vec<SweepVariant>,
    /// Fault-injection presets (see `faults::FaultSpec::preset`; defaults
    /// to `["none"]`). A non-"none" entry makes its points simulate under a
    /// [`FaultPlan`] generated from `(point.seed, preset)`. The axis stays
    /// out of [`config_for`](SweepSpec::config_for), so faulted and clean
    /// points at the same setting share one memoized preparation; it cannot
    /// combine with multi-region `+` sets or the week-window axis.
    pub faults: Vec<String>,
    /// DAG-shape labels (see `config::DagShape::parse`; defaults to
    /// `["none"]`). Unlike the faults axis, a shape DOES enter
    /// [`config_for`](SweepSpec::config_for): it rewrites trace generation
    /// itself, so shaped and flat points at one setting prepare in separate
    /// [`prep_hash`] memoization groups. The axis cannot combine with
    /// multi-region `+` sets or the week-window axis (the composite-cell
    /// drivers have no eligibility-gating path).
    pub dag_shapes: Vec<String>,
    /// Workload/trace seeds; each is mixed into a per-cell seed.
    pub seeds: Vec<u64>,
    /// Policies to run at every point.
    pub policies: Vec<PolicyKind>,
    /// Pre-prepared regional experiments injected by the
    /// `run_spatial_prepared` adapter (must match the spec's single region
    /// set, in order). Empty = the runner prepares regions itself.
    pub spatial_preps: Vec<Arc<PreparedExperiment>>,
    /// Deterministic multi-process partitioning: `Some((i, n))` runs only
    /// the `i`-th of `n` contiguous slices of [`points`](SweepSpec::points)
    /// (0-based; slice `i` is `points[i*len/n .. (i+1)*len/n]`). Rows of all
    /// shards, concatenated in shard order, are bitwise identical to the
    /// unsharded run. `None` = the whole grid.
    pub shard: Option<(usize, usize)>,
}

/// One grid point: a fully pinned experimental setting (everything except
/// the policy, which all shares this point's prepared state).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// Region key, or a `+`-joined set for spatial points.
    pub region: String,
    /// Dispatch-strategy label for spatial points ("" otherwise).
    pub dispatch: String,
    pub capacity: usize,
    pub horizon_hours: usize,
    /// Week index for week-window cells (`None` when the axis is unused;
    /// such points always evaluate a 168 h window).
    pub week: Option<usize>,
    /// Label of the variant applied ("" when the axis is unused).
    pub variant: String,
    /// Fault-preset label ("none" when the axis is unused).
    pub faults: String,
    /// DAG-shape label ("none" when the axis is unused).
    pub dag_shape: String,
    /// The spec-level seed entry this point simulates with (the config's
    /// seed, verbatim — so a single-cell sweep reproduces `compare`
    /// bitwise). Region/capacity/variant rows deliberately share their seed
    /// entry's draw: rows that differ in one knob then compare the same
    /// workload stream (common random numbers) instead of confounding the
    /// trend with resampling noise.
    pub seed: u64,
}

impl SweepPoint {
    /// Whether this point is a multi-region spatial cell.
    pub fn is_spatial(&self) -> bool {
        self.region.contains('+')
    }
}

/// One result cell, in grid order.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub point: SweepPoint,
    pub kind: PolicyKind,
    pub result: SimResult,
    /// Carbon savings (%) vs. this point's carbon-agnostic baseline (same
    /// dispatch strategy for spatial points, same week for week cells).
    pub savings_pct: f64,
    /// Spatial cells: jobs routed to each region of the set, in set order.
    pub jobs_per_region: Option<Vec<usize>>,
    /// Week cells: live knowledge-base cases after the window slide.
    pub kb_live: Option<usize>,
    /// Week cells: mean CI of the evaluation week (seasonality indicator).
    pub mean_ci: Option<f64>,
}

fn axis_or<T: Clone>(axis: &[T], default: T) -> Vec<T> {
    if axis.is_empty() {
        vec![default]
    } else {
        axis.to_vec()
    }
}

impl SweepSpec {
    /// A single-cell spec over `base`; push onto the axis vectors to grow
    /// the grid.
    pub fn new(base: ExperimentConfig) -> SweepSpec {
        SweepSpec {
            base,
            regions: Vec::new(),
            dispatchers: Vec::new(),
            capacities: Vec::new(),
            horizons: Vec::new(),
            weeks: Vec::new(),
            aging_window_hours: DEFAULT_AGING_WINDOW_HOURS,
            variants: Vec::new(),
            faults: Vec::new(),
            dag_shapes: Vec::new(),
            seeds: Vec::new(),
            policies: Vec::new(),
            spatial_preps: Vec::new(),
            shard: None,
        }
    }

    /// The policy axis (defaults to the paper's headline six).
    pub fn policies(&self) -> Vec<PolicyKind> {
        if self.policies.is_empty() {
            PolicyKind::HEADLINE.to_vec()
        } else {
            self.policies.clone()
        }
    }

    /// All grid points, in grid order (region → dispatch → capacity →
    /// horizon → week → variant → dag shape → faults → seed).
    pub fn points(&self) -> Vec<SweepPoint> {
        let regions = axis_or(&self.regions, self.base.region.clone());
        let dispatchers = axis_or(&self.dispatchers, DispatchStrategy::RoundRobin);
        for (i, d) in dispatchers.iter().enumerate() {
            assert!(!dispatchers[..i].contains(d), "duplicate dispatch strategy {d:?}");
        }
        let capacities = axis_or(&self.capacities, self.base.capacity);
        let horizons = axis_or(&self.horizons, self.base.horizon_hours);
        let weeks: Vec<Option<usize>> = if self.weeks.is_empty() {
            vec![None]
        } else {
            assert!(
                self.horizons.is_empty(),
                "the week-window axis pins each cell's horizon to 168 h; clear the horizons axis"
            );
            assert!(
                !regions.iter().any(|r| r.contains('+')),
                "week-window cells cannot combine with multi-region '+' sets"
            );
            for (i, w) in self.weeks.iter().enumerate() {
                assert!(!self.weeks[..i].contains(w), "duplicate week index {w}");
            }
            self.weeks.iter().map(|&w| Some(w)).collect()
        };
        let variant_labels: Vec<String> = if self.variants.is_empty() {
            vec![String::new()]
        } else {
            self.variants.iter().map(|v| v.label.clone()).collect()
        };
        // Labels are identities ([`config_for`] resolves by label); a
        // duplicate would silently simulate the first variant twice.
        for (i, label) in variant_labels.iter().enumerate() {
            assert!(
                !variant_labels[..i].contains(label),
                "duplicate sweep variant label '{label}'"
            );
        }
        let faults = axis_or(&self.faults, "none".to_string());
        for (i, f) in faults.iter().enumerate() {
            assert!(FaultSpec::preset(f).is_some(), "unknown fault preset '{f}'");
            assert!(!faults[..i].contains(f), "duplicate fault preset '{f}'");
        }
        if faults.iter().any(|f| f != "none") {
            // Composite cells run through their own drivers, which have no
            // fault-plan path; restricting the axis keeps their bitwise
            // contracts untouched.
            assert!(
                !regions.iter().any(|r| r.contains('+')),
                "the faults axis cannot combine with multi-region '+' sets"
            );
            assert!(
                self.weeks.is_empty(),
                "the faults axis cannot combine with the week-window axis"
            );
        }
        let dag_shapes = axis_or(&self.dag_shapes, "none".to_string());
        for (i, d) in dag_shapes.iter().enumerate() {
            assert!(DagShape::parse(d).is_ok(), "unknown dag shape '{d}'");
            assert!(!dag_shapes[..i].contains(d), "duplicate dag shape '{d}'");
        }
        if dag_shapes.iter().any(|d| d != "none") {
            // Same restriction (and reason) as the faults axis: the
            // composite-cell drivers have no dependency-gating path.
            assert!(
                !regions.iter().any(|r| r.contains('+')),
                "the dag-shape axis cannot combine with multi-region '+' sets"
            );
            assert!(
                self.weeks.is_empty(),
                "the dag-shape axis cannot combine with the week-window axis"
            );
        }
        let seeds = axis_or(&self.seeds, self.base.seed);

        let mut points = Vec::new();
        for region in &regions {
            // The dispatch axis only multiplies multi-region sets; plain
            // points carry the empty label.
            let dispatches: Vec<String> = if region.contains('+') {
                dispatchers.iter().map(|d| d.as_str().to_string()).collect()
            } else {
                vec![String::new()]
            };
            for dispatch in &dispatches {
                for &capacity in &capacities {
                    for &horizon_hours in &horizons {
                        for &week in &weeks {
                            for variant in &variant_labels {
                                for dag in &dag_shapes {
                                    for fault in &faults {
                                        for &seed in &seeds {
                                            points.push(SweepPoint {
                                                region: region.clone(),
                                                dispatch: dispatch.clone(),
                                                capacity,
                                                // Week cells always evaluate
                                                // one 168 h week.
                                                horizon_hours: if week.is_some() {
                                                    168
                                                } else {
                                                    horizon_hours
                                                },
                                                week,
                                                variant: variant.clone(),
                                                faults: fault.clone(),
                                                dag_shape: dag.clone(),
                                                seed,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        points
    }

    /// Total cells (points × policies).
    pub fn num_cells(&self) -> usize {
        self.points().len() * self.policies().len()
    }

    /// Materialize the config for one point.
    pub fn config_for(&self, point: &SweepPoint) -> ExperimentConfig {
        let mut cfg = self.base.clone();
        cfg.region = point.region.clone();
        cfg.capacity = point.capacity;
        cfg.horizon_hours = point.horizon_hours;
        // Unlike `point.faults` below, the DAG shape MUST enter the config:
        // it changes trace generation itself, so shaped points land in their
        // own [`prep_hash`] groups and prepare separately.
        cfg.dag_shape = DagShape::parse(&point.dag_shape)
            .unwrap_or_else(|_| panic!("unknown dag shape '{}'", point.dag_shape));
        if let Some(v) = self.variants.iter().find(|v| v.label == point.variant) {
            v.apply(&mut cfg);
        }
        if point.week.is_none() && !point.is_spatial() {
            // The learning window must cover at least the evaluation
            // horizon. (Week chains keep `history_hours` as their learning
            // window verbatim, and spatial cells pass the config through to
            // per-region preparations unclamped — both matching the legacy
            // drivers bit for bit.)
            cfg.history_hours = cfg.history_hours.max(cfg.horizon_hours);
        }
        // `point.faults` deliberately never enters the config: preparation
        // is fault-independent, so faulted and clean points stay in one
        // [`prep_hash`] memoization group.
        cfg.seed = point.seed;
        cfg
    }

    /// The concrete fault plan for one point: empty for "none", otherwise
    /// generated deterministically from the point's own seed and setting.
    pub fn plan_for(&self, point: &SweepPoint) -> FaultPlan {
        if point.faults.is_empty() || point.faults == "none" {
            return FaultPlan::none();
        }
        let fspec = FaultSpec::preset(&point.faults)
            .unwrap_or_else(|| panic!("unknown fault preset '{}'", point.faults));
        FaultPlan::generate(point.seed, &fspec, point.horizon_hours, point.capacity, 1)
    }

    /// Apply the optional `[sweep]` table of an experiment TOML, so a
    /// config file can pin a whole grid declaratively:
    ///
    /// ```toml
    /// [sweep]
    /// regions = ["south-australia", "south-australia+ontario"]
    /// dispatch = ["round-robin", "lowest-window-ci"]
    /// capacities = [100, 150]
    /// seeds = [1, 2]
    /// weeks = [0, 1, 2, 3]
    /// faults = ["none", "light"]
    /// dag_shapes = ["none", "chains"]
    /// aging_window_hours = 672
    /// policies = ["agnostic", "carbonflex", "oracle"]
    /// ```
    ///
    /// Axes present in the file replace the spec's; absent ones are left
    /// untouched (the CLI applies its flags afterwards, so flags override
    /// the file per axis).
    pub fn apply_toml_axes(&mut self, src: &str) -> Result<(), String> {
        use crate::carbon::synth::Region;
        use crate::config::toml::{self, Value};
        let root = toml::parse(src).map_err(|e| e.to_string())?;
        let Some(sweep) = root.get("sweep") else {
            return Ok(());
        };
        fn str_list(v: &Value, field: &str) -> Result<Vec<String>, String> {
            v.as_arr()
                .ok_or_else(|| format!("sweep.{field}: expected an array"))?
                .iter()
                .map(|e| {
                    e.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("sweep.{field}: expected strings"))
                })
                .collect()
        }
        fn int_list(v: &Value, field: &str) -> Result<Vec<usize>, String> {
            v.as_arr()
                .ok_or_else(|| format!("sweep.{field}: expected an array"))?
                .iter()
                .map(|e| match e.as_int() {
                    Some(i) if i >= 0 => Ok(i as usize),
                    _ => Err(format!("sweep.{field}: expected non-negative integers")),
                })
                .collect()
        }
        if let Some(v) = sweep.get("regions") {
            // Store the canonical trimmed '+'-joined keys, not the raw
            // entries — a padded "ontario " must not sneak past validation
            // only to panic inside preparation.
            let mut canonical = Vec::new();
            for entry in &str_list(v, "regions")? {
                let keys: Result<Vec<String>, String> = entry
                    .split('+')
                    .map(|key| {
                        Region::parse(key.trim())
                            .map(|r| r.key().to_string())
                            .ok_or_else(|| format!("sweep.regions: unknown region '{key}'"))
                    })
                    .collect();
                canonical.push(keys?.join("+"));
            }
            self.regions = canonical;
        }
        if let Some(v) = sweep.get("dispatch") {
            self.dispatchers = str_list(v, "dispatch")?
                .iter()
                .map(|s| {
                    DispatchStrategy::parse(s)
                        .ok_or_else(|| format!("sweep.dispatch: unknown strategy '{s}'"))
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("capacities") {
            self.capacities = int_list(v, "capacities")?;
        }
        if let Some(v) = sweep.get("horizons") {
            self.horizons = int_list(v, "horizons")?;
        }
        if let Some(v) = sweep.get("weeks") {
            self.weeks = int_list(v, "weeks")?;
        }
        if let Some(v) = sweep.get("faults") {
            let labels = str_list(v, "faults")?;
            for f in &labels {
                if FaultSpec::preset(f).is_none() {
                    return Err(format!("sweep.faults: unknown fault preset '{f}'"));
                }
            }
            self.faults = labels;
        }
        if let Some(v) = sweep.get("dag_shapes") {
            let labels = str_list(v, "dag_shapes")?;
            for d in &labels {
                if DagShape::parse(d).is_err() {
                    return Err(format!("sweep.dag_shapes: unknown dag shape '{d}'"));
                }
            }
            self.dag_shapes = labels;
        }
        if let Some(v) = sweep.get("aging_window_hours") {
            match v.as_int() {
                Some(h) if h > 0 => self.aging_window_hours = h as usize,
                _ => return Err("sweep.aging_window_hours: expected a positive integer".into()),
            }
        }
        if let Some(v) = sweep.get("seeds") {
            self.seeds = v
                .as_arr()
                .ok_or_else(|| "sweep.seeds: expected an array".to_string())?
                .iter()
                .map(|e| {
                    e.as_int()
                        .map(|i| i as u64)
                        .ok_or_else(|| "sweep.seeds: expected integers".to_string())
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("policies") {
            self.policies = str_list(v, "policies")?
                .iter()
                .map(|s| {
                    PolicyKind::parse(s)
                        .ok_or_else(|| format!("sweep.policies: unknown policy '{s}'"))
                })
                .collect::<Result<_, _>>()?;
        }
        Ok(())
    }
}

/// Per-point prepared state: plain, spatial, or week-window.
enum PointPrep {
    Single(Arc<PreparedExperiment>),
    Spatial(Arc<SpatialPrep>),
    Week(Arc<WeekCell>),
}

/// A phase-1 preparation unit: points that share prepared state. Plain
/// points with hash-equal prepared inputs ([`prep_hash`]) form one memoized
/// group (first prepares, rest rebind); spatial points at the same
/// (setting, dispatch strategy) share regional preparations across local
/// policies; week points at the same setting form one sequential learning
/// chain.
enum PrepUnit {
    Single(Vec<usize>),
    Spatial(Vec<usize>),
    WeekChain(Vec<usize>),
}

/// Phase-1 work counters from [`SweepRunner::run_with_stats`]: how many
/// plain (non-composite) grid points actually paid for preparation (trace
/// synthesis + workload generation) and for the learning phase. With
/// cross-cell memoization, [`prep_hash`]-equal points share one
/// preparation, so `prepares` counts distinct hash groups — not points.
/// Composite (spatial / week-chain) units keep their own sharing and are
/// not counted here.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrepStats {
    /// `PreparedExperiment::prepare` executions for plain points.
    pub prepares: usize,
    /// Learning-phase (`knowledge_base()`) executions forced in phase 1a.
    pub learns: usize,
}

/// Executes a [`SweepSpec`] on a scoped thread pool.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    pub threads: usize,
}

impl SweepRunner {
    pub fn new(threads: usize) -> SweepRunner {
        SweepRunner { threads: threads.max(1) }
    }

    /// One worker per available core.
    pub fn auto() -> SweepRunner {
        SweepRunner::new(auto_threads())
    }

    /// Run the grid; rows come back in grid order (policy innermost)
    /// regardless of which worker finished which cell first.
    pub fn run(&self, spec: &SweepSpec) -> Vec<SweepRow> {
        self.run_with_stats(spec).0
    }

    /// [`run`](SweepRunner::run), plus the phase-1 [`PrepStats`] counters —
    /// the probe the memoization tests assert on (a k-sweep over one
    /// workload must report `prepares == 1`).
    pub fn run_with_stats(&self, spec: &SweepSpec) -> (Vec<SweepRow>, PrepStats) {
        let mut points = spec.points();
        if let Some((i, n)) = spec.shard {
            assert!(n > 0, "shard denominator must be positive");
            assert!(i < n, "shard index {i} out of range for {n} shards");
            let len = points.len();
            points = points[i * len / n..(i + 1) * len / n].to_vec();
        }
        let policies = spec.policies();
        let needs_kb = policies.contains(&PolicyKind::CarbonFlex);
        let prepares = AtomicUsize::new(0);
        let learns = AtomicUsize::new(0);

        // --- Phase 1a: prepared state, one unit per sharing group. ---
        let mut unit_of: HashMap<(String, String, usize, usize, String, u64), usize> =
            HashMap::new();
        let mut single_of: HashMap<u64, usize> = HashMap::new();
        let mut units: Vec<PrepUnit> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            if p.is_spatial() || p.week.is_some() {
                // Dispatch enters the key: spatial preparation learns the
                // per-region knowledge bases from the dispatch-skewed
                // historical split, so strategies no longer share prepared
                // state. (Week points carry the empty dispatch label.)
                let key = (
                    p.region.clone(),
                    p.dispatch.clone(),
                    p.capacity,
                    p.horizon_hours,
                    p.variant.clone(),
                    p.seed,
                );
                match unit_of.get(&key) {
                    Some(&u) => match &mut units[u] {
                        PrepUnit::Spatial(v) | PrepUnit::WeekChain(v) => v.push(i),
                        PrepUnit::Single(_) => unreachable!("singles are keyed separately"),
                    },
                    None => {
                        unit_of.insert(key, units.len());
                        units.push(if p.is_spatial() {
                            PrepUnit::Spatial(vec![i])
                        } else {
                            PrepUnit::WeekChain(vec![i])
                        });
                    }
                }
            } else {
                // Plain points group by prepared-input content hash: cells
                // that differ only in downstream knobs (knn_k, tolerance,
                // distance bound) share one synthesis + learning pass.
                let h = prep_hash(&spec.config_for(p));
                match single_of.get(&h) {
                    Some(&u) => match &mut units[u] {
                        PrepUnit::Single(v) => v.push(i),
                        _ => unreachable!("hash groups only hold singles"),
                    },
                    None => {
                        single_of.insert(h, units.len());
                        units.push(PrepUnit::Single(vec![i]));
                    }
                }
            }
        }
        let unit_results: Vec<Vec<(usize, PointPrep)>> =
            par_map(self.threads, &units, |unit, _| match unit {
                PrepUnit::Single(idxs) => {
                    let cfg = spec.config_for(&points[idxs[0]]);
                    let prep = PreparedExperiment::prepare(&cfg);
                    prepares.fetch_add(1, Ordering::Relaxed);
                    if needs_kb {
                        // Force the learning phase here so phase 2 cells
                        // only pay for their own simulation.
                        let _ = prep.knowledge_base();
                        learns.fetch_add(1, Ordering::Relaxed);
                    }
                    let first = Arc::new(prep);
                    idxs.iter()
                        .map(|&i| {
                            if i == idxs[0] {
                                (i, PointPrep::Single(first.clone()))
                            } else {
                                // Hash-equal cell: same prepared inputs,
                                // different downstream knobs — rebind
                                // instead of re-preparing.
                                let cell_cfg = spec.config_for(&points[i]);
                                (i, PointPrep::Single(Arc::new(first.rebind(&cell_cfg))))
                            }
                        })
                        .collect()
                }
                PrepUnit::Spatial(idxs) => {
                    let cfg = spec.config_for(&points[idxs[0]]);
                    let regions = cells::parse_region_set(&points[idxs[0]].region);
                    let strategy = DispatchStrategy::parse(&points[idxs[0]].dispatch)
                        .expect("dispatch label");
                    let sp = if spec.spatial_preps.is_empty() {
                        cells::prepare_spatial(&cfg, &regions, strategy)
                    } else {
                        // Injected pre-prepared regional state (the
                        // `run_spatial_prepared` adapter); must match this
                        // unit's setting, not just its region keys —
                        // otherwise a multi-point spec would silently reuse
                        // preparations from the wrong seed/capacity/horizon.
                        assert_eq!(
                            spec.spatial_preps.len(),
                            regions.len(),
                            "spatial_preps does not match the region set"
                        );
                        let per_region_capacity = (cfg.capacity / regions.len()).max(1);
                        for (p, r) in spec.spatial_preps.iter().zip(&regions) {
                            assert_eq!(p.cfg.region, r.key(), "spatial_preps region mismatch");
                            assert_eq!(
                                p.cfg.capacity, per_region_capacity,
                                "spatial_preps capacity mismatch"
                            );
                            assert_eq!(p.cfg.seed, cfg.seed, "spatial_preps seed mismatch");
                            assert_eq!(
                                p.cfg.horizon_hours, cfg.horizon_hours,
                                "spatial_preps horizon mismatch"
                            );
                        }
                        SpatialPrep { regions, preps: spec.spatial_preps.clone() }
                    };
                    if needs_kb {
                        for p in &sp.preps {
                            let _ = p.knowledge_base();
                        }
                    }
                    let sp = Arc::new(sp);
                    idxs.iter().map(|&i| (i, PointPrep::Spatial(sp.clone()))).collect()
                }
                PrepUnit::WeekChain(idxs) => {
                    let cfg = spec.config_for(&points[idxs[0]]);
                    // The chain emits cells in ascending week order; zip
                    // them back to point indices sorted the same way (the
                    // weeks axis may be listed in any order).
                    let mut order: Vec<usize> = idxs.clone();
                    order.sort_by_key(|&i| points[i].week.unwrap());
                    let weeks: Vec<usize> =
                        order.iter().map(|&i| points[i].week.unwrap()).collect();
                    // The chain's learning passes are its dominant cost;
                    // skip them when no requested policy reads the KB.
                    let chain =
                        cells::prepare_week_chain(&cfg, &weeks, spec.aging_window_hours, needs_kb);
                    order
                        .into_iter()
                        .zip(chain)
                        .map(|(i, cell)| (i, PointPrep::Week(Arc::new(cell))))
                        .collect()
                }
            });
        let mut slots: Vec<Option<PointPrep>> = (0..points.len()).map(|_| None).collect();
        for unit in unit_results {
            for (i, pp) in unit {
                slots[i] = Some(pp);
            }
        }
        let preps: Vec<PointPrep> =
            slots.into_iter().map(|p| p.expect("every point prepared")).collect();

        // --- Phase 1b: the per-point carbon-agnostic baseline. ---
        struct Baseline {
            result: Arc<SimResult>,
            jobs_per_region: Option<Arc<Vec<usize>>>,
        }
        let point_idxs: Vec<usize> = (0..points.len()).collect();
        let baselines: Vec<Baseline> = par_map(self.threads, &point_idxs, |&pi, _| {
            match &preps[pi] {
                PointPrep::Single(p) => Baseline {
                    // Faulted points compare policies under the *same*
                    // fault plan; an empty plan takes the exact `run` path.
                    result: Arc::new(
                        p.run_with_plan(PolicyKind::CarbonAgnostic, &spec.plan_for(&points[pi])),
                    ),
                    jobs_per_region: None,
                },
                PointPrep::Week(w) => Baseline {
                    result: Arc::new(w.prep.run(PolicyKind::CarbonAgnostic)),
                    jobs_per_region: None,
                },
                PointPrep::Spatial(sp) => {
                    let point = &points[pi];
                    let cfg = spec.config_for(point);
                    let strategy =
                        DispatchStrategy::parse(&point.dispatch).expect("dispatch label");
                    let (r, jpr) =
                        cells::run_spatial_cell(&cfg, sp, strategy, PolicyKind::CarbonAgnostic);
                    Baseline { result: Arc::new(r), jobs_per_region: Some(Arc::new(jpr)) }
                }
            }
        });

        // --- Phase 2: every cell (point × policy) in parallel. ---
        let cell_list: Vec<(usize, PolicyKind)> = (0..points.len())
            .flat_map(|pi| policies.iter().map(move |&kind| (pi, kind)))
            .collect();
        let rows = par_map(self.threads, &cell_list, |&(pi, kind), _| {
            let point = &points[pi];
            let bl = &baselines[pi];
            let (result, jobs_per_region) = if kind == PolicyKind::CarbonAgnostic {
                // Reuse the baseline run instead of simulating it again.
                ((*bl.result).clone(), bl.jobs_per_region.as_deref().cloned())
            } else {
                match &preps[pi] {
                    PointPrep::Single(p) => (p.run_with_plan(kind, &spec.plan_for(point)), None),
                    PointPrep::Week(w) => (w.prep.run(kind), None),
                    PointPrep::Spatial(sp) => {
                        let cfg = spec.config_for(point);
                        let strategy =
                            DispatchStrategy::parse(&point.dispatch).expect("dispatch label");
                        let (r, jpr) = cells::run_spatial_cell(&cfg, sp, strategy, kind);
                        (r, Some(jpr))
                    }
                }
            };
            let savings_pct = result.metrics.savings_vs(&bl.result.metrics);
            let (kb_live, mean_ci) = match &preps[pi] {
                PointPrep::Week(w) => (Some(w.kb_live), Some(w.mean_ci)),
                _ => (None, None),
            };
            SweepRow {
                point: point.clone(),
                kind,
                result,
                savings_pct,
                jobs_per_region,
                kb_live,
                mean_ci,
            }
        });
        (
            rows,
            PrepStats {
                prepares: prepares.load(Ordering::Relaxed),
                learns: learns.load(Ordering::Relaxed),
            },
        )
    }
}

/// Number of workers to use when the caller does not say: one per core.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Order-preserving parallel map on a scoped thread pool. Workers pull
/// indices from a shared counter, so slow items never stall unrelated ones;
/// output slot `i` always holds `f(&items[i], i)`. With `threads <= 1` the
/// map runs inline on the caller's thread.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T, usize) -> R + Sync,
{
    let threads = usize::min(threads, items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, item)| f(item, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i], i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots.into_inner().unwrap().into_iter().map(|r| r.expect("every cell completed")).collect()
}

/// Print rows as a fixed-width table (the CLI's default output). The
/// dispatch/week/variant columns only appear when the spec used those axes.
pub fn print_table(rows: &[SweepRow]) {
    let with_dispatch = rows.iter().any(|r| !r.point.dispatch.is_empty());
    let with_week = rows.iter().any(|r| r.point.week.is_some());
    let with_variant = rows.iter().any(|r| !r.point.variant.is_empty());
    let with_faults = rows.iter().any(|r| !r.point.faults.is_empty() && r.point.faults != "none");
    let with_dag =
        rows.iter().any(|r| !r.point.dag_shape.is_empty() && r.point.dag_shape != "none");
    let mut headers = vec!["region"];
    if with_dispatch {
        headers.push("dispatch");
    }
    headers.extend_from_slice(&["M", "h"]);
    if with_week {
        headers.push("week");
    }
    if with_variant {
        headers.push("variant");
    }
    if with_faults {
        headers.push("faults");
    }
    if with_dag {
        headers.push("dag");
    }
    headers.push("seed");
    headers.extend_from_slice(&[
        "policy",
        "carbon (kg)",
        "savings %",
        "delay (h)",
        "viol",
        "unfin",
    ]);
    let mut t = Table::new(&headers);
    for r in rows {
        let m = &r.result.metrics;
        let mut cells = vec![r.point.region.clone()];
        if with_dispatch {
            cells.push(r.point.dispatch.clone());
        }
        cells.push(format!("{}", r.point.capacity));
        cells.push(format!("{}", r.point.horizon_hours));
        if with_week {
            cells.push(r.point.week.map(|w| format!("{w}")).unwrap_or_default());
        }
        if with_variant {
            cells.push(r.point.variant.clone());
        }
        if with_faults {
            cells.push(r.point.faults.clone());
        }
        if with_dag {
            cells.push(r.point.dag_shape.clone());
        }
        cells.push(format!("{}", r.point.seed));
        cells.extend([
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", r.savings_pct),
            format!("{:.2}", m.mean_delay_hours),
            format!("{}", m.violations),
            format!("{}", m.unfinished),
        ]);
        t.row(&cells);
    }
    t.print();
}

/// Rows as a JSON array (the CLI's `--json` output). Seeds are emitted as
/// strings: the JSON substrate stores numbers as f64, which cannot hold all
/// 64 bits. Composite-cell extras (`jobs_per_region`, `kb_live_cases`,
/// `mean_ci`) appear only on the rows that carry them.
pub fn to_json(rows: &[SweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let m = &r.result.metrics;
                let mut fields = vec![
                    ("region", Json::Str(r.point.region.clone())),
                    ("dispatch", Json::Str(r.point.dispatch.clone())),
                    ("capacity", Json::Num(r.point.capacity as f64)),
                    ("horizon_hours", Json::Num(r.point.horizon_hours as f64)),
                    (
                        "week",
                        r.point.week.map(|w| Json::Num(w as f64)).unwrap_or(Json::Null),
                    ),
                    ("variant", Json::Str(r.point.variant.clone())),
                    ("faults", Json::Str(r.point.faults.clone())),
                    ("dag_shape", Json::Str(r.point.dag_shape.clone())),
                    ("seed", Json::Str(format!("{}", r.point.seed))),
                    ("policy", Json::Str(m.policy.clone())),
                    ("carbon_g", Json::Num(m.carbon_g)),
                    ("energy_kwh", Json::Num(m.energy_kwh)),
                    ("savings_pct", Json::Num(r.savings_pct)),
                    ("completed", Json::Num(m.completed as f64)),
                    ("unfinished", Json::Num(m.unfinished as f64)),
                    ("violations", Json::Num(m.violations as f64)),
                    ("mean_delay_hours", Json::Num(m.mean_delay_hours)),
                    ("p95_delay_hours", Json::Num(m.p95_delay_hours)),
                    ("mean_utilization", Json::Num(m.mean_utilization)),
                ];
                if !r.point.faults.is_empty() && r.point.faults != "none" {
                    fields.push(("restarts", Json::Num(m.restarts as f64)));
                    fields.push(("lost_work_hours", Json::Num(m.lost_work_hours)));
                    fields.push(("recovery_p50_slots", Json::Num(m.recovery_p50_slots)));
                    fields.push(("recovery_p99_slots", Json::Num(m.recovery_p99_slots)));
                    fields.push(("degraded_stale", Json::Num(m.degraded_stale as f64)));
                    fields.push(("degraded_fallback", Json::Num(m.degraded_fallback as f64)));
                }
                if let Some(jpr) = &r.jobs_per_region {
                    fields.push((
                        "jobs_per_region",
                        Json::Arr(jpr.iter().map(|&n| Json::Num(n as f64)).collect()),
                    ));
                }
                if let Some(live) = r.kb_live {
                    fields.push(("kb_live_cases", Json::Num(live as f64)));
                }
                if let Some(ci) = r.mean_ci {
                    fields.push(("mean_ci", Json::Num(ci)));
                }
                Json::obj(fields)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 10;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        cfg
    }

    #[test]
    fn empty_axes_default_to_base() {
        let spec = SweepSpec::new(tiny_base());
        let points = spec.points();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].region, "south-australia");
        assert_eq!(points[0].capacity, 10);
        assert_eq!(points[0].seed, 42);
        assert_eq!(points[0].dispatch, "");
        assert_eq!(points[0].week, None);
        assert_eq!(spec.policies(), PolicyKind::HEADLINE.to_vec());
    }

    #[test]
    fn grid_order_is_region_major_policy_minor() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia".into(), "ontario".into()];
        spec.seeds = vec![1, 2];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let points = spec.points();
        assert_eq!(points.len(), 4);
        assert_eq!(points[0].region, "south-australia");
        assert_eq!(points[0].seed, 1);
        assert_eq!(points[1].seed, 2);
        assert_eq!(points[2].region, "ontario");
        assert_eq!(spec.num_cells(), 8);
    }

    #[test]
    fn seeds_are_verbatim_and_reorder_stable() {
        let mut a = SweepSpec::new(tiny_base());
        a.regions = vec!["south-australia".into(), "ontario".into()];
        a.seeds = vec![1, 2];
        let mut b = SweepSpec::new(tiny_base());
        b.regions = vec!["ontario".into(), "south-australia".into()];
        b.seeds = vec![2, 1];
        // A setting's config does not depend on where it sits in the grid,
        // and the simulated seed is the spec entry itself.
        for p in b.points() {
            let cfg = b.config_for(&p);
            assert_eq!(cfg.seed, p.seed);
            assert_eq!(cfg.region, p.region);
        }
        let a_pts: std::collections::BTreeSet<_> =
            a.points().iter().map(|p| (p.region.clone(), p.seed)).collect();
        let b_pts: std::collections::BTreeSet<_> =
            b.points().iter().map(|p| (p.region.clone(), p.seed)).collect();
        assert_eq!(a_pts, b_pts);
    }

    #[test]
    fn variants_share_the_draw_but_not_the_config() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.variants = vec![
            SweepVariant::new("d6", |cfg| cfg.uniform_delay_hours = Some(6.0)),
            SweepVariant::new("d24", |cfg| cfg.uniform_delay_hours = Some(24.0)),
        ];
        let points = spec.points();
        assert_eq!(points.len(), 2);
        // Common random numbers: single-knob rows compare the same draw.
        assert_eq!(points[0].seed, points[1].seed);
        let cfg = spec.config_for(&points[1]);
        assert_eq!(cfg.uniform_delay_hours, Some(24.0));
        assert_eq!(cfg.seed, points[1].seed);
    }

    #[test]
    #[should_panic(expected = "duplicate sweep variant label")]
    fn duplicate_variant_labels_panic() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.variants =
            vec![SweepVariant::new("x", |_| {}), SweepVariant::new("x", |_| {})];
        let _ = spec.points();
    }

    #[test]
    fn dispatch_axis_multiplies_only_region_sets() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia".into(), "south-australia+ontario".into()];
        spec.dispatchers =
            vec![DispatchStrategy::RoundRobin, DispatchStrategy::LowestWindowCi];
        let points = spec.points();
        // 1 (single region) + 2 (set × dispatchers).
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].dispatch, "");
        assert!(!points[0].is_spatial());
        assert!(points[1].is_spatial());
        assert_eq!(points[1].dispatch, "round-robin");
        assert_eq!(points[2].dispatch, "lowest-window-CI");
        // The spatial config carries the set string and the total capacity.
        let cfg = spec.config_for(&points[1]);
        assert_eq!(cfg.region, "south-australia+ontario");
        assert_eq!(cfg.capacity, 10);
    }

    #[test]
    fn week_axis_pins_weekly_horizons() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.base.history_hours = 168;
        spec.weeks = vec![0, 2];
        let points = spec.points();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].week, Some(0));
        assert_eq!(points[1].week, Some(2));
        for p in &points {
            assert_eq!(p.horizon_hours, 168, "week cells evaluate one week");
            let cfg = spec.config_for(p);
            assert_eq!(cfg.horizon_hours, 168);
            // The learning window stays the base's, unclamped.
            assert_eq!(cfg.history_hours, 168);
        }
    }

    #[test]
    #[should_panic(expected = "cannot combine with multi-region")]
    fn week_axis_rejects_region_sets() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia+ontario".into()];
        spec.weeks = vec![0];
        let _ = spec.points();
    }

    #[test]
    #[should_panic(expected = "pins each cell's horizon")]
    fn week_axis_rejects_horizon_axis() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.horizons = vec![72];
        spec.weeks = vec![0];
        let _ = spec.points();
    }

    #[test]
    #[should_panic(expected = "duplicate week index")]
    fn duplicate_weeks_panic() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.weeks = vec![1, 1];
        let _ = spec.points();
    }

    #[test]
    fn toml_axes_apply_and_validate() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.apply_toml_axes(
            r#"
[sweep]
regions = ["ontario", "south-australia+great-britain"]
dispatch = ["rr", "window"]
capacities = [8, 16]
seeds = [1, 2]
policies = ["agnostic", "carbonflex"]
faults = ["none", "heavy"]
dag_shapes = ["none", "fanout"]
aging_window_hours = 336
"#,
        )
        .unwrap();
        assert_eq!(spec.regions.len(), 2);
        assert_eq!(spec.faults, vec!["none".to_string(), "heavy".to_string()]);
        assert_eq!(spec.dag_shapes, vec!["none".to_string(), "fanout".to_string()]);
        assert_eq!(
            spec.dispatchers,
            vec![DispatchStrategy::RoundRobin, DispatchStrategy::LowestWindowCi]
        );
        assert_eq!(spec.capacities, vec![8, 16]);
        assert_eq!(spec.seeds, vec![1, 2]);
        assert_eq!(spec.policies, vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex]);
        assert_eq!(spec.aging_window_hours, 336);
        // A config without [sweep] leaves the axes untouched.
        spec.apply_toml_axes("[experiment]\nseed = 3\n").unwrap();
        assert_eq!(spec.capacities, vec![8, 16]);
        // Bad entries are rejected with the offending field named.
        let mut bad = SweepSpec::new(tiny_base());
        assert!(bad.apply_toml_axes("[sweep]\nregions = [\"atlantis\"]\n").is_err());
        assert!(bad.apply_toml_axes("[sweep]\ndispatch = [\"teleport\"]\n").is_err());
        assert!(bad.apply_toml_axes("[sweep]\npolicies = [\"magic\"]\n").is_err());
        assert!(bad.apply_toml_axes("[sweep]\nfaults = [\"meteor\"]\n").is_err());
        assert!(bad.apply_toml_axes("[sweep]\ndag_shapes = [\"moebius\"]\n").is_err());
        assert!(bad.apply_toml_axes("[sweep]\naging_window_hours = 0\n").is_err());
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let doubled = par_map(8, &items, |&x, i| {
            assert_eq!(x, i);
            x * 2
        });
        assert_eq!(doubled, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        // Serial path agrees.
        assert_eq!(par_map(1, &items, |&x, _| x * 2), doubled);
        // Empty input is fine.
        assert_eq!(par_map(4, &[] as &[usize], |&x, _| x), Vec::<usize>::new());
    }

    #[test]
    fn runner_emits_grid_order_with_shared_baseline() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia".into(), "ontario".into()];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let rows = SweepRunner::new(4).run(&spec);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].point.region, "south-australia");
        assert_eq!(rows[0].kind, PolicyKind::CarbonAgnostic);
        assert_eq!(rows[1].kind, PolicyKind::WaitAwhile);
        assert_eq!(rows[2].point.region, "ontario");
        for r in &rows {
            assert_eq!(r.result.metrics.unfinished, 0, "{:?}", r.point);
            assert!(r.result.metrics.carbon_g > 0.0);
        }
        // The agnostic rows are their own baselines.
        assert_eq!(rows[0].savings_pct, 0.0);
        assert_eq!(rows[2].savings_pct, 0.0);
    }

    #[test]
    fn memoized_prepare_shares_hash_equal_cells() {
        // Three variants differing only in downstream scheduler knobs: one
        // prepared-input hash group → synthesis + learning run exactly once.
        let mut spec = SweepSpec::new(tiny_base());
        spec.variants = vec![
            SweepVariant::new("k5", |cfg| cfg.knn_k = 5),
            SweepVariant::new("k9", |cfg| cfg.knn_k = 9),
            SweepVariant::new("tol", |cfg| cfg.violation_tolerance = 0.05),
        ];
        spec.policies = vec![PolicyKind::CarbonFlex];
        let (rows, stats) = SweepRunner::new(4).run_with_stats(&spec);
        assert_eq!(rows.len(), 3);
        assert_eq!(stats, PrepStats { prepares: 1, learns: 1 }, "hash group not shared");
        // Output preservation: every memoized row is bitwise what a fresh,
        // unshared preparation of its cell config produces.
        for (r, p) in rows.iter().zip(spec.points()) {
            let cfg = spec.config_for(&p);
            let fresh = PreparedExperiment::prepare(&cfg).run(r.kind);
            assert_eq!(
                r.result.fingerprint(),
                fresh.fingerprint(),
                "memoized cell '{}' diverged from fresh prepare",
                p.variant
            );
        }
        // A knob that feeds preparation must NOT share: seeds split groups.
        let mut split = SweepSpec::new(tiny_base());
        split.seeds = vec![1, 2];
        split.policies = vec![PolicyKind::CarbonFlex];
        let (_, stats) = SweepRunner::new(4).run_with_stats(&split);
        assert_eq!(stats.prepares, 2, "distinct seeds must prepare separately");
    }

    #[test]
    fn sharded_rows_concatenate_to_the_unsharded_grid() {
        let mk = |shard: Option<(usize, usize)>| {
            let mut spec = SweepSpec::new(tiny_base());
            spec.regions = vec!["south-australia".into(), "ontario".into()];
            spec.seeds = vec![1, 2];
            spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
            spec.shard = shard;
            spec
        };
        let full = SweepRunner::new(2).run(&mk(None));
        // n=3 over 4 points exercises uneven slices (1/1/2).
        let mut concat: Vec<SweepRow> = Vec::new();
        for i in 0..3 {
            concat.extend(SweepRunner::new(2).run(&mk(Some((i, 3)))));
        }
        assert_eq!(full.len(), concat.len());
        for (a, b) in full.iter().zip(&concat) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.kind, b.kind);
            assert_eq!(
                a.result.fingerprint(),
                b.result.fingerprint(),
                "shard diverged at {:?}/{:?}",
                a.point,
                a.kind
            );
            assert_eq!(a.savings_pct.to_bits(), b.savings_pct.to_bits());
        }
        // More shards than points: some slices are empty, nothing panics
        // (4 points over 6 shards: slice 3 spans [2, 2)).
        assert!(SweepRunner::new(1).run(&mk(Some((3, 6)))).is_empty());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shard_index_must_be_below_denominator() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.shard = Some((2, 2));
        let _ = SweepRunner::new(1).run(&spec);
    }

    #[test]
    fn runner_executes_spatial_cells() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.base.capacity = 16; // 8 per region
        spec.regions = vec!["south-australia+ontario".into()];
        spec.dispatchers = vec![DispatchStrategy::LowestWindowCi];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let rows = SweepRunner::new(2).run(&spec);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.point.dispatch, "lowest-window-CI");
            assert_eq!(r.result.metrics.unfinished, 0, "{:?}", r.point);
            assert!(r.result.metrics.carbon_g > 0.0);
            let jpr = r.jobs_per_region.as_ref().expect("spatial rows carry routing");
            assert_eq!(jpr.len(), 2);
            assert_eq!(jpr.iter().sum::<usize>(), r.result.metrics.completed);
        }
        // The agnostic row is its own baseline; routing is
        // policy-independent, so both rows saw the same stream split.
        assert_eq!(rows[0].savings_pct, 0.0);
        assert_eq!(rows[0].jobs_per_region, rows[1].jobs_per_region);
    }

    #[test]
    fn faults_axis_injects_and_preserves_clean_rows() {
        let mk = |faults: Vec<String>| {
            let mut spec = SweepSpec::new(tiny_base());
            spec.faults = faults;
            spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
            spec
        };
        let spec = mk(vec!["none".into(), "light".into()]);
        let points = spec.points();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].faults, "none");
        assert!(spec.plan_for(&points[0]).is_empty());
        assert!(!spec.plan_for(&points[1]).is_empty());

        // Faulted and clean points at one setting share one preparation.
        let (rows, stats) = SweepRunner::new(2).run_with_stats(&spec);
        assert_eq!(rows.len(), 4);
        assert_eq!(stats.prepares, 1, "faults axis must not split prep groups");

        // "none" rows are bitwise identical to a sweep without the axis.
        let clean = SweepRunner::new(2).run(&mk(Vec::new()));
        for (a, b) in rows[..2].iter().zip(&clean) {
            assert_eq!(a.result.fingerprint(), b.result.fingerprint());
            assert_eq!(a.savings_pct.to_bits(), b.savings_pct.to_bits());
        }

        // The light preset's outage actually walks the degradation ladder,
        // and a rerun reproduces every faulted row bitwise.
        let flex = &rows[3].result.metrics;
        assert!(flex.degraded_stale + flex.degraded_fallback > 0, "outage never degraded");
        let again = SweepRunner::new(1).run(&spec);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.result.fingerprint(), b.result.fingerprint());
        }
    }

    #[test]
    #[should_panic(expected = "unknown fault preset")]
    fn unknown_fault_preset_panics() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.faults = vec!["apocalypse".into()];
        let _ = spec.points();
    }

    #[test]
    #[should_panic(expected = "cannot combine with multi-region")]
    fn faults_axis_rejects_region_sets() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia+ontario".into()];
        spec.faults = vec!["light".into()];
        let _ = spec.points();
    }

    #[test]
    fn dag_axis_injects_and_preserves_clean_rows() {
        let mk = |shapes: Vec<String>| {
            let mut spec = SweepSpec::new(tiny_base());
            spec.dag_shapes = shapes;
            spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
            spec
        };
        let spec = mk(vec!["none".into(), "chains".into()]);
        let points = spec.points();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].dag_shape, "none");
        assert_eq!(spec.config_for(&points[0]).dag_shape, DagShape::None);
        assert_eq!(spec.config_for(&points[1]).dag_shape, DagShape::Chains);

        // Unlike the faults axis, the shape feeds trace generation: shaped
        // and flat points at one setting must prepare separately.
        let (rows, stats) = SweepRunner::new(2).run_with_stats(&spec);
        assert_eq!(rows.len(), 4);
        assert_eq!(stats.prepares, 2, "dag axis must split prep groups");

        // "none" rows are bitwise identical to a sweep without the axis.
        let flat = SweepRunner::new(2).run(&mk(Vec::new()));
        for (a, b) in rows[..2].iter().zip(&flat) {
            assert_eq!(a.result.fingerprint(), b.result.fingerprint());
            assert_eq!(a.savings_pct.to_bits(), b.savings_pct.to_bits());
        }

        // Shaped rows still make progress, and a rerun reproduces every
        // row bitwise regardless of thread count.
        assert!(rows[2].result.metrics.completed > 0, "chained cell completed nothing");
        let again = SweepRunner::new(1).run(&spec);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.result.fingerprint(), b.result.fingerprint());
        }
    }

    #[test]
    #[should_panic(expected = "unknown dag shape")]
    fn unknown_dag_shape_panics() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.dag_shapes = vec!["moebius".into()];
        let _ = spec.points();
    }

    #[test]
    #[should_panic(expected = "cannot combine with multi-region")]
    fn dag_axis_rejects_region_sets() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.regions = vec!["south-australia+ontario".into()];
        spec.dag_shapes = vec!["chains".into()];
        let _ = spec.points();
    }

    #[test]
    fn runner_executes_week_cells() {
        let mut spec = SweepSpec::new(tiny_base());
        spec.base.capacity = 12;
        spec.base.history_hours = 168;
        spec.weeks = vec![0, 1];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::WaitAwhile];
        let rows = SweepRunner::new(4).run(&spec);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].point.week, Some(0));
        assert_eq!(rows[2].point.week, Some(1));
        for r in &rows {
            assert_eq!(r.point.horizon_hours, 168);
            assert_eq!(r.result.metrics.unfinished, 0, "{:?}", r.point);
            // No requested policy reads the KB, so the chain skips its
            // learning passes and reports an empty knowledge base.
            assert_eq!(r.kb_live, Some(0));
            assert!(r.mean_ci.unwrap() > 0.0);
        }
        assert_eq!(rows[0].savings_pct, 0.0);
    }
}
