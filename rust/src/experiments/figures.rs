//! Per-figure experiment drivers — one function per table/figure of the
//! paper's evaluation (§6). Each prints the same rows/series the paper
//! reports; `benches/` wraps these with timing, the CLI exposes them via
//! `carbonflex experiment <id>`.
//!
//! Every gridded figure is expressed as a [`SweepSpec`] and executed on the
//! parallel [`SweepRunner`] (one worker per core), so regenerating a figure
//! costs one prepared experiment per grid point instead of one per cell.

use crate::carbon::synth::{self, Region};
use crate::config::{ElasticityScenario, ExperimentConfig, Hardware, TraceFamily};
use crate::experiments::runner::{ExperimentRow, PreparedExperiment};
use crate::experiments::sweep::{SweepRow, SweepRunner, SweepSpec, SweepVariant};
use crate::sched::PolicyKind;
use crate::util::bench::Table;

/// Default config matching the paper's primary setting (§6.1): CPU cluster,
/// M = 150, South Australia, ~50% utilization, one-week evaluation after a
/// two-week learning window.
pub fn paper_default() -> ExperimentConfig {
    ExperimentConfig::default()
}

/// GPU variant (§6.1: 15 G6 GPUs, sampling limited to similar utilization).
pub fn paper_gpu() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.hardware = Hardware::Gpu;
    cfg.capacity = 15;
    cfg.trace = TraceFamily::AlibabaLike;
    cfg
}

/// Dispatch by figure id; returns a process exit code.
pub fn run_by_name(which: &str, config_path: Option<&str>) -> i32 {
    let base = match config_path {
        Some(p) => match ExperimentConfig::load(p) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        },
        None => paper_default(),
    };
    match which {
        "fig2" | "tab3" => fig2_profiles(),
        "fig5" => fig5_traces(base.seed),
        "fig6" => fig6_cpu(&base),
        "fig7" => fig7_gpu(),
        "fig8" => fig8_capacity(&base),
        "fig9" => fig9_delay(&base),
        "fig10" => fig10_elasticity(&base),
        "fig11" => fig11_traces(&base),
        "fig12" => fig12_locations(&base),
        "fig13" => fig13_shift(&base),
        "fig14" => fig14_vcc(&base),
        "overheads" => overheads(&base),
        "yearlong" => yearlong_summary(&base),
        "noise" => crate::experiments::forecast_noise::print_noise_sweep(&base),
        "spatial" => crate::experiments::spatial::print_spatial(&base),
        other => {
            eprintln!("unknown experiment '{other}'");
            return 1;
        }
    }
    0
}

fn print_rows(title: &str, rows: &[ExperimentRow]) {
    println!("\n== {title} ==");
    let mut t = Table::new(&[
        "policy",
        "carbon (kg)",
        "savings %",
        "mean delay (h)",
        "p95 delay (h)",
        "violations",
        "rescales",
    ]);
    for row in rows {
        let m = &row.result.metrics;
        t.row(&[
            m.policy.clone(),
            format!("{:.2}", m.carbon_kg()),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", m.mean_delay_hours),
            format!("{:.2}", m.p95_delay_hours),
            format!("{}", m.violations),
            format!("{}", m.total_rescales),
        ]);
    }
    t.print();
}

/// Reshape sweep rows into the paper-table row type.
fn as_experiment_rows(rows: Vec<SweepRow>) -> Vec<ExperimentRow> {
    rows.into_iter()
        .map(|r| ExperimentRow { kind: r.kind, result: r.result, savings_pct: r.savings_pct })
        .collect()
}

/// Fig. 2 / Table 3: the elastic scaling profile catalog.
pub fn fig2_profiles() {
    println!("\n== Fig. 2 / Table 3: elastic scaling profiles (normalized throughput S(k)) ==");
    let mut t = Table::new(&["workload", "impl", "comm MB", "class", "S(2)", "S(4)", "S(8)"]);
    for w in crate::workload::profile::catalog() {
        let p = w.profile(8);
        t.row(&[
            w.name.to_string(),
            w.hardware.as_str().to_string(),
            format!("{:.2}", w.comm_mb),
            w.scalability.as_str().to_string(),
            format!("{:.2}", p.throughput(2)),
            format!("{:.2}", p.throughput(4)),
            format!("{:.2}", p.throughput(8)),
        ]);
    }
    t.print();
}

/// Fig. 5: mean CI and daily CoV for the ten regions.
pub fn fig5_traces(seed: u64) {
    println!("\n== Fig. 5: carbon-intensity trace diversity (synthesized year) ==");
    let mut t = Table::new(&["region", "mean CI (g/kWh)", "daily CoV"]);
    for region in Region::ALL {
        let trace = synth::synthesize_year(region, seed);
        t.row(&[
            region.key().to_string(),
            format!("{:.0}", trace.mean()),
            format!("{:.3}", trace.daily_cov()),
        ]);
    }
    t.print();
}

/// Fig. 6: CPU-cluster emissions + delay across the six headline policies.
pub fn fig6_cpu(base: &ExperimentConfig) {
    let mut spec = SweepSpec::new(base.clone());
    spec.policies = PolicyKind::HEADLINE.to_vec();
    let rows = SweepRunner::auto().run(&spec);
    print_rows("Fig. 6: CPU cluster (M=150, South Australia)", &as_experiment_rows(rows));
}

/// Fig. 7: GPU-cluster emissions (heterogeneous per-workload power).
pub fn fig7_gpu() {
    let mut spec = SweepSpec::new(paper_gpu());
    spec.policies = PolicyKind::HEADLINE.to_vec();
    let rows = SweepRunner::auto().run(&spec);
    print_rows("Fig. 7: GPU cluster (M=15, heterogeneous power)", &as_experiment_rows(rows));
}

/// Fig. 8: capacity sweep M ∈ {100, 150, 200} (≈75%/50%/37% utilization).
pub fn fig8_capacity(base: &ExperimentConfig) {
    println!("\n== Fig. 8: effect of maximum cluster capacity ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.capacities = vec![100, 150, 200];
    // Same workload (calibrated against the default M=150) — utilization
    // varies with M exactly as in the paper.
    spec.variants = vec![SweepVariant::new("calibrated-load", |cfg| {
        cfg.target_utilization = 0.5 * 150.0 / cfg.capacity as f64;
    })];
    spec.policies = vec![
        PolicyKind::Oracle,
        PolicyKind::CarbonFlex,
        PolicyKind::CarbonScaler,
        PolicyKind::WaitAwhile,
    ];
    let rows = SweepRunner::auto().run(&spec);
    let mut t = Table::new(&["M", "policy", "savings %", "mean delay (h)"]);
    for row in &rows {
        t.row(&[
            format!("{}", row.point.capacity),
            row.result.metrics.policy.clone(),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", row.result.metrics.mean_delay_hours),
        ]);
    }
    t.print();
}

/// Fig. 9: delay sweep d ∈ {0, 6, 12, 24, 36} hours (uniform across queues).
pub fn fig9_delay(base: &ExperimentConfig) {
    println!("\n== Fig. 9: effect of allowed delay (slack) ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.variants = [0.0f64, 6.0, 12.0, 24.0, 36.0]
        .iter()
        .map(|&d| {
            SweepVariant::new(format!("{d:.0}"), move |cfg| cfg.uniform_delay_hours = Some(d))
        })
        .collect();
    spec.policies = vec![
        PolicyKind::Oracle,
        PolicyKind::CarbonFlex,
        PolicyKind::CarbonScaler,
        PolicyKind::WaitAwhile,
        PolicyKind::Gaia,
    ];
    let rows = SweepRunner::auto().run(&spec);
    let mut t = Table::new(&["delay (h)", "policy", "savings %", "mean wait (h)"]);
    for row in &rows {
        t.row(&[
            row.point.variant.clone(),
            row.result.metrics.policy.clone(),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", row.result.metrics.mean_delay_hours),
        ]);
    }
    t.print();
}

/// Fig. 10: elasticity scenarios High/Moderate/Low/Mix/NoScaling.
pub fn fig10_elasticity(base: &ExperimentConfig) {
    println!("\n== Fig. 10: workload elasticity impact ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.variants = [
        ElasticityScenario::High,
        ElasticityScenario::Moderate,
        ElasticityScenario::Low,
        ElasticityScenario::Mix,
        ElasticityScenario::NoScaling,
    ]
    .iter()
    .map(|&scen| SweepVariant::new(scen.as_str(), move |cfg| cfg.elasticity = scen))
    .collect();
    spec.policies = vec![
        PolicyKind::Oracle,
        PolicyKind::CarbonFlex,
        PolicyKind::CarbonScaler,
        PolicyKind::WaitAwhile,
    ];
    let rows = SweepRunner::auto().run(&spec);
    let mut t = Table::new(&["elasticity", "policy", "savings %"]);
    for row in &rows {
        t.row(&[
            row.point.variant.clone(),
            row.result.metrics.policy.clone(),
            format!("{:.1}", row.savings_pct),
        ]);
    }
    t.print();
}

/// Fig. 11: workload trace families (Azure/Alibaba/SURF-like).
pub fn fig11_traces(base: &ExperimentConfig) {
    println!("\n== Fig. 11: carbon savings across workload traces ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.variants = [TraceFamily::AzureLike, TraceFamily::AlibabaLike, TraceFamily::SurfLike]
        .iter()
        .map(|&family| SweepVariant::new(family.as_str(), move |cfg| cfg.trace = family))
        .collect();
    spec.policies = vec![
        PolicyKind::Oracle,
        PolicyKind::CarbonFlex,
        PolicyKind::CarbonScaler,
        PolicyKind::WaitAwhile,
        PolicyKind::Gaia,
    ];
    let rows = SweepRunner::auto().run(&spec);
    let mut t = Table::new(&["trace", "policy", "savings %", "mean delay (h)"]);
    for row in &rows {
        t.row(&[
            row.point.variant.clone(),
            row.result.metrics.policy.clone(),
            format!("{:.1}", row.savings_pct),
            format!("{:.2}", row.result.metrics.mean_delay_hours),
        ]);
    }
    t.print();
}

/// Fig. 12: savings across the ten regions.
pub fn fig12_locations(base: &ExperimentConfig) {
    println!("\n== Fig. 12: carbon savings across locations ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.regions = Region::ALL.iter().map(|r| r.key().to_string()).collect();
    spec.policies = vec![PolicyKind::Oracle, PolicyKind::CarbonFlex, PolicyKind::CarbonScaler];
    let rows = SweepRunner::auto().run(&spec);
    // CoV of the same synthesized year each region was simulated on,
    // computed once per region (not once per policy row).
    let covs: std::collections::BTreeMap<String, f64> = spec
        .points()
        .iter()
        .map(|p| {
            let region = Region::parse(&p.region).expect("sweep region");
            (p.region.clone(), synth::synthesize_year(region, p.seed).daily_cov())
        })
        .collect();
    let mut t = Table::new(&["region", "daily CoV", "policy", "savings %"]);
    for row in &rows {
        t.row(&[
            row.point.region.clone(),
            format!("{:.3}", covs[&row.point.region]),
            row.result.metrics.policy.clone(),
            format!("{:.1}", row.savings_pct),
        ]);
    }
    t.print();
}

/// Fig. 13: distribution shift — arrival-rate/length scaling ±20%.
pub fn fig13_shift(base: &ExperimentConfig) {
    println!("\n== Fig. 13: impact of distribution shifts (CarbonFlex) ==");
    let mut spec = SweepSpec::new(base.clone());
    spec.variants = [-0.2f64, -0.1, 0.0, 0.1, 0.2]
        .iter()
        .map(|&shift| {
            // `prepare` applies the scales to the evaluation window only
            // (the KB learns on the unshifted history), so this measures
            // the paper's genuine learn/eval mismatch.
            SweepVariant::new(format!("{:+.0}", shift * 100.0), move |cfg| {
                cfg.arrival_scale = 1.0 + shift;
                cfg.length_scale = 1.0 + shift;
            })
        })
        .collect();
    spec.policies = vec![PolicyKind::CarbonFlex];
    let rows = SweepRunner::auto().run(&spec);
    let mut t = Table::new(&["shift %", "utilization %", "savings %"]);
    for row in &rows {
        t.row(&[
            row.point.variant.clone(),
            format!("{:.0}", row.result.metrics.mean_utilization * 100.0),
            format!("{:.1}", row.savings_pct),
        ]);
    }
    t.print();
}

/// Fig. 14: carbon-aware provisioning interop (VCC vs VCC(Scaling) vs
/// CarbonFlex, uniform 24 h delay).
pub fn fig14_vcc(base: &ExperimentConfig) {
    let mut cfg = base.clone();
    cfg.uniform_delay_hours = Some(24.0);
    let mut spec = SweepSpec::new(cfg);
    spec.policies =
        vec![PolicyKind::Vcc, PolicyKind::VccScaling, PolicyKind::CarbonFlex, PolicyKind::Oracle];
    let rows = SweepRunner::auto().run(&spec);
    print_rows(
        "Fig. 14: carbon-aware capacity provisioning (d = 24 h)",
        &as_experiment_rows(rows),
    );
}

/// Extension: continuous learning over consecutive weeks (paper §5's
/// year-long CarbonFlex-Simulator mode, with KB aging).
pub fn yearlong_summary(base: &ExperimentConfig) {
    let r = crate::experiments::yearlong::run_yearlong(base, 8, 24 * 28);
    println!("\n== Continuous learning over {} weeks ==", r.weeks.len());
    let mut t = Table::new(&["week", "mean CI", "CarbonFlex %", "Oracle %", "KB cases"]);
    for w in &r.weeks {
        t.row(&[
            format!("{}", w.week),
            format!("{:.0}", w.mean_ci),
            format!("{:.1}", w.savings_pct),
            format!("{:.1}", w.oracle_savings_pct),
            format!("{}", w.kb_cases),
        ]);
    }
    t.print();
    println!("mean {:.1}% (oracle {:.1}%)", r.mean_savings(), r.mean_oracle_savings());
}

/// §6.8: system overheads.
pub fn overheads(base: &ExperimentConfig) {
    use std::time::Instant;
    println!("\n== §6.8: system overheads ==");
    let prep = PreparedExperiment::prepare(base);

    // Oracle runtime over a week-long trace (paper: 2–10 min in Python).
    let t0 = Instant::now();
    let _ = crate::sched::oracle::compute_schedule(
        &prep.eval_jobs,
        &prep.eval_trace,
        base.capacity,
        24.0,
        8,
    );
    let oracle_time = t0.elapsed();

    // Learning phase (oracle replay over the two-week history, all offsets).
    let t1 = Instant::now();
    let kb_len = prep.knowledge_base().cases().len();
    let learn_time = t1.elapsed();

    // State-match latency (paper: 1–2 ms with scikit-learn).
    let kb = prep.knowledge_base().clone();
    let query = crate::learning::state::StateVector::from_raw(250.0, -10.0, 0.3, &[5, 3, 1], 0.7);
    let t2 = Instant::now();
    let iters = 1000;
    for _ in 0..iters {
        let hits = crate::learning::kb::Matcher::top_k(&kb, &query, 5);
        std::hint::black_box(hits);
    }
    let match_time = t2.elapsed() / iters;

    let energy = crate::cluster::energy::EnergyModel::for_hardware(base.hardware);
    let mut t = Table::new(&["overhead", "paper", "this repo"]);
    t.row(&[
        "oracle (week trace)".into(),
        "2–10 min".into(),
        format!("{:.2?} ({} jobs)", oracle_time, prep.eval_jobs.len()),
    ]);
    t.row(&[
        "learning phase (2-week history)".into(),
        "n/a".into(),
        format!("{:.2?} ({} cases)", learn_time, kb_len),
    ]);
    t.row(&["state match".into(), "1–2 ms".into(), format!("{:.2?}", match_time)]);
    t.row(&[
        "checkpoint+restore".into(),
        "2.3 s (ViT-B/32)".into(),
        format!("{:.1} s (modeled)", energy.ckpt_hours * 3600.0),
    ]);
    t.row(&[
        "instance boot".into(),
        "3 min CPU / 5 min GPU".into(),
        format!("{:.1} Wh/server boot energy", energy.boot_wh_per_server),
    ]);
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small config so figure smoke tests stay fast.
    fn tiny() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 12;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        cfg
    }

    #[test]
    fn dispatch_unknown_fails() {
        assert_eq!(run_by_name("fig99", None), 1);
    }

    #[test]
    fn fig5_and_fig2_print() {
        fig5_traces(1);
        fig2_profiles();
    }

    #[test]
    fn fig13_runs_on_tiny_config() {
        fig13_shift(&tiny());
    }
}
