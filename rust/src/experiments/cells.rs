//! Composite sweep-cell executors: the machinery behind the sweep engine's
//! spatial (multi-region) and week-window (continuous-learning) grid axes.
//!
//! The sweep engine treats every grid cell as "prepared state + one
//! simulation". For plain cells that is [`PreparedExperiment`]; this module
//! supplies the two composite flavors:
//!
//! - **Spatial cells** ([`SpatialPrep`] + [`run_spatial_cell`]): one cluster
//!   per region of a `+`-joined region set, a geo-dispatcher routing each
//!   arrival by [`DispatchStrategy`], per-region carbon traces and (for
//!   CarbonFlex) per-region knowledge bases. The per-slot dispatch loop
//!   that used to live in `experiments/spatial.rs::run_spatial_prepared`
//!   now lives here, invoked once per sweep cell.
//! - **Week-window cells** ([`WeekCell`] + [`prepare_week_chain`]): the
//!   paper's year-long continuous-learning mode (§5). Weeks at the same
//!   grid point form a sequential chain — each week learns on the trailing
//!   history window, pushes into a carried knowledge base, and slides the
//!   rolling window with [`KnowledgeBase::advance_window`] — and every
//!   *requested* week gets an immutable [`PreparedExperiment`] snapshot, so
//!   the policy runs of different weeks still execute in parallel.
//!
//! Both executors are bitwise-faithful ports of the bespoke loops they
//! replace; `experiments/spatial.rs` and `experiments/yearlong.rs` keep the
//! legacy implementations alive in-test as references.

use std::sync::Arc;

use crate::carbon::forecast::Forecaster;
use crate::carbon::synth::{self, Region};
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::{ClusterEngine, SimResult, Simulator};
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::experiments::sweep::{auto_threads, par_map};
use crate::learning::kb::{Case, KnowledgeBase};
use crate::learning::replay::{learn, LearnConfig};
use crate::sched::{Policy, PolicyKind};
use crate::workload::job::Job;
use crate::workload::tracegen;

/// How the geo-dispatcher picks a region for an arriving job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchStrategy {
    /// Round-robin — the carbon-agnostic baseline for spatial decisions.
    RoundRobin,
    /// Route to the region with the lowest *current* carbon intensity.
    LowestCurrentCi,
    /// Route to the region whose forecast is cleanest over the job's
    /// expected window (arrival → deadline), weighted by base length.
    LowestWindowCi,
}

impl DispatchStrategy {
    /// Every strategy, in the axis' canonical order.
    pub const ALL: [DispatchStrategy; 3] = [
        DispatchStrategy::RoundRobin,
        DispatchStrategy::LowestCurrentCi,
        DispatchStrategy::LowestWindowCi,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            DispatchStrategy::RoundRobin => "round-robin",
            DispatchStrategy::LowestCurrentCi => "lowest-current-CI",
            DispatchStrategy::LowestWindowCi => "lowest-window-CI",
        }
    }

    /// Parse a strategy key (the `as_str` labels plus short CLI aliases).
    pub fn parse(s: &str) -> Option<DispatchStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(DispatchStrategy::RoundRobin),
            "lowest-current-ci" | "current" => Some(DispatchStrategy::LowestCurrentCi),
            "lowest-window-ci" | "window" => Some(DispatchStrategy::LowestWindowCi),
            _ => None,
        }
    }
}

/// Deterministic geo-dispatch: pick the destination shard for one arrival at
/// slot `t`. `rr` is the round-robin cursor (pre-incremented, matching the
/// historical spatial-cell semantics pinned by the golden fingerprints);
/// `window_hours` is the job's expected occupancy window (length + slack,
/// ceiled) and is only read by [`DispatchStrategy::LowestWindowCi`]. Shared
/// by the spatial sweep cells and the sharded serving coordinator so both
/// route identically.
pub fn route_arrival<T>(
    strategy: DispatchStrategy,
    rr: &mut usize,
    shards: &[T],
    forecaster_of: impl Fn(&T) -> &Forecaster,
    t: usize,
    window_hours: usize,
) -> usize {
    match strategy {
        DispatchStrategy::RoundRobin => {
            *rr = (*rr + 1) % shards.len();
            *rr
        }
        DispatchStrategy::LowestCurrentCi => shards
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                forecaster_of(a)
                    .predict(t)
                    .partial_cmp(&forecaster_of(b).predict(t))
                    .unwrap()
            })
            .map(|(i, _)| i)
            .unwrap(),
        DispatchStrategy::LowestWindowCi => shards
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let ma = mean_of(&forecaster_of(a).predict_window(t, window_hours));
                let mb = mean_of(&forecaster_of(b).predict_window(t, window_hours));
                ma.partial_cmp(&mb).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap(),
    }
}

/// Split a `+`-joined region-set key ("south-australia+ontario") into
/// regions; panics on unknown keys (axis entries are validated up front by
/// the CLI, so a bad key here is a programming error).
pub fn parse_region_set(set: &str) -> Vec<Region> {
    set.split('+')
        .map(|key| {
            Region::parse(key.trim())
                .unwrap_or_else(|| panic!("unknown region '{key}' in set '{set}'"))
        })
        .collect()
}

/// Prepared state shared by every local-policy cell of one (spatial grid
/// point, dispatch strategy) pair: one [`PreparedExperiment`] per region,
/// each with `cfg.capacity / regions.len()` servers, its own carbon trace
/// and — for CarbonFlex — its own locally learned knowledge base.
pub struct SpatialPrep {
    pub regions: Vec<Region>,
    pub preps: Vec<Arc<PreparedExperiment>>,
}

/// Prepare one regional experiment per region with the region's **full**
/// historical stream — each region learns as if the whole (per-region-scaled)
/// load landed on it, regardless of how the dispatcher would actually split
/// arrivals. This is the pre-skew behaviour, kept as the building block for
/// [`prepare_spatial`] and as the strategy-independent preparation behind
/// the `run_spatial_prepared` injection path; regions prepare in parallel.
pub fn prepare_spatial_unskewed(cfg: &ExperimentConfig, regions: &[Region]) -> SpatialPrep {
    assert!(!regions.is_empty());
    let per_region_capacity = (cfg.capacity / regions.len()).max(1);
    let preps = par_map(auto_threads(), regions, |&region, _| {
        let mut rcfg = cfg.clone();
        rcfg.region = region.key().to_string();
        rcfg.capacity = per_region_capacity;
        Arc::new(PreparedExperiment::prepare(&rcfg))
    });
    SpatialPrep { regions: regions.to_vec(), preps }
}

/// Prepare one regional experiment per region, learning each region's
/// knowledge base from the **dispatch-skewed** historical split: one global
/// history stream at deployment scale (the hist analogue of the eval loop's
/// shared arrival stream) is routed job-by-job with the same
/// [`route_arrival`] the evaluation dispatcher uses — against each region's
/// *historical* forecast — and every region keeps only its routed subset as
/// `hist_jobs`. A clean region that the dispatcher favours therefore trains
/// on the heavier stream it will actually serve, instead of the uniform
/// full-stream history that confounded CarbonFlex under carbon-aware
/// dispatch (the PR-5 train/serve mismatch). Preparation now depends on the
/// strategy, so the sweep engine keys spatial prep units by (point,
/// dispatch).
pub fn prepare_spatial(
    cfg: &ExperimentConfig,
    regions: &[Region],
    strategy: DispatchStrategy,
) -> SpatialPrep {
    let base = prepare_spatial_unskewed(cfg, regions);

    // The global historical stream: same generator + seed lineage as
    // `PreparedExperiment::prepare` (unshifted history, `seed ^ 0x1157`) but
    // at the *aggregate* capacity, mirroring how `run_spatial_cell` sizes
    // the shared evaluation stream for the whole deployment.
    let hist_jobs =
        tracegen::generate(&cfg.unshifted_history(), cfg.history_hours, cfg.seed ^ 0x1157);
    let forecasters: Vec<Forecaster> =
        base.preps.iter().map(|p| Forecaster::perfect(p.hist_trace.clone())).collect();

    // Route by arrival order with the evaluation dispatcher's exact
    // semantics (pre-incremented round-robin cursor, window = length +
    // slack); re-id densely per region so replay learning sees a normal
    // dense stream.
    let mut by_arrival: Vec<&Job> = hist_jobs.iter().collect();
    by_arrival.sort_by_key(|j| j.arrival);
    let mut routed: Vec<Vec<Job>> = vec![Vec::new(); regions.len()];
    let mut rr = 0usize;
    for job in by_arrival {
        let window = (job.length_hours + job.slack_hours).ceil() as usize;
        let r = route_arrival(strategy, &mut rr, &forecasters, |f| f, job.arrival, window);
        let local = Job { id: routed[r].len(), ..job.clone() };
        routed[r].push(local);
    }

    let preps = base
        .preps
        .iter()
        .zip(routed)
        .map(|(p, region_hist)| {
            Arc::new(PreparedExperiment::from_parts(
                p.cfg.clone(),
                p.hist_trace.clone(),
                p.eval_trace.clone(),
                region_hist,
                p.eval_jobs.clone(),
                None,
            ))
        })
        .collect();
    SpatialPrep { regions: regions.to_vec(), preps }
}

/// One regional cluster: engine + forecaster + local policy.
struct RegionalCluster {
    engine: ClusterEngine,
    forecaster: Forecaster,
    policy: Box<dyn Policy>,
    next_id: usize,
}

/// Execute one spatial sweep cell: dispatch one shared arrival stream
/// across the prepared regional clusters, step them in lockstep, and
/// aggregate. Returns the combined [`SimResult`] (region-major slot/outcome
/// concatenation; metric sums in region order, matching the legacy
/// `run_spatial_prepared` fold expressions bit for bit) plus the number of
/// jobs routed to each region.
pub fn run_spatial_cell(
    cfg: &ExperimentConfig,
    sp: &SpatialPrep,
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> (SimResult, Vec<usize>) {
    assert!(!sp.preps.is_empty());
    let horizon = cfg.horizon_hours;
    let energy = EnergyModel::for_hardware(cfg.hardware);

    // Build the regional clusters over the shared prepared state.
    let mut clusters: Vec<RegionalCluster> = sp
        .preps
        .iter()
        .map(|prep| {
            let policy: Box<dyn Policy> = prep.build_policy(local_policy);
            let sim =
                Simulator::new(prep.cfg.capacity, energy.clone(), cfg.queues.len(), horizon);
            RegionalCluster {
                engine: ClusterEngine::new(sim),
                forecaster: Forecaster::perfect(prep.eval_trace.clone()),
                policy,
                next_id: 0,
            }
        })
        .collect();

    // One global arrival stream sized for the aggregate capacity.
    let jobs = tracegen::generate(cfg, horizon, cfg.seed ^ 0x5EA7);
    let mut jobs_per_region = vec![0usize; sp.preps.len()];
    let mut rr = 0usize;

    // Dispatch + step in lockstep.
    let mut by_arrival: Vec<&Job> = jobs.iter().collect();
    by_arrival.sort_by_key(|j| j.arrival);
    let mut next_job = 0usize;
    let last_arrival = by_arrival.last().map(|j| j.arrival).unwrap_or(0);
    let t_end = last_arrival + horizon + 4096;

    for t in 0..t_end {
        // Route this slot's arrivals.
        while next_job < by_arrival.len() && by_arrival[next_job].arrival == t {
            let job = by_arrival[next_job];
            let window = (job.length_hours + job.slack_hours).ceil() as usize;
            let r = route_arrival(strategy, &mut rr, &clusters, |c| &c.forecaster, t, window);
            let c = &mut clusters[r];
            // Re-id within the destination cluster (engines need dense ids).
            let local = Job { id: c.next_id, arrival: t, ..job.clone() };
            c.next_id += 1;
            c.engine.add_job(local);
            jobs_per_region[r] += 1;
            next_job += 1;
        }
        // Advance every region one slot.
        let mut any_pending = next_job < by_arrival.len();
        for c in clusters.iter_mut() {
            if c.engine.pending_jobs() > 0 {
                c.engine.step(t, &c.forecaster, c.policy.as_mut());
                any_pending = true;
            }
        }
        if !any_pending {
            break;
        }
    }

    let per_region: Vec<SimResult> =
        clusters.into_iter().map(|c| c.engine.finish("regional")).collect();
    let result = aggregate_regional(per_region, sp, local_policy.as_str());
    (result, jobs_per_region)
}

/// Fold per-region results into one cell result, in region order. The
/// metric sums use the exact fold expressions of the legacy
/// `run_spatial_prepared` aggregation (carbon/completed/unfinished sums,
/// completed-weighted mean delay), so the values are bitwise identical;
/// p95 delay takes the per-region maximum and `peak_allocated` the sum of
/// per-region peaks (coarse cluster-of-clusters aggregates). Slot records
/// and job outcomes concatenate region-major so the cell fingerprint pins
/// every region's full trajectory.
fn aggregate_regional(per_region: Vec<SimResult>, sp: &SpatialPrep, policy: &str) -> SimResult {
    let metrics: Vec<&crate::cluster::metrics::RunMetrics> =
        per_region.iter().map(|r| &r.metrics).collect();
    let completed: usize = metrics.iter().map(|m| m.completed).sum();
    let delay_weighted: f64 =
        metrics.iter().map(|m| m.mean_delay_hours * m.completed as f64).sum();
    let total_capacity: f64 = sp.preps.iter().map(|p| p.cfg.capacity as f64).sum();
    let util_weighted: f64 = metrics
        .iter()
        .zip(&sp.preps)
        .map(|(m, p)| m.mean_utilization * p.cfg.capacity as f64)
        .sum();
    let agg = crate::cluster::metrics::RunMetrics {
        policy: policy.to_string(),
        carbon_g: metrics.iter().map(|m| m.carbon_g).sum(),
        energy_kwh: metrics.iter().map(|m| m.energy_kwh).sum(),
        completed,
        unfinished: metrics.iter().map(|m| m.unfinished).sum(),
        mean_delay_hours: if completed == 0 { 0.0 } else { delay_weighted / completed as f64 },
        p95_delay_hours: metrics.iter().map(|m| m.p95_delay_hours).fold(0.0, f64::max),
        violations: metrics.iter().map(|m| m.violations).sum(),
        mean_utilization: if total_capacity > 0.0 { util_weighted / total_capacity } else { 0.0 },
        peak_allocated: metrics.iter().map(|m| m.peak_allocated).sum(),
        total_rescales: metrics.iter().map(|m| m.total_rescales).sum(),
        makespan: metrics.iter().map(|m| m.makespan).max().unwrap_or(0),
    };
    let mut outcomes = Vec::new();
    let mut slots = Vec::new();
    let mut overhead_energy_kwh = 0.0;
    let mut overhead_carbon_g = 0.0;
    for r in per_region {
        outcomes.extend(r.outcomes);
        slots.extend(r.slots);
        overhead_energy_kwh += r.overhead_energy_kwh;
        overhead_carbon_g += r.overhead_carbon_g;
    }
    SimResult { metrics: agg, outcomes, slots, overhead_energy_kwh, overhead_carbon_g }
}

fn mean_of(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// One prepared week-window cell: an immutable snapshot of the continuous
/// learning chain at week `week`, ready for parallel policy runs.
pub struct WeekCell {
    pub week: usize,
    /// Mean CI of the week's evaluation trace (seasonality indicator).
    pub mean_ci: f64,
    /// Live (non-tombstoned) knowledge-base cases after the window slide.
    pub kb_live: usize,
    /// The week's prepared experiment: 168 h evaluation window (+ drain
    /// week), trailing learning history, and the carried knowledge base
    /// pre-seeded (a memcpy snapshot — tombstones stay filtered at match
    /// time, exactly like the legacy loop's per-week `kb.clone()`).
    pub prep: PreparedExperiment,
}

/// Walk the continuous-learning chain and snapshot every requested week.
///
/// The chain is inherently sequential — each week's learning feeds the
/// next — so it walks weeks `0..=max(weeks)` even when only a subset is
/// requested: a cell's knowledge base always reflects the full history up
/// to its week, which makes a single-week sweep bitwise identical to the
/// corresponding week of a full run (the cross-scenario invariant the
/// yearlong equivalence tests pin).
///
/// Faithful port of the legacy `run_yearlong` learning loop: same year
/// synthesis, the same per-week job seeds (`seed ^ week<<8 ^ 0x1157` /
/// `^ 0xE7A1`), absolute-time case stamping, and an
/// [`advance_window`](KnowledgeBase::advance_window) slide before each
/// evaluation week. The learning history is generated with the
/// distribution-shift knobs reset (see
/// [`ExperimentConfig::unshifted_history`]), matching the Fig. 13 fidelity
/// fix in `PreparedExperiment::prepare`.
///
/// `learn_kb = false` skips the oracle-replay learning passes and window
/// slides entirely (the chain's dominant cost) — the sweep runner passes it
/// when no requested policy reads the knowledge base; such cells report
/// `kb_live == 0`.
pub fn prepare_week_chain(
    cfg: &ExperimentConfig,
    weeks: &[usize],
    aging_window_hours: usize,
    learn_kb: bool,
) -> Vec<WeekCell> {
    assert!(!weeks.is_empty());
    let region = Region::parse(&cfg.region)
        .unwrap_or_else(|| panic!("unknown region '{}'", cfg.region));
    let max_week = *weeks.iter().max().unwrap();
    let total_hours = cfg.history_hours + (max_week + 1) * 168 + 336;
    let year = synth::synthesize(region, total_hours.max(8760), cfg.seed);
    let energy = EnergyModel::for_hardware(cfg.hardware);
    let hist_cfg = cfg.unshifted_history();

    let mut kb = KnowledgeBase::new();
    let mut cells = Vec::with_capacity(weeks.len());
    for week in 0..=max_week {
        let eval_start = cfg.history_hours + week * 168;
        let hist_start = eval_start - cfg.history_hours;

        // --- Learning phase on the trailing window, then age the KB ---
        let hist_trace = year.slice(hist_start, cfg.history_hours);
        let week_seed = cfg.seed ^ (week as u64) << 8;
        let hist_jobs = tracegen::generate(&hist_cfg, cfg.history_hours, week_seed ^ 0x1157);
        if learn_kb {
            let fresh = learn(
                &hist_jobs,
                &hist_trace,
                &LearnConfig {
                    max_capacity: cfg.capacity,
                    num_queues: cfg.queues.len(),
                    offsets: cfg.replay_offsets,
                    energy: energy.clone(),
                    threads: 0, // parallel per-offset replays, offset-major merge
                },
            );
            for c in fresh.cases() {
                // Stamp cases with absolute time so aging works across weeks.
                kb.push(Case { recorded_at: hist_start + c.recorded_at, ..c.clone() });
            }
            // Amortized sliding-window maintenance: tombstone aged cases and
            // keep the fresh tail brute-force-matched, rebuilding the index
            // only once churn crosses the CARBONFLEX_KB_CHURN fraction.
            kb.advance_window(eval_start, aging_window_hours);
        }

        if !weeks.contains(&week) {
            continue;
        }

        // --- Snapshot the week as an immutable prepared cell. ---
        let eval_trace = year.slice(eval_start, 168 + 168); // + drain week
        let eval_jobs = tracegen::generate(cfg, 168, cfg.seed ^ (week as u64) << 8 ^ 0xE7A1);
        let mut week_cfg = cfg.clone();
        week_cfg.horizon_hours = 168;
        let prep = PreparedExperiment::from_parts(
            week_cfg,
            hist_trace,
            eval_trace,
            hist_jobs,
            eval_jobs,
            Some(kb.clone()),
        );
        cells.push(WeekCell {
            week,
            mean_ci: year.slice(eval_start, 168).mean(),
            kb_live: kb.live(),
            prep,
        });
    }
    // Requested weeks come back in ascending order; the sweep engine zips
    // them with its week-chain point indices, which it sorts the same way.
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_strategy_parse_roundtrip() {
        for d in DispatchStrategy::ALL {
            assert_eq!(DispatchStrategy::parse(d.as_str()), Some(d));
        }
        assert_eq!(DispatchStrategy::parse("rr"), Some(DispatchStrategy::RoundRobin));
        assert_eq!(DispatchStrategy::parse("window"), Some(DispatchStrategy::LowestWindowCi));
        assert_eq!(DispatchStrategy::parse("current"), Some(DispatchStrategy::LowestCurrentCi));
        assert_eq!(DispatchStrategy::parse("teleport"), None);
    }

    #[test]
    fn region_set_parses_in_order() {
        let set = parse_region_set("south-australia+ontario+virginia");
        assert_eq!(
            set,
            vec![Region::SouthAustralia, Region::Ontario, Region::Virginia]
        );
    }

    #[test]
    #[should_panic(expected = "unknown region")]
    fn region_set_rejects_unknown_keys() {
        parse_region_set("south-australia+atlantis");
    }

    #[test]
    fn spatial_prep_learns_on_the_dispatch_skewed_split() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 24; // 8 per region
        cfg.horizon_hours = 48;
        cfg.history_hours = 120;
        cfg.replay_offsets = 1;
        let regions = [Region::SouthAustralia, Region::California, Region::Virginia];
        let rr = prepare_spatial(&cfg, &regions, DispatchStrategy::RoundRobin);
        let geo = prepare_spatial(&cfg, &regions, DispatchStrategy::LowestWindowCi);

        // Both strategies partition the same global stream: every hist job
        // lands in exactly one region, with dense per-region ids.
        let rr_total: usize = rr.preps.iter().map(|p| p.hist_jobs.len()).sum();
        let geo_total: usize = geo.preps.iter().map(|p| p.hist_jobs.len()).sum();
        assert_eq!(rr_total, geo_total);
        assert!(rr_total > 0);
        for p in geo.preps.iter().chain(&rr.preps) {
            for (i, j) in p.hist_jobs.iter().enumerate() {
                assert_eq!(j.id, i, "routed hist jobs must be densely re-id'd");
            }
        }

        // Round-robin splits evenly; carbon-aware dispatch skews the
        // learning load toward the clean region (South Australia) and away
        // from the dirty one (Virginia).
        let rr_counts: Vec<usize> = rr.preps.iter().map(|p| p.hist_jobs.len()).collect();
        assert!(
            rr_counts.iter().max().unwrap() - rr_counts.iter().min().unwrap() <= 1,
            "round-robin split should be even: {rr_counts:?}"
        );
        let geo_counts: Vec<usize> = geo.preps.iter().map(|p| p.hist_jobs.len()).collect();
        assert!(
            geo_counts[0] > geo_counts[2],
            "window-CI dispatch should favour the clean region: {geo_counts:?}"
        );
        assert!(
            geo_counts[2] < rr_counts[2],
            "the dirty region must train on fewer jobs than under round-robin"
        );

        // The regression this pins: the dirty region's knowledge base is
        // learned from its (smaller) routed stream, not the full one.
        let geo_kb = geo.preps[2].knowledge_base().live();
        let rr_kb = rr.preps[2].knowledge_base().live();
        assert!(
            geo_kb < rr_kb,
            "skewed KB should hold fewer cases than the round-robin KB ({geo_kb} vs {rr_kb})"
        );
    }

    #[test]
    fn week_chain_subset_matches_full_chain() {
        // The chain walks every week up to the max request, so a
        // subset-sweep's cell carries the same knowledge base as the same
        // week inside a full sweep — the invariant that makes week cells
        // safely grid-parallel.
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 12;
        cfg.history_hours = 168;
        cfg.horizon_hours = 48;
        cfg.replay_offsets = 1;
        let full = prepare_week_chain(&cfg, &[0, 1, 2], 24 * 28, true);
        let subset = prepare_week_chain(&cfg, &[2], 24 * 28, true);
        assert_eq!(full.len(), 3);
        assert_eq!(subset.len(), 1);
        let (a, b) = (&full[2], &subset[0]);
        assert_eq!(a.week, 2);
        assert_eq!(a.kb_live, b.kb_live);
        assert_eq!(a.mean_ci.to_bits(), b.mean_ci.to_bits());
        let (ra, rb) = (a.prep.run(PolicyKind::CarbonFlex), b.prep.run(PolicyKind::CarbonFlex));
        assert_eq!(ra.fingerprint(), rb.fingerprint());
    }
}
