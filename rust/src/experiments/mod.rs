//! Experiment drivers — one per figure/table in the paper's evaluation
//! (§6). Each driver builds traces + jobs from an [`ExperimentConfig`], runs
//! the requested policies, and returns paper-shaped rows. The `benches/`
//! binaries and the CLI `experiment` subcommand are thin wrappers over
//! these.

pub mod cells;
pub mod chaos;
pub mod figures;
pub mod forecast_noise;
pub mod net;
pub mod perf;
pub mod runner;
pub mod spatial;
pub mod sweep;
pub mod yearlong;

pub use cells::{route_arrival, DispatchStrategy};
pub use chaos::{run_chaos_bench, ChaosBenchOpts, ChaosReport};
pub use net::{run_net_bench, NetBenchOpts, NetReport};
pub use runner::{run_policies, run_policy, ExperimentRow, PreparedExperiment};
pub use sweep::{SweepRunner, SweepSpec, SweepVariant};
