//! Spatial-shifting extension (the paper's §8 future work: "distributed
//! cluster settings"; §2.1 motivates spatial as well as temporal shifting).
//!
//! A geo-dispatcher owns one cluster per region and routes each job at
//! arrival; every regional cluster then schedules locally with its own
//! policy. This composes the existing substrates — per-region carbon
//! traces, the [`ClusterEngine`], and the CarbonFlex learning loop — into
//! a multi-region deployment, quantifying how much spatial freedom adds on
//! top of CarbonFlex's temporal/elastic savings.

use crate::carbon::forecast::Forecaster;
use crate::carbon::synth::Region;
use crate::cluster::energy::EnergyModel;
use crate::cluster::metrics::RunMetrics;
use crate::cluster::sim::{ClusterEngine, Simulator};
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::sched::{Policy, PolicyKind};
use crate::workload::job::Job;
use crate::workload::tracegen;

/// How the dispatcher picks a region for an arriving job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchStrategy {
    /// Round-robin — the carbon-agnostic baseline for spatial decisions.
    RoundRobin,
    /// Route to the region with the lowest *current* carbon intensity.
    LowestCurrentCi,
    /// Route to the region whose forecast is cleanest over the job's
    /// expected window (arrival → deadline), weighted by base length.
    LowestWindowCi,
}

impl DispatchStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            DispatchStrategy::RoundRobin => "round-robin",
            DispatchStrategy::LowestCurrentCi => "lowest-current-CI",
            DispatchStrategy::LowestWindowCi => "lowest-window-CI",
        }
    }
}

/// Result of one multi-region run.
#[derive(Debug)]
pub struct SpatialResult {
    pub strategy: DispatchStrategy,
    /// Local (per-cluster) scheduling policy used everywhere.
    pub local_policy: PolicyKind,
    /// Summed metrics across regions.
    pub carbon_g: f64,
    pub completed: usize,
    pub unfinished: usize,
    pub mean_delay_hours: f64,
    /// Jobs routed to each region.
    pub jobs_per_region: Vec<usize>,
}

/// One regional cluster: engine + forecaster + local policy.
struct RegionalCluster {
    engine: ClusterEngine,
    forecaster: Forecaster,
    policy: Box<dyn Policy>,
    next_id: usize,
}

/// Prepare one regional experiment per region (`cfg.capacity` split evenly;
/// each region gets its own trace and, for CarbonFlex, its own locally
/// learned knowledge base). Preparation does not depend on the dispatch
/// strategy or local policy, so callers comparing several combos share one
/// set of preps across all of them; regions prepare in parallel.
pub fn prepare_regions(cfg: &ExperimentConfig, regions: &[Region]) -> Vec<PreparedExperiment> {
    assert!(!regions.is_empty());
    let per_region_capacity = (cfg.capacity / regions.len()).max(1);
    crate::experiments::sweep::par_map(
        crate::experiments::sweep::auto_threads(),
        regions,
        |&region, _| {
            let mut rcfg = cfg.clone();
            rcfg.region = region.key().to_string();
            rcfg.capacity = per_region_capacity;
            PreparedExperiment::prepare(&rcfg)
        },
    )
}

/// Run a multi-region deployment: `regions.len()` clusters of
/// `cfg.capacity / regions.len()` servers each, one shared arrival stream.
pub fn run_spatial(
    cfg: &ExperimentConfig,
    regions: &[Region],
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> SpatialResult {
    run_spatial_prepared(cfg, &prepare_regions(cfg, regions), strategy, local_policy)
}

/// [`run_spatial`] over already-prepared regions (see [`prepare_regions`]).
pub fn run_spatial_prepared(
    cfg: &ExperimentConfig,
    preps: &[PreparedExperiment],
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> SpatialResult {
    assert!(!preps.is_empty());
    let horizon = cfg.horizon_hours;
    let energy = EnergyModel::for_hardware(cfg.hardware);

    // Build the regional clusters over the shared prepared state.
    let mut clusters: Vec<RegionalCluster> = preps
        .iter()
        .map(|prep| {
            let policy: Box<dyn Policy> = prep.build_policy(local_policy);
            let sim =
                Simulator::new(prep.cfg.capacity, energy.clone(), cfg.queues.len(), horizon);
            RegionalCluster {
                engine: ClusterEngine::new(sim),
                forecaster: Forecaster::perfect(prep.eval_trace.clone()),
                policy,
                next_id: 0,
            }
        })
        .collect();

    // One global arrival stream sized for the aggregate capacity.
    let jobs = tracegen::generate(cfg, horizon, cfg.seed ^ 0x5EA7);
    let mut jobs_per_region = vec![0usize; preps.len()];
    let mut rr = 0usize;

    // Dispatch + step in lockstep.
    let mut by_arrival: Vec<&Job> = jobs.iter().collect();
    by_arrival.sort_by_key(|j| j.arrival);
    let mut next_job = 0usize;
    let last_arrival = by_arrival.last().map(|j| j.arrival).unwrap_or(0);
    let t_end = last_arrival + horizon + 4096;

    for t in 0..t_end {
        // Route this slot's arrivals.
        while next_job < by_arrival.len() && by_arrival[next_job].arrival == t {
            let job = by_arrival[next_job];
            let r = match strategy {
                DispatchStrategy::RoundRobin => {
                    rr = (rr + 1) % clusters.len();
                    rr
                }
                DispatchStrategy::LowestCurrentCi => clusters
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        a.forecaster.predict(t).partial_cmp(&b.forecaster.predict(t)).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap(),
                DispatchStrategy::LowestWindowCi => {
                    let window = (job.length_hours + job.slack_hours).ceil() as usize;
                    clusters
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            let ma = mean_of(&a.forecaster.predict_window(t, window));
                            let mb = mean_of(&b.forecaster.predict_window(t, window));
                            ma.partial_cmp(&mb).unwrap()
                        })
                        .map(|(i, _)| i)
                        .unwrap()
                }
            };
            let c = &mut clusters[r];
            // Re-id within the destination cluster (engines need dense ids).
            let local = Job { id: c.next_id, arrival: t, ..job.clone() };
            c.next_id += 1;
            c.engine.add_job(local);
            jobs_per_region[r] += 1;
            next_job += 1;
        }
        // Advance every region one slot.
        let mut any_pending = next_job < by_arrival.len();
        for c in clusters.iter_mut() {
            if c.engine.pending_jobs() > 0 {
                c.engine.step(t, &c.forecaster, c.policy.as_mut());
                any_pending = true;
            }
        }
        if !any_pending {
            break;
        }
    }

    // Aggregate.
    let metrics: Vec<RunMetrics> = clusters
        .into_iter()
        .map(|c| c.engine.finish("regional").metrics)
        .collect();
    let completed = metrics.iter().map(|m| m.completed).sum();
    let delay_weighted: f64 =
        metrics.iter().map(|m| m.mean_delay_hours * m.completed as f64).sum();
    SpatialResult {
        strategy,
        local_policy,
        carbon_g: metrics.iter().map(|m| m.carbon_g).sum(),
        completed,
        unfinished: metrics.iter().map(|m| m.unfinished).sum(),
        mean_delay_hours: if completed == 0 { 0.0 } else { delay_weighted / completed as f64 },
        jobs_per_region,
    }
}

fn mean_of(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Print the spatial comparison table (used by the bench and CLI). The
/// dispatch × local-policy combos are independent deployments, so they run
/// in parallel on the sweep engine's thread pool; the first combo
/// (round-robin + carbon-agnostic) is the savings baseline.
pub fn print_spatial(cfg: &ExperimentConfig) {
    use crate::experiments::sweep::{auto_threads, par_map};
    use crate::util::bench::Table;
    let regions = [Region::SouthAustralia, Region::California, Region::GreatBritain];
    println!(
        "\n== Extension: spatial shifting across {} regions ({} servers each) ==",
        regions.len(),
        cfg.capacity / regions.len()
    );
    let mut t = Table::new(&[
        "dispatch",
        "local policy",
        "carbon (kg)",
        "savings %",
        "mean delay (h)",
        "jobs/region",
    ]);
    let combos = [
        (DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic),
        (DispatchStrategy::LowestCurrentCi, PolicyKind::CarbonAgnostic),
        (DispatchStrategy::LowestWindowCi, PolicyKind::CarbonAgnostic),
        (DispatchStrategy::RoundRobin, PolicyKind::CarbonFlex),
        (DispatchStrategy::LowestWindowCi, PolicyKind::CarbonFlex),
    ];
    // Each region's synthesis/learning runs once, shared by all 5 combos.
    let preps = prepare_regions(cfg, &regions);
    let results = par_map(auto_threads(), &combos, |&(strategy, local), _| {
        run_spatial_prepared(cfg, &preps, strategy, local)
    });
    let base = results[0].carbon_g;
    for r in &results {
        t.row(&[
            r.strategy.as_str().to_string(),
            r.local_policy.as_str().to_string(),
            format!("{:.2}", r.carbon_g / 1000.0),
            format!("{:.1}", (1.0 - r.carbon_g / base) * 100.0),
            format!("{:.2}", r.mean_delay_hours),
            format!("{:?}", r.jobs_per_region),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 30; // 10 per region
        cfg.horizon_hours = 72;
        cfg.history_hours = 120;
        cfg.replay_offsets = 1;
        cfg
    }

    const REGIONS: [Region; 3] = [Region::SouthAustralia, Region::California, Region::Virginia];

    #[test]
    fn all_jobs_complete_under_every_strategy() {
        for strategy in [
            DispatchStrategy::RoundRobin,
            DispatchStrategy::LowestCurrentCi,
            DispatchStrategy::LowestWindowCi,
        ] {
            let r = run_spatial(&cfg(), &REGIONS, strategy, PolicyKind::CarbonAgnostic);
            assert_eq!(r.unfinished, 0, "{strategy:?}");
            assert!(r.completed > 0);
            assert_eq!(r.jobs_per_region.iter().sum::<usize>(), r.completed);
        }
    }

    #[test]
    fn carbon_aware_dispatch_beats_round_robin() {
        let rr =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic);
        let geo = run_spatial(
            &cfg(),
            &REGIONS,
            DispatchStrategy::LowestWindowCi,
            PolicyKind::CarbonAgnostic,
        );
        assert!(
            geo.carbon_g < rr.carbon_g * 0.95,
            "geo {} vs rr {}",
            geo.carbon_g,
            rr.carbon_g
        );
        // The dirty region (Virginia) should receive the fewest jobs.
        assert!(geo.jobs_per_region[2] < geo.jobs_per_region[0]);
    }

    #[test]
    fn spatial_and_temporal_compose_vs_baseline() {
        // CarbonFlex locally + geo dispatch must clearly beat the fully
        // carbon-agnostic deployment (round-robin + FCFS). Note it does
        // NOT always beat geo + agnostic: carbon-aware dispatch skews each
        // region's load away from the distribution its knowledge base was
        // learned on — an interaction worth reporting, not hiding (see the
        // spatial_shifting bench output).
        let baseline =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic);
        let both =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::LowestWindowCi, PolicyKind::CarbonFlex);
        assert!(
            both.carbon_g < baseline.carbon_g * 0.9,
            "both {} vs baseline {}",
            both.carbon_g,
            baseline.carbon_g
        );
        assert_eq!(both.unfinished, 0);
    }
}
