//! Spatial-shifting extension (the paper's §8 future work: "distributed
//! cluster settings"; §2.1 motivates spatial as well as temporal shifting).
//!
//! A geo-dispatcher owns one cluster per region and routes each job at
//! arrival; every regional cluster then schedules locally with its own
//! policy. Since PR 5, multi-region deployments are **first-class sweep
//! cells**: a `+`-joined region set on the sweep's `regions` axis plus the
//! `dispatchers` axis (see `experiments/sweep.rs`; the per-slot dispatch
//! engine lives in `experiments/cells.rs`). This module is the thin
//! adapter layer — [`run_spatial`] / [`run_spatial_prepared`] build a
//! single-cell [`SweepSpec`] and route it through [`SweepRunner`], and
//! [`print_spatial`] is one dispatch × local-policy grid. The retired
//! bespoke loop survives in-test as a bitwise reference implementation.

use std::sync::Arc;

use crate::carbon::synth::Region;
use crate::config::ExperimentConfig;
use crate::experiments::cells;
use crate::experiments::runner::PreparedExperiment;
use crate::experiments::sweep::{SweepRow, SweepRunner, SweepSpec};
use crate::sched::PolicyKind;

pub use crate::experiments::cells::DispatchStrategy;

/// Result of one multi-region run.
#[derive(Debug)]
pub struct SpatialResult {
    pub strategy: DispatchStrategy,
    /// Local (per-cluster) scheduling policy used everywhere.
    pub local_policy: PolicyKind,
    /// Summed metrics across regions.
    pub carbon_g: f64,
    pub completed: usize,
    pub unfinished: usize,
    pub mean_delay_hours: f64,
    /// Jobs routed to each region.
    pub jobs_per_region: Vec<usize>,
}

impl SpatialResult {
    /// Extract the legacy result shape from one spatial sweep row.
    fn from_row(row: &SweepRow, strategy: DispatchStrategy, local_policy: PolicyKind) -> Self {
        let m = &row.result.metrics;
        SpatialResult {
            strategy,
            local_policy,
            carbon_g: m.carbon_g,
            completed: m.completed,
            unfinished: m.unfinished,
            mean_delay_hours: m.mean_delay_hours,
            jobs_per_region: row
                .jobs_per_region
                .clone()
                .expect("spatial rows carry per-region routing"),
        }
    }
}

/// Join a region list into the sweep engine's `+`-set axis key.
pub fn region_set_key(regions: &[Region]) -> String {
    regions.iter().map(|r| r.key()).collect::<Vec<_>>().join("+")
}

/// Prepare one regional experiment per region (`cfg.capacity` split evenly;
/// each region gets its own trace and, for CarbonFlex, its own locally
/// learned knowledge base). This is the strategy-independent *unskewed*
/// preparation (each region learns on a full per-region-scaled history), so
/// callers comparing several combos can still share one set of preps across
/// all of them via [`run_spatial_prepared`]; regions prepare in parallel.
/// The sweep engine's own (non-injected) spatial cells instead learn each
/// region's knowledge base from the dispatch-skewed historical split — see
/// [`cells::prepare_spatial`].
pub fn prepare_regions(
    cfg: &ExperimentConfig,
    regions: &[Region],
) -> Vec<Arc<PreparedExperiment>> {
    cells::prepare_spatial_unskewed(cfg, regions).preps
}

/// Build the single-cell sweep spec for one (set, strategy, policy) combo.
fn single_cell_spec(
    cfg: &ExperimentConfig,
    regions: &[Region],
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> SweepSpec {
    let mut spec = SweepSpec::new(cfg.clone());
    spec.regions = vec![region_set_key(regions)];
    spec.dispatchers = vec![strategy];
    spec.policies = vec![local_policy];
    spec
}

/// Run a multi-region deployment: `regions.len()` clusters of
/// `cfg.capacity / regions.len()` servers each, one shared arrival stream.
/// Thin adapter over a single spatial sweep cell.
pub fn run_spatial(
    cfg: &ExperimentConfig,
    regions: &[Region],
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> SpatialResult {
    let spec = single_cell_spec(cfg, regions, strategy, local_policy);
    let rows = SweepRunner::auto().run(&spec);
    SpatialResult::from_row(&rows[0], strategy, local_policy)
}

/// [`run_spatial`] over already-prepared regions (see [`prepare_regions`]):
/// the preps are injected into the spec, so several combos share one
/// synthesis + learning pass. Routes through the same sweep cell.
pub fn run_spatial_prepared(
    cfg: &ExperimentConfig,
    preps: &[Arc<PreparedExperiment>],
    strategy: DispatchStrategy,
    local_policy: PolicyKind,
) -> SpatialResult {
    assert!(!preps.is_empty());
    let regions: Vec<Region> = preps
        .iter()
        .map(|p| Region::parse(&p.cfg.region).expect("prepared region"))
        .collect();
    let mut spec = single_cell_spec(cfg, &regions, strategy, local_policy);
    spec.spatial_preps = preps.to_vec();
    let rows = SweepRunner::auto().run(&spec);
    SpatialResult::from_row(&rows[0], strategy, local_policy)
}

/// Print the spatial comparison table (used by the bench and CLI): one
/// sweep grid over the dispatch × local-policy axes. The sweep runner
/// shares each region's synthesis/learning across every dispatch strategy
/// at the point; the round-robin + carbon-agnostic cell is the savings
/// baseline, as in the paper-style table.
pub fn print_spatial(cfg: &ExperimentConfig) {
    use crate::util::bench::Table;
    let regions = [Region::SouthAustralia, Region::California, Region::GreatBritain];
    println!(
        "\n== Extension: spatial shifting across {} regions ({} servers each) ==",
        regions.len(),
        cfg.capacity / regions.len()
    );
    let mut spec = SweepSpec::new(cfg.clone());
    spec.regions = vec![region_set_key(&regions)];
    spec.dispatchers = DispatchStrategy::ALL.to_vec();
    spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
    let rows = SweepRunner::auto().run(&spec);

    let mut t = Table::new(&[
        "dispatch",
        "local policy",
        "carbon (kg)",
        "savings %",
        "mean delay (h)",
        "jobs/region",
    ]);
    // Savings vs. the fully carbon-agnostic deployment (round-robin +
    // FCFS), which grid order puts first.
    let base = rows[0].result.metrics.carbon_g;
    for r in &rows {
        let m = &r.result.metrics;
        t.row(&[
            r.point.dispatch.clone(),
            m.policy.clone(),
            format!("{:.2}", m.carbon_g / 1000.0),
            format!("{:.1}", (1.0 - m.carbon_g / base) * 100.0),
            format!("{:.2}", m.mean_delay_hours),
            format!("{:?}", r.jobs_per_region.as_ref().expect("spatial row")),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 30; // 10 per region
        cfg.horizon_hours = 72;
        cfg.history_hours = 120;
        cfg.replay_offsets = 1;
        cfg
    }

    const REGIONS: [Region; 3] = [Region::SouthAustralia, Region::California, Region::Virginia];

    /// The retired bespoke driver, kept verbatim as the bitwise reference
    /// the sweep-routed path must reproduce (the PR 3 sanitize/kd-search
    /// pattern). Any change to the sweep's spatial cell that alters output
    /// bits fails the equivalence test below.
    mod legacy_reference {
        use super::*;
        use crate::carbon::forecast::Forecaster;
        use crate::cluster::energy::EnergyModel;
        use crate::cluster::metrics::RunMetrics;
        use crate::cluster::sim::{ClusterEngine, Simulator};
        use crate::sched::Policy;
        use crate::workload::job::Job;
        use crate::workload::tracegen;

        struct RegionalCluster {
            engine: ClusterEngine,
            forecaster: Forecaster,
            policy: Box<dyn Policy>,
            next_id: usize,
        }

        fn mean_of(xs: &[f64]) -> f64 {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        }

        pub fn run_spatial_prepared(
            cfg: &ExperimentConfig,
            preps: &[Arc<PreparedExperiment>],
            strategy: DispatchStrategy,
            local_policy: PolicyKind,
        ) -> SpatialResult {
            assert!(!preps.is_empty());
            let horizon = cfg.horizon_hours;
            let energy = EnergyModel::for_hardware(cfg.hardware);

            let mut clusters: Vec<RegionalCluster> = preps
                .iter()
                .map(|prep| {
                    let policy: Box<dyn Policy> = prep.build_policy(local_policy);
                    let sim = Simulator::new(
                        prep.cfg.capacity,
                        energy.clone(),
                        cfg.queues.len(),
                        horizon,
                    );
                    RegionalCluster {
                        engine: ClusterEngine::new(sim),
                        forecaster: Forecaster::perfect(prep.eval_trace.clone()),
                        policy,
                        next_id: 0,
                    }
                })
                .collect();

            let jobs = tracegen::generate(cfg, horizon, cfg.seed ^ 0x5EA7);
            let mut jobs_per_region = vec![0usize; preps.len()];
            let mut rr = 0usize;

            let mut by_arrival: Vec<&Job> = jobs.iter().collect();
            by_arrival.sort_by_key(|j| j.arrival);
            let mut next_job = 0usize;
            let last_arrival = by_arrival.last().map(|j| j.arrival).unwrap_or(0);
            let t_end = last_arrival + horizon + 4096;

            for t in 0..t_end {
                while next_job < by_arrival.len() && by_arrival[next_job].arrival == t {
                    let job = by_arrival[next_job];
                    let r = match strategy {
                        DispatchStrategy::RoundRobin => {
                            rr = (rr + 1) % clusters.len();
                            rr
                        }
                        DispatchStrategy::LowestCurrentCi => clusters
                            .iter()
                            .enumerate()
                            .min_by(|(_, a), (_, b)| {
                                a.forecaster
                                    .predict(t)
                                    .partial_cmp(&b.forecaster.predict(t))
                                    .unwrap()
                            })
                            .map(|(i, _)| i)
                            .unwrap(),
                        DispatchStrategy::LowestWindowCi => {
                            let window = (job.length_hours + job.slack_hours).ceil() as usize;
                            clusters
                                .iter()
                                .enumerate()
                                .min_by(|(_, a), (_, b)| {
                                    let ma = mean_of(&a.forecaster.predict_window(t, window));
                                    let mb = mean_of(&b.forecaster.predict_window(t, window));
                                    ma.partial_cmp(&mb).unwrap()
                                })
                                .map(|(i, _)| i)
                                .unwrap()
                        }
                    };
                    let c = &mut clusters[r];
                    let local = Job { id: c.next_id, arrival: t, ..job.clone() };
                    c.next_id += 1;
                    c.engine.add_job(local);
                    jobs_per_region[r] += 1;
                    next_job += 1;
                }
                let mut any_pending = next_job < by_arrival.len();
                for c in clusters.iter_mut() {
                    if c.engine.pending_jobs() > 0 {
                        c.engine.step(t, &c.forecaster, c.policy.as_mut());
                        any_pending = true;
                    }
                }
                if !any_pending {
                    break;
                }
            }

            let metrics: Vec<RunMetrics> = clusters
                .into_iter()
                .map(|c| c.engine.finish("regional").metrics)
                .collect();
            let completed = metrics.iter().map(|m| m.completed).sum();
            let delay_weighted: f64 =
                metrics.iter().map(|m| m.mean_delay_hours * m.completed as f64).sum();
            SpatialResult {
                strategy,
                local_policy,
                carbon_g: metrics.iter().map(|m| m.carbon_g).sum(),
                completed,
                unfinished: metrics.iter().map(|m| m.unfinished).sum(),
                mean_delay_hours: if completed == 0 {
                    0.0
                } else {
                    delay_weighted / completed as f64
                },
                jobs_per_region,
            }
        }
    }

    #[test]
    fn sweep_cell_is_bitwise_identical_to_legacy_loop() {
        // The tentpole equivalence: a single-cell sweep over the regions
        // axis reproduces the retired bespoke driver bit for bit, for every
        // strategy and for both a plain and a learning local policy.
        let cfg = cfg();
        let preps = prepare_regions(&cfg, &REGIONS);
        for (strategy, local) in [
            (DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic),
            (DispatchStrategy::LowestCurrentCi, PolicyKind::CarbonAgnostic),
            (DispatchStrategy::LowestWindowCi, PolicyKind::CarbonAgnostic),
            (DispatchStrategy::LowestWindowCi, PolicyKind::CarbonFlex),
        ] {
            let want = legacy_reference::run_spatial_prepared(&cfg, &preps, strategy, local);
            let got = run_spatial_prepared(&cfg, &preps, strategy, local);
            assert_eq!(
                got.carbon_g.to_bits(),
                want.carbon_g.to_bits(),
                "{strategy:?}/{local:?}: carbon diverged ({} vs {})",
                got.carbon_g,
                want.carbon_g
            );
            assert_eq!(got.completed, want.completed, "{strategy:?}/{local:?}");
            assert_eq!(got.unfinished, want.unfinished, "{strategy:?}/{local:?}");
            assert_eq!(
                got.mean_delay_hours.to_bits(),
                want.mean_delay_hours.to_bits(),
                "{strategy:?}/{local:?}: delay diverged"
            );
            assert_eq!(got.jobs_per_region, want.jobs_per_region, "{strategy:?}/{local:?}");
        }
    }

    #[test]
    fn fresh_and_injected_preps_agree() {
        // run_spatial (fresh preps inside the sweep) and
        // run_spatial_prepared (injected preps) are the same cell.
        let cfg = cfg();
        let preps = prepare_regions(&cfg, &REGIONS);
        let a = run_spatial(
            &cfg,
            &REGIONS,
            DispatchStrategy::LowestWindowCi,
            PolicyKind::CarbonAgnostic,
        );
        let b = run_spatial_prepared(
            &cfg,
            &preps,
            DispatchStrategy::LowestWindowCi,
            PolicyKind::CarbonAgnostic,
        );
        assert_eq!(a.carbon_g.to_bits(), b.carbon_g.to_bits());
        assert_eq!(a.jobs_per_region, b.jobs_per_region);
    }

    #[test]
    fn all_jobs_complete_under_every_strategy() {
        for strategy in [
            DispatchStrategy::RoundRobin,
            DispatchStrategy::LowestCurrentCi,
            DispatchStrategy::LowestWindowCi,
        ] {
            let r = run_spatial(&cfg(), &REGIONS, strategy, PolicyKind::CarbonAgnostic);
            assert_eq!(r.unfinished, 0, "{strategy:?}");
            assert!(r.completed > 0);
            assert_eq!(r.jobs_per_region.iter().sum::<usize>(), r.completed);
        }
    }

    #[test]
    fn carbon_aware_dispatch_beats_round_robin() {
        let rr =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic);
        let geo = run_spatial(
            &cfg(),
            &REGIONS,
            DispatchStrategy::LowestWindowCi,
            PolicyKind::CarbonAgnostic,
        );
        assert!(
            geo.carbon_g < rr.carbon_g * 0.95,
            "geo {} vs rr {}",
            geo.carbon_g,
            rr.carbon_g
        );
        // The dirty region (Virginia) should receive the fewest jobs.
        assert!(geo.jobs_per_region[2] < geo.jobs_per_region[0]);
    }

    #[test]
    fn spatial_and_temporal_compose_vs_baseline() {
        // CarbonFlex locally + geo dispatch must clearly beat the fully
        // carbon-agnostic deployment (round-robin + FCFS). The fresh-prep
        // sweep path (run_spatial) now learns each region's knowledge base
        // from the dispatch-skewed historical split, so the KBs match the
        // load distribution carbon-aware dispatch actually sends them (the
        // PR-5 train/serve-mismatch follow-up).
        let baseline =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::RoundRobin, PolicyKind::CarbonAgnostic);
        let both =
            run_spatial(&cfg(), &REGIONS, DispatchStrategy::LowestWindowCi, PolicyKind::CarbonFlex);
        assert!(
            both.carbon_g < baseline.carbon_g * 0.9,
            "both {} vs baseline {}",
            both.carbon_g,
            baseline.carbon_g
        );
        assert_eq!(both.unfinished, 0);
    }
}
