//! Chaos benchmark (§Robustness): what running through faults costs.
//!
//! Two deterministic legs over one seeded [`FaultPlan`]:
//!
//! - **Sim leg** — one prepared experiment, the chosen policy run clean and
//!   under the plan's slot crashes + signal outages: the carbon overhead of
//!   the degradation ladder, restart counts, lost work, and crash-recovery
//!   percentiles.
//! - **Serve leg** — a sharded deployment driven through the same arrival
//!   stream with the plan's mid-stream shard kills armed: supervisor
//!   failover counters, the shed-during-failover rate, and the exactly-once
//!   drain identity (killed-incarnation completions + failover sheds +
//!   fleet drain == every accepted submission).
//!
//! Emitted as the `BENCH_chaos.json` document; the CI `chaos-smoke` job
//! runs the smoke config, asserts the headline fields, and uploads the
//! JSON as an artifact.

use std::sync::{Arc, Mutex};

use crate::carbon::synth::Region;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::client::SessionClient;
use crate::coordinator::loadgen::{drive, drive_session, submissions_of};
use crate::coordinator::session::{take_cluster, SessionConfig, SessionServer};
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::transport::{FrameHandler, LoopbackTransport};
use crate::experiments::cells::DispatchStrategy;
use crate::experiments::runner::PreparedExperiment;
use crate::faults::net::{LinkFaultSpec, LinkPlan};
use crate::faults::{FaultPlan, FaultSpec};
use crate::sched::PolicyKind;
use crate::util::json::Json;
use crate::workload::tracegen;

/// Options for [`run_chaos_bench`].
#[derive(Debug, Clone)]
pub struct ChaosBenchOpts {
    pub cfg: ExperimentConfig,
    pub service: ServiceConfig,
    /// Fault preset name (see [`FaultSpec::preset`]).
    pub preset: String,
    /// Sim-leg policy (the paper's headline is CarbonFlex — it is the only
    /// policy with a non-trivial degradation ladder).
    pub kind: PolicyKind,
    /// Serve-leg shard policy.
    pub serve_kind: PolicyKind,
    /// Serve-leg arrival count. Must exceed the preset's
    /// `kill_after_max` for the shard kill to fire mid-stream.
    pub serve_jobs: usize,
    /// Serve-leg shard count (kills need at least one survivor).
    pub shards: usize,
}

impl ChaosBenchOpts {
    pub fn new(cfg: ExperimentConfig, service: ServiceConfig) -> ChaosBenchOpts {
        ChaosBenchOpts {
            cfg,
            service,
            preset: "light".to_string(),
            kind: PolicyKind::CarbonFlex,
            serve_kind: PolicyKind::CarbonAgnostic,
            serve_jobs: 120,
            shards: 2,
        }
    }
}

/// The measured chaos document.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub preset: String,
    // Sim leg.
    pub carbon_clean_g: f64,
    pub carbon_faulted_g: f64,
    /// Carbon cost of running through the faults, % over the clean run.
    pub carbon_overhead_pct: f64,
    pub restarts: u64,
    pub lost_work_hours: f64,
    pub recovery_p50_slots: f64,
    pub recovery_p99_slots: f64,
    pub degraded_stale: u64,
    pub degraded_fallback: u64,
    // Serve leg.
    pub serve_submitted: usize,
    pub serve_accepted: usize,
    pub serve_completed: usize,
    pub killed_completed: usize,
    pub failovers: u64,
    pub rerouted: u64,
    pub failover_shed: u64,
    /// Fraction of failed-over submissions lost: shed / (rerouted + shed).
    pub shed_during_failover_rate: f64,
    /// Exactly-once drain identity: killed-incarnation completions +
    /// failover sheds + fleet drain == accepted submissions.
    pub drained_exactly_once: bool,
    // Session chaos leg: the same shard kills, driven through a session
    // client over a loopback link carrying a seeded fault plan.
    pub session_reconnects: u64,
    pub session_retries: u64,
    pub session_dedup_hits: u64,
    pub session_link_events: usize,
    /// Exactly-once identity under combined shard kills + link faults,
    /// with the server-side session ledger agreeing with the client.
    pub session_exactly_once: bool,
}

/// Run both chaos legs. Deterministic in `(opts.cfg.seed, preset)`; the
/// "none" preset degenerates to a clean run with zero overhead.
pub fn run_chaos_bench(opts: &ChaosBenchOpts) -> Result<ChaosReport, String> {
    let spec = FaultSpec::preset(&opts.preset)
        .ok_or_else(|| format!("unknown fault preset '{}'", opts.preset))?;
    let cfg = &opts.cfg;

    // --- Sim leg: clean vs faulted on one prepared experiment. ---
    let plan = FaultPlan::generate(cfg.seed, &spec, cfg.horizon_hours, cfg.capacity, 1);
    let prep = PreparedExperiment::prepare(cfg);
    let clean = prep.run(opts.kind);
    let faulted = prep.run_with_plan(opts.kind, &plan);
    let (cg, fg) = (clean.metrics.carbon_g, faulted.metrics.carbon_g);
    let carbon_overhead_pct = if cg > 0.0 { (fg - cg) / cg * 100.0 } else { 0.0 };

    // --- Serve leg: sharded deployment with mid-stream shard kills. ---
    let shards = opts.shards.max(2);
    let serve_plan = FaultPlan::generate(cfg.seed, &spec, cfg.horizon_hours, cfg.capacity, shards);
    let base = Region::parse(&cfg.region).unwrap_or(Region::ALL[0]);
    let start = Region::ALL.iter().position(|r| r.key() == base.key()).unwrap_or(0);
    let regions: Vec<Region> =
        (0..shards).map(|i| Region::ALL[(start + i) % Region::ALL.len()]).collect();
    let trace = tracegen::generate_n(cfg, cfg.horizon_hours, cfg.seed, opts.serve_jobs);
    let arrivals = submissions_of(&trace);
    let mut cluster = ShardedCoordinator::start(
        cfg,
        &opts.service,
        opts.serve_kind,
        &regions,
        DispatchStrategy::RoundRobin,
    );
    cluster.set_kill_plan(&serve_plan.shard_kills);
    let report = drive(&mut cluster, &arrivals, 1, "chaos");
    let (failovers, rerouted, failover_shed) = cluster.failover_counters();
    let killed_completed: usize = cluster.killed_metrics().iter().map(|m| m.completed).sum();
    cluster.shutdown();
    let failed_over = rerouted + failover_shed;
    let shed_during_failover_rate =
        if failed_over > 0 { failover_shed as f64 / failed_over as f64 } else { 0.0 };
    let drained_exactly_once = killed_completed as u64
        + report.completed as u64
        + failover_shed
        == report.accepted as u64;

    // --- Session chaos leg: the same kill plan, driven through a session
    // client whose loopback link carries a seeded fault plan from the
    // matching link preset. Dedup'd retries never reach the cluster, so
    // the kill clock (submissions seen) fires at the same points as the
    // plain serve leg — and the exactly-once identity must still hold.
    let link_spec = LinkFaultSpec::preset(&opts.preset)
        .ok_or_else(|| format!("unknown link-fault preset '{}'", opts.preset))?;
    let link_plan =
        LinkPlan::generate(cfg.seed, &link_spec, opts.serve_jobs + cfg.horizon_hours + 16);
    let session_link_events = link_plan.len();
    let mut session_cluster = ShardedCoordinator::start(
        cfg,
        &opts.service,
        opts.serve_kind,
        &regions,
        DispatchStrategy::RoundRobin,
    );
    session_cluster.set_kill_plan(&serve_plan.shard_kills);
    let server = Arc::new(Mutex::new(SessionServer::new(
        session_cluster,
        SessionConfig::default(),
    )));
    let handler: Arc<Mutex<dyn FrameHandler>> = server.clone();
    let mut client = SessionClient::new(
        Box::new(LoopbackTransport::new(handler, link_plan)),
        "chaos-session",
        cfg.seed,
    );
    let s_report = drive_session(&mut client, &arrivals, 16, "chaos-session")
        .map_err(|e| format!("session chaos leg failed: {e}"))?;
    let s_stats = client.stats();
    drop(client);
    let s_counters =
        server.lock().map_err(|_| "session server poisoned")?.counters();
    let session_cluster =
        take_cluster(server).ok_or("session server still shared after chaos leg")?;
    let (_, _, s_failover_shed) = session_cluster.failover_counters();
    let s_killed: usize =
        session_cluster.killed_metrics().iter().map(|m| m.completed).sum();
    session_cluster.shutdown();
    let session_exactly_once = s_killed as u64
        + s_report.completed as u64
        + s_failover_shed
        == s_report.accepted as u64
        && s_counters.accepted == s_report.accepted as u64;

    Ok(ChaosReport {
        preset: opts.preset.clone(),
        carbon_clean_g: cg,
        carbon_faulted_g: fg,
        carbon_overhead_pct,
        restarts: faulted.metrics.restarts,
        lost_work_hours: faulted.metrics.lost_work_hours,
        recovery_p50_slots: faulted.metrics.recovery_p50_slots,
        recovery_p99_slots: faulted.metrics.recovery_p99_slots,
        degraded_stale: faulted.metrics.degraded_stale,
        degraded_fallback: faulted.metrics.degraded_fallback,
        serve_submitted: report.submitted,
        serve_accepted: report.accepted,
        serve_completed: report.completed,
        killed_completed,
        failovers,
        rerouted,
        failover_shed,
        shed_during_failover_rate,
        drained_exactly_once,
        session_reconnects: s_stats.reconnects,
        session_retries: s_stats.retries,
        session_dedup_hits: s_counters.dedup_hits,
        session_link_events,
        session_exactly_once,
    })
}

impl ChaosReport {
    /// The `BENCH_chaos.json` document.
    pub fn to_json(&self, opts: &ChaosBenchOpts, wall_seconds: f64) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("region", Json::str(opts.cfg.region.clone())),
                    ("capacity", Json::num(opts.cfg.capacity as f64)),
                    ("horizon_hours", Json::num(opts.cfg.horizon_hours as f64)),
                    ("seed", Json::num(opts.cfg.seed as f64)),
                    ("preset", Json::str(self.preset.clone())),
                    ("policy", Json::str(opts.kind.key())),
                    ("serve_policy", Json::str(opts.serve_kind.key())),
                    ("serve_jobs", Json::num(opts.serve_jobs as f64)),
                    ("shards", Json::num(opts.shards.max(2) as f64)),
                ]),
            ),
            ("carbon_clean_g", Json::num(self.carbon_clean_g)),
            ("carbon_faulted_g", Json::num(self.carbon_faulted_g)),
            ("carbon_overhead_pct", Json::num(self.carbon_overhead_pct)),
            ("restarts", Json::num(self.restarts as f64)),
            ("lost_work_hours", Json::num(self.lost_work_hours)),
            ("recovery_p50_slots", Json::num(self.recovery_p50_slots)),
            ("recovery_p99_slots", Json::num(self.recovery_p99_slots)),
            ("degraded_stale", Json::num(self.degraded_stale as f64)),
            ("degraded_fallback", Json::num(self.degraded_fallback as f64)),
            (
                "serve",
                Json::obj(vec![
                    ("submitted", Json::num(self.serve_submitted as f64)),
                    ("accepted", Json::num(self.serve_accepted as f64)),
                    ("completed", Json::num(self.serve_completed as f64)),
                    ("killed_completed", Json::num(self.killed_completed as f64)),
                    ("failovers", Json::num(self.failovers as f64)),
                    ("rerouted", Json::num(self.rerouted as f64)),
                    ("failover_shed", Json::num(self.failover_shed as f64)),
                ]),
            ),
            ("shed_during_failover_rate", Json::num(self.shed_during_failover_rate)),
            ("drained_exactly_once", Json::Bool(self.drained_exactly_once)),
            (
                "session",
                Json::obj(vec![
                    ("reconnects", Json::num(self.session_reconnects as f64)),
                    ("retries", Json::num(self.session_retries as f64)),
                    ("dedup_hits", Json::num(self.session_dedup_hits as f64)),
                    ("link_events", Json::num(self.session_link_events as f64)),
                ]),
            ),
            ("session_exactly_once", Json::Bool(self.session_exactly_once)),
            ("wall_seconds", Json::num(wall_seconds)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_opts() -> ChaosBenchOpts {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 10;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        ChaosBenchOpts::new(cfg, ServiceConfig::default())
    }

    #[test]
    fn chaos_bench_light_fires_and_balances() {
        let opts = smoke_opts();
        let r = run_chaos_bench(&opts).unwrap();
        // The light preset's outage walks the ladder, and its shard kill
        // (kill_after ≤ 96 < 120 arrivals) fires mid-stream.
        assert!(r.degraded_stale + r.degraded_fallback > 0, "ladder never engaged");
        assert_eq!(r.failovers, 1, "shard kill did not fire");
        assert!(r.drained_exactly_once, "accepted submissions lost or duplicated");
        // The combined cell: link faults actually fired alongside the
        // shard kill, and the session still accounted exactly once.
        assert!(r.session_link_events > 0, "light link plan was empty");
        assert!(r.session_exactly_once, "session leg lost or duplicated submissions");
        assert!(r.carbon_clean_g > 0.0 && r.carbon_faulted_g > 0.0);
        // Determinism: a second run reproduces the document bitwise.
        let again = run_chaos_bench(&opts).unwrap();
        assert_eq!(
            r.to_json(&opts, 0.0).to_string(),
            again.to_json(&opts, 0.0).to_string()
        );
    }

    #[test]
    fn chaos_bench_none_preset_is_clean() {
        let mut opts = smoke_opts();
        opts.preset = "none".to_string();
        let r = run_chaos_bench(&opts).unwrap();
        assert_eq!(r.carbon_overhead_pct, 0.0);
        assert_eq!(r.restarts, 0);
        assert_eq!(r.failovers, 0);
        assert!(r.drained_exactly_once);
        assert_eq!(r.session_link_events, 0);
        assert_eq!(r.session_reconnects + r.session_retries + r.session_dedup_hits, 0);
        assert!(r.session_exactly_once);
        assert_eq!(r.carbon_clean_g.to_bits(), r.carbon_faulted_g.to_bits());
    }

    #[test]
    fn chaos_bench_rejects_unknown_preset() {
        let mut opts = smoke_opts();
        opts.preset = "ragnarok".to_string();
        assert!(run_chaos_bench(&opts).is_err());
    }

    #[test]
    fn chaos_json_has_headline_fields() {
        let opts = smoke_opts();
        let doc = run_chaos_bench(&opts).unwrap().to_json(&opts, 1.5);
        for field in [
            "carbon_overhead_pct",
            "recovery_p50_slots",
            "recovery_p99_slots",
            "shed_during_failover_rate",
            "drained_exactly_once",
            "session_exactly_once",
        ] {
            assert!(doc.get(field).is_some(), "missing headline field '{field}'");
        }
        assert_eq!(doc.get("schema").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            doc.get("config").and_then(|c| c.get("preset")).and_then(Json::as_str),
            Some("light")
        );
    }
}
