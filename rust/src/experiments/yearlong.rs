//! Year-long continuous-learning evaluation (paper §5: "we integrate the
//! online and offline scheduling policies … into a simulation environment,
//! denoted CarbonFlex-Simulator, which enables year-long evaluation";
//! §4.2: "older mappings from the knowledge base are aged out over a
//! rolling window").
//!
//! The driver walks the year week by week: before each evaluation week it
//! re-runs the learning phase over the trailing history window, ages the
//! knowledge base, and evaluates CarbonFlex against the carbon-agnostic
//! baseline and the per-week oracle. This exercises the paper's continuous
//! learning loop end to end, including seasonal drift in the carbon traces.
//!
//! Weeks are inherently sequential (each week's knowledge base feeds the
//! next), but within a week the three evaluation runs are independent and
//! execute in parallel on the sweep engine's thread pool.

use crate::carbon::forecast::Forecaster;
use crate::carbon::synth::{self, Region};
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::Simulator;
use crate::config::ExperimentConfig;
use crate::experiments::sweep::par_map;
use crate::learning::kb::{Case, KnowledgeBase};
use crate::learning::replay::{learn, LearnConfig};
use crate::sched::carbon_agnostic::CarbonAgnostic;
use crate::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
use crate::sched::oracle::Oracle;
use crate::sched::{Policy, PolicyKind};
use crate::util::stats;
use crate::workload::tracegen;

/// One evaluated week.
#[derive(Debug, Clone)]
pub struct WeekResult {
    pub week: usize,
    /// Mean CI of the week's trace (seasonality indicator).
    pub mean_ci: f64,
    pub savings_pct: f64,
    pub oracle_savings_pct: f64,
    pub kb_cases: usize,
    pub violations: usize,
}

/// Aggregate over the evaluated weeks.
#[derive(Debug)]
pub struct YearResult {
    pub weeks: Vec<WeekResult>,
}

impl YearResult {
    pub fn mean_savings(&self) -> f64 {
        stats::mean(&self.weeks.iter().map(|w| w.savings_pct).collect::<Vec<_>>())
    }
    pub fn mean_oracle_savings(&self) -> f64 {
        stats::mean(&self.weeks.iter().map(|w| w.oracle_savings_pct).collect::<Vec<_>>())
    }
    /// Worst week — continuous learning should keep this bounded.
    pub fn min_savings(&self) -> f64 {
        self.weeks.iter().map(|w| w.savings_pct).fold(f64::INFINITY, f64::min)
    }
}

/// Run the continuous-learning loop over `weeks` evaluation weeks.
///
/// `aging_window_hours` bounds the knowledge base's memory (paper: a
/// rolling window; we default to ~4 weeks). Weeks before the first full
/// history window are skipped.
pub fn run_yearlong(cfg: &ExperimentConfig, weeks: usize, aging_window_hours: usize) -> YearResult {
    let region = Region::parse(&cfg.region).expect("region");
    let total_hours = cfg.history_hours + weeks * 168 + 336;
    let year = synth::synthesize(region, total_hours.max(8760), cfg.seed);
    let energy = EnergyModel::for_hardware(cfg.hardware);

    let mut kb = KnowledgeBase::new();
    let mut results = Vec::new();

    for week in 0..weeks {
        let eval_start = cfg.history_hours + week * 168;
        let hist_start = eval_start - cfg.history_hours;

        // --- Learning phase on the trailing window, then age the KB ---
        let hist_trace = year.slice(hist_start, cfg.history_hours);
        let hist_jobs =
            tracegen::generate(cfg, cfg.history_hours, cfg.seed ^ (week as u64) << 8 ^ 0x1157);
        let fresh = learn(
            &hist_jobs,
            &hist_trace,
            &LearnConfig {
                max_capacity: cfg.capacity,
                num_queues: cfg.queues.len(),
                offsets: cfg.replay_offsets,
                energy: energy.clone(),
                threads: 0, // parallel per-offset replays, offset-major merge
            },
        );
        for c in fresh.cases() {
            // Stamp cases with absolute time so aging works across weeks.
            kb.push(Case { recorded_at: hist_start + c.recorded_at, ..c.clone() });
        }
        // Amortized sliding-window maintenance: tombstone aged cases and
        // keep the fresh tail brute-force-matched, rebuilding the index
        // only once churn crosses the CARBONFLEX_KB_CHURN fraction.
        kb.advance_window(eval_start, aging_window_hours);

        // --- Evaluation week: the three runs are independent given the
        // frozen knowledge base, so run them in parallel. ---
        let eval_trace = year.slice(eval_start, 168 + 168); // + drain week
        let eval_jobs = tracegen::generate(cfg, 168, cfg.seed ^ (week as u64) << 8 ^ 0xE7A1);
        let forecaster = Forecaster::perfect(eval_trace.clone());
        let sim = Simulator::new(cfg.capacity, energy.clone(), cfg.queues.len(), 168);

        let kinds = [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];
        let runs = par_map(kinds.len(), &kinds, |&kind, _| {
            let mut policy: Box<dyn Policy> = match kind {
                PolicyKind::CarbonFlex => Box::new(CarbonFlex::new(
                    // Memcpy snapshot of the lazily-maintained index — no
                    // per-run rebuild; tombstones stay filtered at match
                    // time.
                    kb.clone(),
                    CarbonFlexParams {
                        knn_k: cfg.knn_k,
                        violation_tolerance: cfg.violation_tolerance,
                        distance_bound: cfg.distance_bound,
                        ..Default::default()
                    },
                )),
                PolicyKind::Oracle => {
                    Box::new(Oracle::new(&eval_jobs, &eval_trace, cfg.capacity))
                }
                _ => Box::new(CarbonAgnostic),
            };
            sim.run(&eval_jobs, &forecaster, policy.as_mut())
        });
        let (baseline, flex_result, oracle_result) = (&runs[0], &runs[1], &runs[2]);

        let base = baseline.metrics.carbon_g;
        results.push(WeekResult {
            week,
            mean_ci: year.slice(eval_start, 168).mean(),
            savings_pct: (1.0 - flex_result.metrics.carbon_g / base) * 100.0,
            oracle_savings_pct: (1.0 - oracle_result.metrics.carbon_g / base) * 100.0,
            kb_cases: kb.live(),
            violations: flex_result.metrics.violations,
        });
    }
    YearResult { weeks: results }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 24;
        cfg.history_hours = 168;
        cfg.replay_offsets = 2;
        cfg
    }

    #[test]
    fn continuous_learning_sustains_savings() {
        let r = run_yearlong(&small_cfg(), 4, 24 * 28);
        assert_eq!(r.weeks.len(), 4);
        assert!(r.mean_savings() > 10.0, "mean savings {:.1}", r.mean_savings());
        assert!(r.mean_oracle_savings() >= r.mean_savings() - 2.0);
        // The KB never grows unbounded thanks to aging.
        let max_cases = r.weeks.iter().map(|w| w.kb_cases).max().unwrap();
        assert!(max_cases < 20_000, "kb grew to {max_cases}");
    }

    #[test]
    fn aging_bounds_kb_size() {
        // With a tiny aging window the KB stays ~one learning pass big.
        let r = run_yearlong(&small_cfg(), 3, 168);
        let sizes: Vec<usize> = r.weeks.iter().map(|w| w.kb_cases).collect();
        assert!(sizes[2] <= sizes[1] * 2, "sizes {sizes:?}");
    }
}
