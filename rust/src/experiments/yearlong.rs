//! Year-long continuous-learning evaluation (paper §5: "we integrate the
//! online and offline scheduling policies … into a simulation environment,
//! denoted CarbonFlex-Simulator, which enables year-long evaluation";
//! §4.2: "older mappings from the knowledge base are aged out over a
//! rolling window").
//!
//! Since PR 5, evaluation weeks are **first-class sweep cells** on the
//! sweep engine's `weeks` axis (see `experiments/sweep.rs`): the sequential
//! learning chain — learn on the trailing history, push into the carried
//! knowledge base, slide the rolling window with
//! `KnowledgeBase::advance_window` — runs once per grid point during sweep
//! preparation (`experiments/cells.rs::prepare_week_chain`), and each
//! week's policy runs execute in parallel against an immutable snapshot.
//! [`run_yearlong`] is the thin adapter that builds the week-axis spec,
//! routes it through [`SweepRunner`], and reshapes the rows into the
//! paper-style [`YearResult`]; the retired bespoke loop survives in-test as
//! a bitwise reference implementation.

use crate::config::ExperimentConfig;
use crate::experiments::sweep::{SweepRunner, SweepSpec};
use crate::sched::PolicyKind;
use crate::util::stats;

/// One evaluated week.
#[derive(Debug, Clone)]
pub struct WeekResult {
    pub week: usize,
    /// Mean CI of the week's trace (seasonality indicator).
    pub mean_ci: f64,
    pub savings_pct: f64,
    pub oracle_savings_pct: f64,
    pub kb_cases: usize,
    pub violations: usize,
}

/// Aggregate over the evaluated weeks.
#[derive(Debug)]
pub struct YearResult {
    pub weeks: Vec<WeekResult>,
}

impl YearResult {
    pub fn mean_savings(&self) -> f64 {
        stats::mean(&self.weeks.iter().map(|w| w.savings_pct).collect::<Vec<_>>())
    }
    pub fn mean_oracle_savings(&self) -> f64 {
        stats::mean(&self.weeks.iter().map(|w| w.oracle_savings_pct).collect::<Vec<_>>())
    }
    /// Worst week — continuous learning should keep this bounded.
    pub fn min_savings(&self) -> f64 {
        self.weeks.iter().map(|w| w.savings_pct).fold(f64::INFINITY, f64::min)
    }
}

/// The three policies every week cell evaluates: the savings baseline, the
/// learned runtime, and the per-week oracle upper bound.
const WEEK_POLICIES: [PolicyKind; 3] =
    [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];

/// Run the continuous-learning loop over `weeks` evaluation weeks — a thin
/// adapter over the sweep engine's `weeks` axis.
///
/// `aging_window_hours` bounds the knowledge base's memory (paper: a
/// rolling window; we default to ~4 weeks in the benches).
pub fn run_yearlong(cfg: &ExperimentConfig, weeks: usize, aging_window_hours: usize) -> YearResult {
    if weeks == 0 {
        return YearResult { weeks: Vec::new() };
    }
    let mut spec = SweepSpec::new(cfg.clone());
    spec.weeks = (0..weeks).collect();
    spec.aging_window_hours = aging_window_hours;
    spec.policies = WEEK_POLICIES.to_vec();
    let rows = SweepRunner::auto().run(&spec);

    // Rows come back in grid order: week-major, policy-minor (agnostic,
    // carbonflex, oracle per week).
    let mut results = Vec::with_capacity(weeks);
    for chunk in rows.chunks(WEEK_POLICIES.len()) {
        let (flex, oracle) = (&chunk[1], &chunk[2]);
        results.push(WeekResult {
            week: flex.point.week.expect("week cell"),
            mean_ci: flex.mean_ci.expect("week rows carry the eval-week mean CI"),
            savings_pct: flex.savings_pct,
            oracle_savings_pct: oracle.savings_pct,
            kb_cases: flex.kb_live.expect("week rows carry the live KB size"),
            violations: flex.result.metrics.violations,
        });
    }
    YearResult { weeks: results }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 24;
        cfg.history_hours = 168;
        cfg.replay_offsets = 2;
        cfg
    }

    /// The retired bespoke week loop, kept verbatim as the bitwise
    /// reference the sweep-routed path must reproduce (the PR 3
    /// sanitize/kd-search pattern).
    mod legacy_reference {
        use super::*;
        use crate::carbon::forecast::Forecaster;
        use crate::carbon::synth::{self, Region};
        use crate::cluster::energy::EnergyModel;
        use crate::cluster::sim::Simulator;
        use crate::experiments::sweep::par_map;
        use crate::learning::kb::{Case, KnowledgeBase};
        use crate::learning::replay::{learn, LearnConfig};
        use crate::sched::carbon_agnostic::CarbonAgnostic;
        use crate::sched::carbonflex::{CarbonFlex, CarbonFlexParams};
        use crate::sched::oracle::Oracle;
        use crate::sched::Policy;
        use crate::workload::tracegen;

        pub fn run_yearlong(
            cfg: &ExperimentConfig,
            weeks: usize,
            aging_window_hours: usize,
        ) -> YearResult {
            let region = Region::parse(&cfg.region).expect("region");
            let total_hours = cfg.history_hours + weeks * 168 + 336;
            let year = synth::synthesize(region, total_hours.max(8760), cfg.seed);
            let energy = EnergyModel::for_hardware(cfg.hardware);
            // The Fig. 13 fidelity fix applies here too: the learning
            // history is generated at the unshifted scale.
            let hist_cfg = cfg.unshifted_history();

            let mut kb = KnowledgeBase::new();
            let mut results = Vec::new();

            for week in 0..weeks {
                let eval_start = cfg.history_hours + week * 168;
                let hist_start = eval_start - cfg.history_hours;

                let hist_trace = year.slice(hist_start, cfg.history_hours);
                let hist_jobs = tracegen::generate(
                    &hist_cfg,
                    cfg.history_hours,
                    cfg.seed ^ (week as u64) << 8 ^ 0x1157,
                );
                let fresh = learn(
                    &hist_jobs,
                    &hist_trace,
                    &LearnConfig {
                        max_capacity: cfg.capacity,
                        num_queues: cfg.queues.len(),
                        offsets: cfg.replay_offsets,
                        energy: energy.clone(),
                        threads: 0,
                    },
                );
                for c in fresh.cases() {
                    kb.push(Case { recorded_at: hist_start + c.recorded_at, ..c.clone() });
                }
                kb.advance_window(eval_start, aging_window_hours);

                let eval_trace = year.slice(eval_start, 168 + 168);
                let eval_jobs =
                    tracegen::generate(cfg, 168, cfg.seed ^ (week as u64) << 8 ^ 0xE7A1);
                let forecaster = Forecaster::perfect(eval_trace.clone());
                let sim = Simulator::new(cfg.capacity, energy.clone(), cfg.queues.len(), 168);

                let kinds =
                    [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];
                let runs = par_map(kinds.len(), &kinds, |&kind, _| {
                    let mut policy: Box<dyn Policy> = match kind {
                        PolicyKind::CarbonFlex => Box::new(CarbonFlex::new(
                            kb.clone(),
                            CarbonFlexParams {
                                knn_k: cfg.knn_k,
                                violation_tolerance: cfg.violation_tolerance,
                                distance_bound: cfg.distance_bound,
                                ..Default::default()
                            },
                        )),
                        PolicyKind::Oracle => {
                            Box::new(Oracle::new(&eval_jobs, &eval_trace, cfg.capacity))
                        }
                        _ => Box::new(CarbonAgnostic),
                    };
                    sim.run(&eval_jobs, &forecaster, policy.as_mut())
                });
                let (baseline, flex_result, oracle_result) = (&runs[0], &runs[1], &runs[2]);

                let base = baseline.metrics.carbon_g;
                results.push(WeekResult {
                    week,
                    mean_ci: year.slice(eval_start, 168).mean(),
                    savings_pct: (1.0 - flex_result.metrics.carbon_g / base) * 100.0,
                    oracle_savings_pct: (1.0 - oracle_result.metrics.carbon_g / base) * 100.0,
                    kb_cases: kb.live(),
                    violations: flex_result.metrics.violations,
                });
            }
            YearResult { weeks: results }
        }
    }

    #[test]
    fn sweep_cells_are_bitwise_identical_to_legacy_loop() {
        // The tentpole equivalence: the week-axis sweep reproduces the
        // retired bespoke loop bit for bit, week by week.
        let cfg = small_cfg();
        let want = legacy_reference::run_yearlong(&cfg, 3, 24 * 28);
        let got = run_yearlong(&cfg, 3, 24 * 28);
        assert_eq!(got.weeks.len(), want.weeks.len());
        for (g, w) in got.weeks.iter().zip(&want.weeks) {
            assert_eq!(g.week, w.week);
            assert_eq!(g.mean_ci.to_bits(), w.mean_ci.to_bits(), "week {}", g.week);
            assert_eq!(
                g.savings_pct.to_bits(),
                w.savings_pct.to_bits(),
                "week {}: savings diverged ({} vs {})",
                g.week,
                g.savings_pct,
                w.savings_pct
            );
            assert_eq!(
                g.oracle_savings_pct.to_bits(),
                w.oracle_savings_pct.to_bits(),
                "week {}: oracle savings diverged",
                g.week
            );
            assert_eq!(g.kb_cases, w.kb_cases, "week {}", g.week);
            assert_eq!(g.violations, w.violations, "week {}", g.week);
        }
    }

    #[test]
    fn subset_week_sweep_matches_full_run() {
        // The cross-scenario invariant: sweeping only week 2 yields the
        // same cell as week 2 of a full run, because the learning chain
        // always walks from week 0.
        let cfg = small_cfg();
        let full = run_yearlong(&cfg, 3, 24 * 28);
        let mut spec = SweepSpec::new(cfg);
        spec.weeks = vec![2];
        spec.policies = vec![PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex];
        let rows = SweepRunner::auto().run(&spec);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].point.week, Some(2));
        assert_eq!(
            rows[1].savings_pct.to_bits(),
            full.weeks[2].savings_pct.to_bits(),
            "subset sweep diverged from the full chain ({} vs {})",
            rows[1].savings_pct,
            full.weeks[2].savings_pct
        );
        assert_eq!(rows[1].kb_live, Some(full.weeks[2].kb_cases));
    }

    #[test]
    fn continuous_learning_sustains_savings() {
        let r = run_yearlong(&small_cfg(), 4, 24 * 28);
        assert_eq!(r.weeks.len(), 4);
        assert!(r.mean_savings() > 10.0, "mean savings {:.1}", r.mean_savings());
        assert!(r.mean_oracle_savings() >= r.mean_savings() - 2.0);
        // The KB never grows unbounded thanks to aging.
        let max_cases = r.weeks.iter().map(|w| w.kb_cases).max().unwrap();
        assert!(max_cases < 20_000, "kb grew to {max_cases}");
    }

    #[test]
    fn aging_bounds_kb_size() {
        // With a tiny aging window the KB stays ~one learning pass big.
        let r = run_yearlong(&small_cfg(), 3, 168);
        let sizes: Vec<usize> = r.weeks.iter().map(|w| w.kb_cases).collect();
        assert!(sizes[2] <= sizes[1] * 2, "sizes {sizes:?}");
    }
}
