//! Machine-readable hot-path benchmarks (§Perf).
//!
//! The paths that gate end-to-end throughput — the offline oracle (Alg. 1)
//! over a full trace, the per-slot state match (single and batched), the
//! knowledge-base index build and amortized sliding-window maintenance, and
//! cluster-engine stepping — measured on one prepared experiment and
//! emitted as the `BENCH_hotpaths.json` document that tracks the repo's
//! perf trajectory.
//! Shared by the `carbonflex bench` CLI subcommand and the
//! `benches/perf_hotpaths` binary; CI runs the smoke config and uploads the
//! JSON as an artifact, failing if any cell regresses more than the allowed
//! ratio against a committed baseline.

use std::time::Duration;

use crate::carbon::forecast::Forecaster;
use crate::carbon::trace::CarbonTrace;
use crate::cluster::energy::EnergyModel;
use crate::cluster::sim::{ClusterEngine, Simulator};
use crate::config::ExperimentConfig;
use crate::experiments::runner::PreparedExperiment;
use crate::learning::kb::{Case, KnowledgeBase, Matcher};
use crate::learning::state::StateVector;
use crate::sched::carbon_agnostic::CarbonAgnostic;
use crate::sched::oracle::compute_schedule;
use crate::sched::PolicyKind;
use crate::workload::job::Job;
use crate::workload::profile::ScalingProfile;
use crate::util::bench::{bench_chunked, bench_for, BenchResult};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One measured hot-path cell.
pub struct BenchCell {
    pub name: String,
    pub result: BenchResult,
    /// Engine cells also report stepping throughput.
    pub slots_per_second: Option<f64>,
}

/// All hot-path cells for one config.
pub struct HotpathReport {
    pub cells: Vec<BenchCell>,
    pub config: ExperimentConfig,
}

/// Engine cells measured per policy (agnostic = pure stepping floor,
/// CarbonFlex = stepping + state match, Oracle = stepping + Alg. 1 plan).
pub const ENGINE_POLICIES: [PolicyKind; 3] =
    [PolicyKind::CarbonAgnostic, PolicyKind::CarbonFlex, PolicyKind::Oracle];

/// Slug used in cell names (`engine/carbon-agnostic`, ...).
fn policy_slug(kind: PolicyKind) -> String {
    kind.as_str()
        .to_ascii_lowercase()
        .replace([' ', '(', ')'], "-")
        .replace("--", "-")
        .trim_matches('-')
        .to_string()
}

/// Measure the hot paths on `cfg`, spending roughly `budget` wall time
/// per cell.
pub fn bench_hotpaths(cfg: &ExperimentConfig, budget: Duration) -> HotpathReport {
    let prep = PreparedExperiment::prepare(cfg);
    let mut cells: Vec<BenchCell> = Vec::new();

    // L3 oracle (Alg. 1) over the evaluation trace — the learning-phase
    // inner loop (paper §6.8: 2–10 minutes in the Python prototype).
    let jobs = prep.eval_jobs.clone();
    let trace = prep.eval_trace.clone();
    let capacity = cfg.capacity;
    let r = bench_for("oracle/week-trace", budget, || {
        std::hint::black_box(compute_schedule(&jobs, &trace, capacity, 24.0, 8));
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // State match (k = 5) on the learned knowledge base (paper §6.8:
    // 1–2 ms with scikit-learn).
    let mut kb = KnowledgeBase::from_cases(prep.knowledge_base().cases().to_vec());
    let mut rng = Rng::new(1);
    let queries: Vec<StateVector> = (0..256)
        .map(|_| {
            StateVector::from_raw(
                rng.range(10.0, 700.0),
                rng.range(-80.0, 80.0),
                rng.f64(),
                &[rng.below(40), rng.below(40), rng.below(40)],
                rng.f64(),
            )
        })
        .collect();
    let mut qi = 0usize;
    let mut hits = Vec::new();
    let r = bench_for("match/native-kdtree", budget.min(Duration::from_secs(2)), || {
        qi = (qi + 1) % queries.len();
        kb.top_k_into(&queries[qi], 5, &mut hits);
        std::hint::black_box(hits.len());
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // Batched state match: the same 256 queries in a single
    // `top_k_batch_into` call — one scratch set and one output reservation
    // amortized across the batch.
    let mut batch_out = Vec::new();
    let mut batch_offsets = Vec::new();
    let r = bench_for("state_match_batch", budget.min(Duration::from_secs(2)), || {
        kb.top_k_batch_into(&queries, 5, &mut batch_out, &mut batch_offsets);
        std::hint::black_box(batch_out.len());
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // KB index construction: scaler fit + O(n log n) flat KD-tree layout.
    // (Includes one O(n) case-vector copy per iteration — negligible next
    // to the median-selection build it feeds.)
    let base_cases = prep.knowledge_base().cases().to_vec();
    let r = bench_for("kb_build", budget.min(Duration::from_secs(2)), || {
        let built = KnowledgeBase::from_cases(base_cases.clone());
        std::hint::black_box(built.len());
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // Amortized sliding-window maintenance: each tick pushes a few fresh
    // cases and advances the rolling window by an hour; `advance_window`
    // tombstones aged cases and defers the reclaim + rebuild until churn
    // crosses CARBONFLEX_KB_CHURN, so the chunked mean is what a
    // yearlong-style continuous run actually pays per slot.
    let window = cfg.history_hours.max(48);
    let mut now = window;
    let mut slide_kb = KnowledgeBase::from_cases(base_cases.clone());
    let mut slide_rng = Rng::new(7);
    let r = bench_chunked("kb_rebuild_amortized", budget.min(Duration::from_secs(2)), 64, || {
        now += 1;
        for _ in 0..4 {
            slide_kb.push(Case {
                recorded_at: now,
                state: StateVector::from_raw(
                    slide_rng.range(10.0, 700.0),
                    slide_rng.range(-80.0, 80.0),
                    slide_rng.f64(),
                    &[slide_rng.below(40), slide_rng.below(40), slide_rng.below(40)],
                    slide_rng.f64(),
                ),
                capacity: slide_rng.below(cfg.capacity.max(1)),
                rho: slide_rng.f64(),
            });
        }
        slide_kb.advance_window(now, window);
        std::hint::black_box(slide_kb.live());
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // Columnar engine stepping under full, stable occupancy: 32
    // never-finishing jobs at base scale for 256 slots per iteration.
    // Isolates exactly the SoA step loop — view/column fill, columnar
    // Table 2 feature extraction, sanitize, and the per-column advance —
    // with completion bookkeeping and policy search excluded.
    const STEP_SLOTS: usize = 256;
    let step_forecaster = Forecaster::perfect(CarbonTrace::new("flat", vec![150.0; STEP_SLOTS]));
    let step_jobs: Vec<Job> = (0..32)
        .map(|i| Job {
            id: i,
            workload: "bench",
            workload_idx: 0,
            arrival: 0,
            length_hours: 1e6, // never completes inside the window
            queue: i % 3,
            slack_hours: 1e9,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.05, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        })
        .collect();
    let hardware = cfg.hardware;
    let r = bench_for("engine_step_soa", budget.min(Duration::from_secs(2)), || {
        let sim = Simulator::new(64, EnergyModel::for_hardware(hardware), 3, STEP_SLOTS);
        let mut engine = ClusterEngine::new(sim);
        for j in &step_jobs {
            engine.add_job(j.clone());
        }
        engine.reserve(STEP_SLOTS);
        let mut policy = CarbonAgnostic;
        for t in 0..STEP_SLOTS {
            engine.step(t, &step_forecaster, &mut policy);
        }
        std::hint::black_box(engine.num_slots());
    });
    let sps = STEP_SLOTS as f64 / r.mean.as_secs_f64().max(1e-12);
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: Some(sps) });

    // Memoized-prepare rebind: what a hash-equal sweep cell pays instead of
    // full trace synthesis + replay learning (the KB above is already
    // learned, so the rebind carries it — the steady-state sweep path).
    let mut rebind_cfg = cfg.clone();
    rebind_cfg.knn_k = cfg.knn_k + 2;
    let r = bench_for("sweep_prepare_memoized", budget.min(Duration::from_secs(2)), || {
        std::hint::black_box(prep.rebind(&rebind_cfg).eval_jobs.len());
    });
    cells.push(BenchCell { name: r.name.clone(), result: r, slots_per_second: None });

    // Cluster-engine stepping throughput, end to end per policy.
    for kind in ENGINE_POLICIES {
        let slots = prep.run(kind).slots.len();
        let name = format!("engine/{}", policy_slug(kind));
        let r = bench_for(&name, budget, || {
            std::hint::black_box(prep.run(kind));
        });
        let sps = slots as f64 / r.mean.as_secs_f64().max(1e-12);
        cells.push(BenchCell { name, result: r, slots_per_second: Some(sps) });
    }

    HotpathReport { cells, config: cfg.clone() }
}

impl HotpathReport {
    /// The `BENCH_hotpaths.json` document.
    pub fn to_json(&self, wall_seconds: f64) -> Json {
        let cells = Json::Obj(
            self.cells
                .iter()
                .map(|c| {
                    let mut obj = match c.result.to_json() {
                        Json::Obj(m) => m,
                        _ => unreachable!("BenchResult::to_json returns an object"),
                    };
                    if let Some(sps) = c.slots_per_second {
                        obj.insert("slots_per_second".to_string(), Json::Num(sps));
                    }
                    (c.name.clone(), Json::Obj(obj))
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("region", Json::Str(self.config.region.clone())),
            ("capacity", Json::Num(self.config.capacity as f64)),
            ("horizon_hours", Json::Num(self.config.horizon_hours as f64)),
            ("history_hours", Json::Num(self.config.history_hours as f64)),
            ("seed", Json::Num(self.config.seed as f64)),
            ("wall_seconds", Json::Num(wall_seconds)),
            ("cells", cells),
        ])
    }
}

/// Config fields that identify what a bench document measured. A baseline
/// recorded on a different config (e.g. the full default config vs CI's
/// smoke config) makes the ratio guard silently inert or falsely red, so a
/// mismatch on any of these is itself a violation.
const CONFIG_KEYS: [&str; 5] = ["region", "capacity", "horizon_hours", "history_hours", "seed"];

/// Compare a current bench document against a committed baseline: any cell
/// whose `mean_seconds` exceeds `max_ratio ×` the baseline's is a violation
/// (a coarse guard against order-of-magnitude regressions, deliberately not
/// a flaky micro-gate). The two documents must describe the same config
/// ([`CONFIG_KEYS`]). Cells present on only one side are reported but
/// tolerated when new (baseline without them predates the cell).
pub fn regression_check(current: &Json, baseline: &Json, max_ratio: f64) -> Vec<String> {
    let mut violations = Vec::new();
    let fmt = |j: Option<&Json>| j.map_or("<absent>".to_string(), |v| v.to_string());
    for key in CONFIG_KEYS {
        let (b, c) = (baseline.get(key), current.get(key));
        if b != c {
            violations.push(format!(
                "config mismatch on '{key}': baseline {} vs current {} — record the baseline \
                 with the same config the check runs on",
                fmt(b),
                fmt(c)
            ));
        }
    }
    if !violations.is_empty() {
        return violations;
    }
    let (Some(cur), Some(base)) = (
        current.get("cells").and_then(Json::as_obj),
        baseline.get("cells").and_then(Json::as_obj),
    ) else {
        return vec!["baseline or current document is missing the 'cells' object".to_string()];
    };
    for (name, bcell) in base {
        let Some(ccell) = cur.get(name) else {
            violations.push(format!("cell '{name}' present in baseline but not measured"));
            continue;
        };
        let (Some(b), Some(c)) = (
            bcell.get("mean_seconds").and_then(Json::as_f64),
            ccell.get("mean_seconds").and_then(Json::as_f64),
        ) else {
            continue;
        };
        if b > 0.0 && c > b * max_ratio {
            violations.push(format!(
                "{name}: {c:.6}s vs baseline {b:.6}s (> {max_ratio:.1}x allowed)"
            ));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn doc(cells: &[(&str, f64)]) -> Json {
        let body: Vec<String> = cells
            .iter()
            .map(|(n, m)| format!("\"{n}\": {{\"mean_seconds\": {m}, \"iters\": 3}}"))
            .collect();
        parse(&format!("{{\"schema\": 1, \"cells\": {{{}}}}}", body.join(","))).unwrap()
    }

    #[test]
    fn regression_check_flags_slowdowns_only() {
        let base = doc(&[("oracle/week-trace", 0.010), ("match/native-kdtree", 0.000_02)]);
        let same = doc(&[("oracle/week-trace", 0.011), ("match/native-kdtree", 0.000_02)]);
        assert!(regression_check(&same, &base, 3.0).is_empty());
        let slow = doc(&[("oracle/week-trace", 0.050), ("match/native-kdtree", 0.000_02)]);
        let v = regression_check(&slow, &base, 3.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("oracle/week-trace"));
    }

    #[test]
    fn regression_check_rejects_config_mismatch() {
        // Identical cells, different measured config: the guard must refuse
        // to compare rather than silently gate nothing.
        let base = parse(
            "{\"schema\": 1, \"region\": \"ontario\", \"capacity\": 150, \
             \"cells\": {\"oracle/week-trace\": {\"mean_seconds\": 0.01}}}",
        )
        .unwrap();
        let cur = parse(
            "{\"schema\": 1, \"region\": \"ontario\", \"capacity\": 12, \
             \"cells\": {\"oracle/week-trace\": {\"mean_seconds\": 0.01}}}",
        )
        .unwrap();
        let v = regression_check(&cur, &base, 3.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("config mismatch on 'capacity'"), "{}", v[0]);
        // Same config (even if fields are absent on both sides) → clean.
        assert!(regression_check(&base, &base, 3.0).is_empty());
    }

    #[test]
    fn regression_check_reports_missing_cells() {
        let base = doc(&[("oracle/week-trace", 0.010)]);
        let cur = doc(&[("match/native-kdtree", 0.000_02)]);
        let v = regression_check(&cur, &base, 3.0);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("not measured"));
    }

    #[test]
    fn regression_check_tolerates_baseline_missing_new_sections() {
        // A baseline recorded before kb_build / kb_rebuild_amortized /
        // state_match_batch existed must keep gating the old cells without
        // flagging the new ones.
        let base = doc(&[("oracle/week-trace", 0.010), ("match/native-kdtree", 0.000_02)]);
        let cur = doc(&[
            ("oracle/week-trace", 0.011),
            ("match/native-kdtree", 0.000_02),
            ("state_match_batch", 0.002),
            ("kb_build", 0.004),
            ("kb_rebuild_amortized", 0.000_5),
        ]);
        assert!(regression_check(&cur, &base, 3.0).is_empty());
        // ... and still catches a regression in an old cell.
        let slow = doc(&[
            ("oracle/week-trace", 0.050),
            ("match/native-kdtree", 0.000_02),
            ("kb_build", 0.004),
        ]);
        let v = regression_check(&slow, &base, 3.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("oracle/week-trace"));
    }

    #[test]
    fn hotpath_report_includes_new_cells() {
        // Tiny config + tiny budget: verifies the report shape end to end
        // (the CI bench-smoke job additionally asserts these names in the
        // uploaded JSON artifact).
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 10;
        cfg.horizon_hours = 48;
        cfg.history_hours = 72;
        cfg.replay_offsets = 1;
        let report = bench_hotpaths(&cfg, Duration::from_millis(1));
        let names: Vec<&str> = report.cells.iter().map(|c| c.name.as_str()).collect();
        for want in [
            "oracle/week-trace",
            "match/native-kdtree",
            "state_match_batch",
            "kb_build",
            "kb_rebuild_amortized",
            "engine_step_soa",
            "sweep_prepare_memoized",
            "engine/carbonflex",
        ] {
            assert!(names.contains(&want), "missing cell '{want}' in {names:?}");
        }
        let json = report.to_json(0.0);
        for want in [
            "state_match_batch",
            "kb_build",
            "kb_rebuild_amortized",
            "engine_step_soa",
            "sweep_prepare_memoized",
        ] {
            assert!(
                json.get("cells").and_then(|c| c.get(want)).is_some(),
                "cell '{want}' missing from the JSON document"
            );
        }
    }

    #[test]
    fn regression_check_rejects_malformed_docs() {
        let ok = doc(&[("a", 1.0)]);
        let bad = parse("{\"schema\": 1}").unwrap();
        assert_eq!(regression_check(&ok, &bad, 3.0).len(), 1);
    }

    #[test]
    fn policy_slugs_are_filesystem_safe() {
        for kind in ENGINE_POLICIES {
            let slug = policy_slug(kind);
            assert!(slug.chars().all(|c| c.is_ascii_alphanumeric() || c == '-'), "{slug}");
        }
    }
}
