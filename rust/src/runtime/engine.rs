//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from Rust.
//!
//! The compile path (`python/compile/aot.py`) lowers the JAX/Pallas graphs to
//! **HLO text** — not serialized protos, which jax ≥ 0.5 emits with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects. The text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
//!
//! Python never runs at runtime: after `make artifacts` the Rust binary is
//! self-contained.
//!
//! The real backend needs the external `xla` crate, which the offline build
//! cannot fetch, so it is gated behind the `pjrt` cargo feature. Without the
//! feature a stub backend with the same API compiles instead: `Engine::cpu`
//! fails cleanly and every caller falls back to the native KD-tree path
//! (exactly as they already do when the AOT artifacts are absent).

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    MissingArtifact(PathBuf),
    Metadata(String),
    Io(std::io::Error),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::MissingArtifact(p) => {
                write!(f, "artifact missing: {} (run `make artifacts`)", p.display())
            }
            RuntimeError::Metadata(msg) => write!(f, "artifact metadata: {msg}"),
            RuntimeError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Shapes of the AOT-compiled kernels, read from `artifacts/meta.json`
/// (written by `aot.py`; Rust pads its inputs to these static shapes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Knowledge-base rows the match kernel was compiled for.
    pub match_cases: usize,
    /// State-vector features (must equal `learning::STATE_DIM`).
    pub match_features: usize,
    /// Top-k width of the match kernel.
    pub match_k: usize,
    /// (jobs × scales) rows of the score kernel.
    pub score_jk: usize,
    /// Time slots of the score kernel.
    pub score_t: usize,
}

impl ArtifactMeta {
    /// Parse `meta.json`.
    pub fn load(dir: &Path) -> Result<ArtifactMeta, RuntimeError> {
        let path = dir.join("meta.json");
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        let src = std::fs::read_to_string(&path)?;
        let v = json::parse(&src).map_err(|e| RuntimeError::Metadata(e.to_string()))?;
        let get = |obj: &Json, key: &str| -> Result<usize, RuntimeError> {
            obj.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| RuntimeError::Metadata(format!("missing field {key}")))
        };
        let m = v.get("match").ok_or_else(|| RuntimeError::Metadata("missing 'match'".into()))?;
        let s = v.get("score").ok_or_else(|| RuntimeError::Metadata("missing 'score'".into()))?;
        Ok(ArtifactMeta {
            match_cases: get(m, "cases")?,
            match_features: get(m, "features")?,
            match_k: get(m, "k")?,
            score_jk: get(s, "jk")?,
            score_t: get(s, "t")?,
        })
    }
}

/// Default artifacts directory: `$CARBONFLEX_ARTIFACTS` or `artifacts/`.
fn artifacts_dir_from_env() -> PathBuf {
    std::env::var("CARBONFLEX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::{ArtifactMeta, RuntimeError};
    use std::path::PathBuf;

    /// A PJRT CPU client plus compiled executables.
    pub struct Engine {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
        meta: ArtifactMeta,
    }

    /// One compiled HLO computation.
    pub struct Computation {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Engine {
        /// Default artifacts directory: `$CARBONFLEX_ARTIFACTS` or `artifacts/`.
        pub fn default_artifacts_dir() -> PathBuf {
            super::artifacts_dir_from_env()
        }

        /// Create a CPU PJRT client over an artifacts directory.
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Engine, RuntimeError> {
            let artifacts_dir = artifacts_dir.into();
            let meta = ArtifactMeta::load(&artifacts_dir)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Engine { client, artifacts_dir, meta })
        }

        pub fn meta(&self) -> ArtifactMeta {
            self.meta
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact by file name (e.g. "match.hlo.txt").
        pub fn load(&self, name: &str) -> Result<Computation, RuntimeError> {
            let path = self.artifacts_dir.join(name);
            if !path.exists() {
                return Err(RuntimeError::MissingArtifact(path));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("artifact path must be valid utf-8"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Computation { exe })
        }
    }

    impl Computation {
        /// Execute with f32 inputs, returning the tuple elements as flat f32
        /// vectors. Each input is (data, dims).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>, RuntimeError> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let expected: i64 = dims.iter().product();
                    assert_eq!(expected as usize, data.len(), "input size/shape mismatch");
                    xla::Literal::vec1(data).reshape(dims)
                })
                .collect::<Result<_, _>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → always a tuple.
            let elems = result.to_tuple()?;
            elems
                .into_iter()
                .map(|l| {
                    // Outputs may be f32 already; convert defensively (top_k
                    // indices come back as s32).
                    let l = l.convert(xla::PrimitiveType::F32)?;
                    Ok(l.to_vec::<f32>()?)
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{ArtifactMeta, RuntimeError};
    use std::path::PathBuf;

    /// Stub engine compiled when the `pjrt` feature is off. Constructing one
    /// always fails, so downstream code (PJRT matcher, score kernel, perf
    /// benches, the e2e example) takes its existing "artifacts unavailable"
    /// fallback path.
    pub struct Engine {
        meta: ArtifactMeta,
    }

    /// Uninhabited: without a real backend no computation can exist.
    pub struct Computation {
        never: std::convert::Infallible,
    }

    impl Engine {
        /// Default artifacts directory: `$CARBONFLEX_ARTIFACTS` or `artifacts/`.
        pub fn default_artifacts_dir() -> PathBuf {
            super::artifacts_dir_from_env()
        }

        /// Always fails: the crate was built without the `pjrt` feature.
        /// Metadata is still validated first so error messages distinguish
        /// "no artifacts" from "no backend".
        pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Engine, RuntimeError> {
            let dir: PathBuf = artifacts_dir.into();
            let _meta = ArtifactMeta::load(&dir)?;
            Err(RuntimeError::Xla(
                "carbonflex was built without the `pjrt` feature; \
                 rebuild with `--features pjrt` and an `xla` dependency"
                    .into(),
            ))
        }

        pub fn meta(&self) -> ArtifactMeta {
            self.meta
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn load(&self, _name: &str) -> Result<Computation, RuntimeError> {
            Err(RuntimeError::Xla("pjrt feature disabled".into()))
        }
    }

    impl Computation {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>, RuntimeError> {
            match self.never {}
        }
    }
}

pub use backend::{Computation, Engine};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join("carbonflex_engine_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"match": {"cases": 4096, "features": 8, "k": 5}, "score": {"jk": 1024, "t": 168}}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.match_cases, 4096);
        assert_eq!(m.match_features, 8);
        assert_eq!(m.match_k, 5);
        assert_eq!(m.score_jk, 1024);
        assert_eq!(m.score_t, 168);
    }

    #[test]
    fn missing_artifacts_is_a_clean_error() {
        let dir = std::env::temp_dir().join("carbonflex_engine_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        match ArtifactMeta::load(&dir) {
            Err(RuntimeError::MissingArtifact(p)) => assert!(p.ends_with("meta.json")),
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }

    #[test]
    fn malformed_meta_rejected() {
        let dir = std::env::temp_dir().join("carbonflex_engine_badmeta");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), r#"{"match": {}}"#).unwrap();
        assert!(matches!(ArtifactMeta::load(&dir), Err(RuntimeError::Metadata(_))));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_fails_cleanly_with_valid_artifacts() {
        let dir = std::env::temp_dir().join("carbonflex_engine_stub");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"match": {"cases": 16, "features": 8, "k": 5}, "score": {"jk": 64, "t": 24}}"#,
        )
        .unwrap();
        match Engine::cpu(&dir) {
            Err(RuntimeError::Xla(msg)) => assert!(msg.contains("pjrt"), "{msg}"),
            other => panic!("expected Xla error, got {:?}", other.err()),
        }
    }
}
