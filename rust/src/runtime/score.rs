//! PJRT-backed oracle score kernel: computes the Algorithm 1 score tensor
//! `score[j·K + k, t] = p_j(k) / CI_t` (masked outside each job's window)
//! with the AOT-compiled Pallas kernel — the `O(N·K·T)` inner loop of the
//! learning phase, offloaded.

use crate::runtime::engine::{Computation, Engine, RuntimeError};

/// Wrapper over the `score.hlo.txt` artifact.
pub struct ScoreKernel {
    comp: Computation,
    jk: usize,
    t: usize,
}

impl ScoreKernel {
    pub fn load(engine: &Engine) -> Result<ScoreKernel, RuntimeError> {
        let meta = engine.meta();
        Ok(ScoreKernel { comp: engine.load("score.hlo.txt")?, jk: meta.score_jk, t: meta.score_t })
    }

    /// Compiled (rows, slots) shape.
    pub fn shape(&self) -> (usize, usize) {
        (self.jk, self.t)
    }

    /// Compute the score matrix.
    ///
    /// - `marginals[r]`: marginal throughput of row r (a (job, k) pair).
    /// - `ci[t]`: carbon intensity per slot.
    /// - `window[r*T + t]`: 1.0 when slot t is inside row r's job window.
    ///
    /// Rows beyond the compiled shape must be pre-padded by the caller
    /// (marginal 0 ⇒ score 0, never chosen). Returns row-major `[jk × t]`.
    pub fn run(
        &self,
        marginals: &[f32],
        ci: &[f32],
        window: &[f32],
    ) -> Result<Vec<f32>, RuntimeError> {
        assert_eq!(marginals.len(), self.jk, "marginals must be padded to {}", self.jk);
        assert_eq!(ci.len(), self.t, "ci must be padded to {}", self.t);
        assert_eq!(window.len(), self.jk * self.t);
        let outputs = self.comp.run_f32(&[
            (marginals, &[self.jk as i64]),
            (ci, &[self.t as i64]),
            (window, &[self.jk as i64, self.t as i64]),
        ])?;
        Ok(outputs.into_iter().next().expect("score kernel returns one output"))
    }
}

/// Pure-Rust reference of the same computation (used by benches to compare
/// the native loop against the PJRT kernel, and by tests for equality).
pub fn score_native(marginals: &[f32], ci: &[f32], window: &[f32]) -> Vec<f32> {
    let (jk, t) = (marginals.len(), ci.len());
    assert_eq!(window.len(), jk * t);
    let mut out = vec![0.0f32; jk * t];
    for r in 0..jk {
        let m = marginals[r];
        for s in 0..t {
            let w = window[r * t + s];
            out[r * t + s] = w * m / ci[s].max(1e-9);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_score_masks_and_divides() {
        let m = [1.0f32, 0.5];
        let ci = [100.0f32, 50.0];
        let w = [1.0f32, 0.0, 1.0, 1.0];
        let s = score_native(&m, &ci, &w);
        assert!((s[0] - 0.01).abs() < 1e-7);
        assert_eq!(s[1], 0.0);
        assert!((s[2] - 0.005).abs() < 1e-7);
        assert!((s[3] - 0.01).abs() < 1e-7);
    }
}
