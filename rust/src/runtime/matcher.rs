//! PJRT-backed state matcher: the AOT-compiled Pallas distance kernel +
//! `lax.top_k` as a [`Matcher`] backend for the CarbonFlex policy.
//!
//! The knowledge base is uploaded once as padded f32 tensors
//! (`[C, F]` states, `[C]` capacities, `[C]` thresholds); each slot the
//! query state `[1, F]` is matched in a single PJRT execution. Padding rows
//! sit at coordinate `PAD_COORD` so their distance is astronomically large
//! and they never enter the top-k of a real query.

use crate::learning::kb::{KnowledgeBase, Matcher, Neighbor};
use crate::learning::state::{StateVector, STATE_DIM};
use crate::runtime::engine::{Computation, Engine, RuntimeError};

/// Coordinate value for padding rows (distance² ≥ (1e3)²·F ≫ any real dist).
const PAD_COORD: f32 = 1e3;

/// Threshold recorded for padding rows: above 1 ⇒ "schedule nothing".
const PAD_RHO: f32 = 1.01;

/// [`Matcher`] that executes the match artifact via PJRT.
pub struct PjrtMatcher {
    comp: Computation,
    /// Padded KB tensors (host copies, uploaded per call).
    states: Vec<f32>,
    caps: Vec<f32>,
    rhos: Vec<f32>,
    pressures: Vec<f32>,
    scaler: crate::learning::kb::Scaler,
    cases: usize,
    valid: usize,
    k: usize,
}

impl PjrtMatcher {
    /// Build from a knowledge base. If the KB exceeds the compiled case
    /// count, the most recent cases win (consistent with aging). The KB
    /// should be compacted (`rebuild`) first: a lazily-maintained KB may
    /// still carry tombstoned cases, which this upload cannot filter.
    pub fn from_kb(engine: &Engine, kb: &KnowledgeBase) -> Result<PjrtMatcher, RuntimeError> {
        let meta = engine.meta();
        assert_eq!(
            meta.match_features, STATE_DIM,
            "artifact feature dim {} != STATE_DIM {}",
            meta.match_features, STATE_DIM
        );
        let comp = engine.load("match.hlo.txt")?;
        let c = meta.match_cases;
        let scaler = kb.scaler();
        let mut states = vec![PAD_COORD; c * STATE_DIM];
        let mut caps = vec![0.0f32; c];
        let mut rhos = vec![PAD_RHO; c];
        let mut pressures = vec![0.0f32; c];
        let all = kb.cases();
        let take = all.len().min(c);
        let skip = all.len() - take; // drop oldest overflow
        for (row, case) in all[skip..].iter().enumerate() {
            // Upload in the KB's z-space so both backends match identically.
            let z = scaler.apply(&case.state);
            for (f, &v) in z.as_array().iter().enumerate() {
                states[row * STATE_DIM + f] = v as f32;
            }
            caps[row] = case.capacity as f32;
            rhos[row] = case.rho as f32;
            pressures[row] = case.state.0[7] as f32;
        }
        Ok(PjrtMatcher {
            comp,
            states,
            caps,
            rhos,
            pressures,
            scaler,
            cases: c,
            valid: take,
            k: meta.match_k,
        })
    }

    /// Compiled top-k width.
    pub fn k(&self) -> usize {
        self.k
    }
}

impl Matcher for PjrtMatcher {
    // `top_k_into` / `top_k_batch_into` use the trait defaults: the match
    // artifact is compiled for a single `[1, F]` query, so a batch is k
    // sequential executions either way; the native KD-tree backend is the
    // one with a batch-native path.
    fn top_k(&self, query: &StateVector, k: usize) -> Vec<Neighbor> {
        let z = self.scaler.apply(query);
        let q: Vec<f32> = z.as_array().iter().map(|&v| v as f32).collect();
        let outputs = self
            .comp
            .run_f32(&[
                (&q, &[1, STATE_DIM as i64]),
                (&self.states, &[self.cases as i64, STATE_DIM as i64]),
                (&self.caps, &[self.cases as i64]),
                (&self.rhos, &[self.cases as i64]),
                (&self.pressures, &[self.cases as i64]),
            ])
            .expect("PJRT match execution failed");
        // Outputs: (top-k d², capacities, rhos, pressures), each [1, k].
        let (d2, caps, rhos, pressures) = (&outputs[0], &outputs[1], &outputs[2], &outputs[3]);
        let take = k.min(self.k).min(self.valid);
        (0..take)
            .map(|i| Neighbor {
                dist: (d2[i].max(0.0) as f64).sqrt(),
                capacity: caps[i].round() as usize,
                rho: rhos[i] as f64,
                pressure: pressures[i] as f64,
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.valid
    }
}

// No #[cfg(test)] unit tests here: exercising PJRT requires the AOT
// artifacts, which are built by `make artifacts`. The integration test
// `rust/tests/pjrt_matcher.rs` cross-checks this backend against the native
// KD-tree and is skipped with a notice when artifacts are absent.
