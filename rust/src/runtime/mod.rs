//! PJRT runtime layer: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path. See
//! DESIGN.md §2 for the three-layer architecture.

pub mod engine;
pub mod matcher;
pub mod score;

pub use engine::{ArtifactMeta, Computation, Engine, RuntimeError};
pub use matcher::PjrtMatcher;
pub use score::ScoreKernel;
