//! Wait Awhile baseline (paper §6.1, [78]): threshold-based suspend/resume.
//!
//! A job runs (at base scale) whenever the current carbon intensity is at or
//! below the 30th percentile of the next-24-hour forecast, and suspends
//! otherwise. To meet its SLO the job runs unconditionally once its
//! remaining slack is exhausted (the simulator also enforces this).
//! Contention resolves FCFS.

use crate::sched::{Decision, Policy, SlotCtx};

/// Threshold percentile of the day-ahead forecast (paper: 30th).
pub const THRESHOLD_PERCENTILE: f64 = 30.0;

/// Suspend/resume threshold policy.
#[derive(Debug, Default)]
pub struct WaitAwhile;

impl Policy for WaitAwhile {
    fn name(&self) -> &'static str {
        "Wait Awhile"
    }

    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let ci_now = ctx.forecaster.predict(ctx.t);
        let threshold = ctx.forecaster.day_ahead_percentile(ctx.t, THRESHOLD_PERCENTILE);
        let low_carbon = ci_now <= threshold;

        let mut alloc = Vec::new();
        let mut used = 0usize;
        // FCFS: overdue jobs first, then arrival order.
        let mut order: Vec<usize> = (0..ctx.jobs.len()).collect();
        order.sort_by_key(|&i| (!ctx.jobs[i].overdue, ctx.jobs[i].job.arrival, ctx.jobs[i].job.id));
        for i in order {
            let v = &ctx.jobs[i];
            // Run if the slot is clean, or the job can no longer afford to wait.
            let must_run = v.overdue || v.slack_left(ctx.t) < 1.0;
            if !(low_carbon || must_run) {
                continue;
            }
            let k = v.job.k_min;
            if used + k > ctx.max_capacity {
                continue;
            }
            used += k;
            alloc.push((v.job.id, k));
        }
        Decision { capacity: ctx.max_capacity, alloc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::cluster::energy::EnergyModel;
    use crate::cluster::sim::Simulator;
    use crate::config::Hardware;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.05, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn diurnal(hours: usize) -> CarbonTrace {
        // Clean slots at hours 0..7 of each day, dirty otherwise.
        let hourly: Vec<f64> =
            (0..hours).map(|t| if t % 24 < 7 { 50.0 } else { 300.0 }).collect();
        CarbonTrace::new("diurnal", hourly)
    }

    #[test]
    fn runs_only_in_clean_slots_until_forced() {
        let f = Forecaster::perfect(diurnal(96));
        let jobs = vec![job(0, 8, 3.0, 24.0)]; // arrives in a dirty period
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut WaitAwhile);
        assert_eq!(r.metrics.completed, 1);
        // All running slots must be clean (CI 50).
        for s in r.slots.iter().filter(|s| s.used > 0) {
            assert!(s.ci <= 50.0 + 1e-9, "ran in dirty slot t={} ci={}", s.t, s.ci);
        }
    }

    #[test]
    fn forced_run_when_slack_exhausted() {
        // Entirely dirty trace → job must still finish within slack.
        let f = Forecaster::perfect(CarbonTrace::new("dirty", vec![300.0; 96]));
        let jobs = vec![job(0, 0, 2.0, 4.0)];
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut WaitAwhile);
        assert_eq!(r.metrics.completed, 1);
        assert!(!r.outcomes[0].violated_slo(), "delay {}", r.outcomes[0].delay_hours());
    }

    #[test]
    fn saves_carbon_vs_agnostic_on_diurnal_trace() {
        let f = Forecaster::perfect(diurnal(240));
        let jobs: Vec<Job> = (0..6).map(|i| job(i, i * 3 + 8, 2.0, 24.0)).collect();
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 240);
        let wa = sim.run(&jobs, &f, &mut WaitAwhile);
        let ag = sim.run(&jobs, &f, &mut crate::sched::carbon_agnostic::CarbonAgnostic);
        assert!(
            wa.metrics.carbon_g < ag.metrics.carbon_g * 0.5,
            "WaitAwhile {} vs Agnostic {}",
            wa.metrics.carbon_g,
            ag.metrics.carbon_g
        );
    }
}
