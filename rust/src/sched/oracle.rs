//! CarbonFlex(Oracle) — Algorithm 1.
//!
//! The offline oracle greedily allocates *individual servers* in descending
//! order of marginal-throughput-per-unit-carbon `p_j(k)/CI_t`, subject to
//! each job's window `[a_j, a_j + l_j + d_j]` and the cluster capacity M.
//! For monotonically decreasing marginal-throughput profiles this greedy is
//! optimal (paper Thm 4.1, via Federgruen & Groenevelt's greedy for
//! concave resource allocation). Infeasible instances are repaired by
//! extending the deadline of unfinished jobs and re-running (paper §4.2).
//!
//! The oracle doubles as (a) the strongest baseline in every figure and
//! (b) the teacher whose `(STATE → m_t, ρ)` decisions the learning phase
//! records into the knowledge base.

use crate::carbon::trace::CarbonTrace;
use crate::sched::{Decision, Policy, SlotCtx};
use crate::workload::job::Job;

/// One planned slot allocation for a job.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobPlan {
    /// (slot, servers) pairs, sorted by slot.
    pub slots: Vec<(usize, usize)>,
}

impl JobPlan {
    pub fn allocation_at(&self, t: usize) -> usize {
        self.slots
            .binary_search_by_key(&t, |&(s, _)| s)
            .map(|i| self.slots[i].1)
            .unwrap_or(0)
    }
    pub fn last_slot(&self) -> Option<usize> {
        self.slots.last().map(|&(s, _)| s)
    }
}

/// A complete offline schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct OracleSchedule {
    pub plans: Vec<JobPlan>,
    /// Slots that needed deadline extension to become feasible.
    pub extended_jobs: Vec<usize>,
    /// Total planned work per job (base-hours; ≥ job length).
    pub planned_work: Vec<f64>,
    /// Used capacity per slot.
    pub capacity_curve: Vec<usize>,
}

/// Candidate-entry packing (Alg. 1 lines 2–5).
///
/// §Perf: each entry is a single u128 sort key —
///   [ !score_f32_bits : 32 | deadline : 24 | job : 32 | t : 24 | k : 16 ]
/// so the million-entry sort (line 6) runs on primitive keys instead of
/// a five-way comparator chain (≈3× faster end to end). Scores are
/// positive finite f32s, whose bit patterns are order-preserving;
/// complementing them turns the descending score order into an
/// ascending integer sort. The trailing fields encode the paper's
/// tie-breaks (earliest deadline, then stable (j, t, k) order).
#[inline]
fn pack_entry(score: f32, deadline: usize, job: usize, t: usize, k: usize) -> u128 {
    // Each mask below silently wraps an out-of-range field into a foreign
    // entry's bits; the asserts make that latent corruption loud in debug
    // builds instead (scores must also be non-negative finite, or the
    // complemented-bits ordering trick breaks down).
    debug_assert!(score.is_finite() && score >= 0.0, "score {score} not a non-negative finite");
    debug_assert!(deadline < 1 << 24, "deadline {deadline} overflows its 24-bit field");
    debug_assert!(job < 1 << 32, "job id {job} overflows its 32-bit field");
    debug_assert!(t < 1 << 24, "slot {t} overflows its 24-bit field");
    debug_assert!(k > 0 && k < 1 << 16, "allocation {k} outside its 16-bit field");
    let inv = !(score.to_bits()) as u128;
    (inv << 96)
        | ((deadline as u128 & 0xFF_FFFF) << 72)
        | ((job as u128 & 0xFFFF_FFFF) << 40)
        | ((t as u128 & 0xFF_FFFF) << 16)
        | (k as u128 & 0xFFFF)
}

/// Inverse of [`pack_entry`]'s payload fields: `(job, t, k)`. The single
/// pack/unpack pair (pinned by the boundary round-trip test) replaces the
/// decoders that used to be scattered inline across the greedy pass.
#[inline]
fn unpack_entry(e: u128) -> (usize, usize, usize) {
    (((e >> 40) & 0xFFFF_FFFF) as usize, ((e >> 16) & 0xFF_FFFF) as usize, (e & 0xFFFF) as usize)
}

/// Entries one job contributes for its current (possibly extended) window,
/// starting no earlier than `start` (its precedence-derived earliest slot).
fn job_entry_count(job: &Job, extra_slack: f64, start: usize) -> usize {
    let deadline = job.arrival + (job.length_hours + job.slack_hours + extra_slack).ceil() as usize;
    deadline.saturating_sub(start) * job.k_max
}

/// Append job `j`'s candidate entries (every (t, k) in its window). `start`
/// is the earliest usable slot — the job's arrival, raised by precedence
/// repair once its parents' planned completions are known.
fn push_job_entries(
    entries: &mut Vec<u128>,
    jobs: &[Job],
    ci: &CarbonTrace,
    j: usize,
    extra: f64,
    start: usize,
) {
    let job = &jobs[j];
    assert_eq!(job.k_min, 1, "oracle assumes unit base allocations");
    debug_assert!(start >= job.arrival, "start {start} precedes arrival of job {j}");
    // The job must COMPLETE by the end of slot deadline−1 (finishing at
    // `arrival + ceil(l+d)` hours after arrival), so the last usable
    // slot is deadline−1.
    let deadline = job.arrival + (job.length_hours + job.slack_hours + extra).ceil() as usize;
    for t in start..deadline {
        let c = ci.at(t).max(1e-9);
        for k in 1..=job.k_max {
            entries.push(pack_entry((job.marginal(k) / c) as f32, deadline, j, t, k));
        }
    }
}

/// Merge two ascending-sorted entry lists into `out` (cleared first).
fn merge_sorted(a: &[u128], b: &[u128], out: &mut Vec<u128>) {
    out.clear();
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

/// Compute Algorithm 1 over a full job trace and carbon trace.
///
/// `extension_step` hours are added to unfinished jobs' windows per repair
/// round (at most `max_rounds` rounds).
///
/// §Perf: the sorted candidate list is built and sorted once; each repair
/// round drops only the extended jobs' entries, regenerates them for the
/// widened windows, and merges the (much smaller) sorted batch back in —
/// O(N + M log M) per round instead of re-sorting all N entries. Membership
/// in the extended set is a dense bool mask, not a `Vec::contains` scan.
/// Output is bitwise-identical to a full rebuild: entry keys are unique, so
/// the merged list equals the re-sorted list
/// (`incremental_repair_matches_full_rebuild`).
pub fn compute_schedule(
    jobs: &[Job],
    ci: &CarbonTrace,
    max_capacity: usize,
    extension_step: f64,
    max_rounds: usize,
) -> OracleSchedule {
    let mut extra_slack = vec![0.0f64; jobs.len()];
    let mut extended: Vec<usize> = Vec::new();
    let mut extended_mask = vec![false; jobs.len()];
    // Precedence state: earliest usable slot per job (its arrival for flat
    // traces; raised by the repair rounds below once parents' planned
    // completions are known). `has_deps` gates every DAG branch, so a flat
    // trace takes the pre-DAG path and produces bitwise-identical output.
    let has_deps = jobs.iter().any(|j| !j.deps.is_empty());
    let mut earliest: Vec<usize> = jobs.iter().map(|j| j.arrival).collect();

    // Lines 2–6: the full candidate list, pre-sized exactly, sorted once
    // (a primitive ascending sort realizes score-desc + tie-breaks).
    let total: usize = jobs
        .iter()
        .enumerate()
        .map(|(j, job)| job_entry_count(job, extra_slack[j], earliest[j]))
        .sum();
    let mut entries: Vec<u128> = Vec::with_capacity(total);
    for j in 0..jobs.len() {
        push_job_entries(&mut entries, jobs, ci, j, extra_slack[j], earliest[j]);
    }
    entries.sort_unstable();

    let mut fresh: Vec<u128> = Vec::new();
    let mut merged: Vec<u128> = Vec::new();
    let mut touched = vec![false; jobs.len()];

    for round in 0..max_rounds.max(1) {
        let result = greedy_pass(jobs, &entries, max_capacity, &extra_slack);
        let unfinished: Vec<usize> = result
            .iter()
            .enumerate()
            .filter(|(j, (_, work))| *work < jobs[*j].length_hours - 1e-9)
            .map(|(j, _)| j)
            .collect();
        // Precedence repair: a child planned into any slot at or before its
        // last parent's final planned slot gets its earliest bound raised
        // and its candidate entries regenerated from that bound.
        let mut displaced: Vec<usize> = Vec::new();
        if has_deps {
            for j in 0..jobs.len() {
                let mut lb = earliest[j];
                for &p in &jobs[j].deps {
                    if let Some(last) = result[p].0.last_slot() {
                        lb = lb.max(last + 1);
                    }
                }
                if lb > earliest[j] && result[j].0.slots.first().map_or(false, |&(t, _)| t < lb) {
                    earliest[j] = lb;
                    displaced.push(j);
                }
            }
        }
        if (unfinished.is_empty() && displaced.is_empty()) || round + 1 == max_rounds {
            let mut result = result;
            if has_deps {
                clamp_precedence(jobs, &mut result);
            }
            // Assemble the schedule.
            let horizon = result
                .iter()
                .flat_map(|(p, _)| p.last_slot())
                .max()
                .map(|m| m + 1)
                .unwrap_or(0);
            let mut capacity_curve = vec![0usize; horizon];
            for (plan, _) in &result {
                for &(t, k) in &plan.slots {
                    capacity_curve[t] += k;
                }
            }
            return OracleSchedule {
                planned_work: result.iter().map(|(_, w)| *w).collect(),
                plans: result.into_iter().map(|(p, _)| p).collect(),
                extended_jobs: extended,
                capacity_curve,
            };
        }
        // Repair: extend the unfinished jobs' windows, raise the displaced
        // jobs' start bounds, and splice only the regenerated entries back
        // into the sorted list.
        for &j in unfinished.iter().chain(&displaced) {
            touched[j] = true;
        }
        for &j in &unfinished {
            extra_slack[j] += extension_step;
            if !extended_mask[j] {
                extended_mask[j] = true;
                extended.push(j);
            }
        }
        entries.retain(|&e| !touched[unpack_entry(e).0]);
        fresh.clear();
        let regen: usize = (0..jobs.len())
            .filter(|&j| touched[j])
            .map(|j| job_entry_count(&jobs[j], extra_slack[j], earliest[j]))
            .sum();
        fresh.reserve(regen);
        for j in 0..jobs.len() {
            if touched[j] {
                push_job_entries(&mut fresh, jobs, ci, j, extra_slack[j], earliest[j]);
            }
        }
        fresh.sort_unstable();
        merge_sorted(&entries, &fresh, &mut merged);
        std::mem::swap(&mut entries, &mut merged);
        for j in unfinished.iter().chain(&displaced) {
            touched[*j] = false;
        }
    }
    unreachable!("loop always returns on the final round");
}

/// Final precedence guarantee: whatever the repair rounds achieved, drop any
/// child slot at or before its last parent's final planned slot. Processed
/// in ascending id order (parents precede children), so each bound reads the
/// parent's post-clamp plan and the output is precedence-feasible
/// unconditionally — a round-capped repair can leave a child short of work,
/// exactly like a round-capped deadline extension, but never a child hour
/// scheduled before its last parent hour.
fn clamp_precedence(jobs: &[Job], result: &mut [(JobPlan, f64)]) {
    for j in 0..jobs.len() {
        if jobs[j].deps.is_empty() {
            continue;
        }
        let mut lb = 0usize;
        for &p in &jobs[j].deps {
            if let Some(last) = result[p].0.last_slot() {
                lb = lb.max(last + 1);
            }
        }
        let plan = &mut result[j].0;
        if plan.slots.first().map_or(false, |&(t, _)| t < lb) {
            plan.slots.retain(|&(t, _)| t >= lb);
            // Re-derive planned work from the surviving slots (Σ over a
            // slot's 1..=k marginals = the slot's throughput).
            let work: f64 = plan
                .slots
                .iter()
                .map(|&(_, k)| (1..=k).map(|i| jobs[j].marginal(i)).sum::<f64>())
                .sum();
            result[j].1 = work;
        }
    }
}

/// One greedy pass of Algorithm 1 (lines 7–12) over a pre-sorted candidate
/// list. Returns per-job (plan, planned work).
fn greedy_pass(
    jobs: &[Job],
    entries: &[u128],
    max_capacity: usize,
    extra_slack: &[f64],
) -> Vec<(JobPlan, f64)> {
    // Per-job allocations live in flat window-indexed vectors
    // (alloc[j][t − arrival]) — the dense layout is ~2× faster than hash
    // maps on the million-entry pop loop (§Perf).
    let t_max = entries.iter().map(|&e| unpack_entry(e).1).max().map(|m| m + 1).unwrap_or(0);
    let mut used = vec![0u32; t_max];
    let mut alloc: Vec<Vec<u16>> = jobs
        .iter()
        .enumerate()
        .map(|(j, job)| {
            let window = (job.length_hours + job.slack_hours + extra_slack[j]).ceil() as usize;
            vec![0u16; window]
        })
        .collect();
    let mut work = vec![0.0f64; jobs.len()];
    let cap = max_capacity as u32;

    for &e in entries {
        let (j, t, k) = unpack_entry(e);
        if work[j] >= jobs[j].length_hours {
            continue; // Line 10–11: job already fully planned
        }
        if used[t] >= cap {
            continue; // Line 9: capacity exhausted at t
        }
        // Server k is only valid on top of servers 1..k−1 at the same slot.
        let off = t - jobs[j].arrival;
        if alloc[j][off] != (k - 1) as u16 {
            continue;
        }
        alloc[j][off] = k as u16;
        used[t] += 1;
        work[j] += jobs[j].marginal(k);
    }

    // Assemble sorted plans.
    jobs.iter()
        .enumerate()
        .map(|(j, job)| {
            let slots: Vec<(usize, usize)> = alloc[j]
                .iter()
                .enumerate()
                .filter(|(_, &k)| k > 0)
                .map(|(off, &k)| (job.arrival + off, k as usize))
                .collect();
            (JobPlan { slots }, work[j])
        })
        .collect()
}

/// The oracle as a [`Policy`]: replays its precomputed plan, falling back to
/// base-scale run-to-completion if execution drifts from the plan (e.g.
/// checkpoint penalties).
pub struct Oracle {
    schedule: OracleSchedule,
}

impl Oracle {
    /// Build the oracle for a known trace. `ci` must be the ground-truth
    /// trace the simulator will charge against.
    pub fn new(jobs: &[Job], ci: &CarbonTrace, max_capacity: usize) -> Self {
        let schedule = compute_schedule(jobs, ci, max_capacity, 24.0, 8);
        Oracle { schedule }
    }

    pub fn schedule(&self) -> &OracleSchedule {
        &self.schedule
    }
}

impl Policy for Oracle {
    fn name(&self) -> &'static str {
        "CarbonFlex(Oracle)"
    }

    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        out.alloc.clear();
        let mut used = 0usize;
        for v in ctx.jobs {
            let planned = self.schedule.plans[v.job.id].allocation_at(ctx.t);
            let past_plan =
                self.schedule.plans[v.job.id].last_slot().map(|l| ctx.t > l).unwrap_or(true);
            let k = if planned > 0 {
                planned
            } else if past_plan && v.remaining > 0.0 {
                v.job.k_min // drift repair: finish at base scale
            } else {
                0
            };
            if k > 0 {
                out.alloc.push((v.job.id, k));
                used += k;
            }
        }
        out.capacity = used;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64, k_max: usize, r: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max,
            profile: ScalingProfile::from_comm_ratio(r, k_max),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn valley_trace(len: usize) -> CarbonTrace {
        // High carbon except a deep valley at slots 4..8.
        let hourly: Vec<f64> =
            (0..len).map(|t| if (4..8).contains(&t) { 50.0 } else { 400.0 }).collect();
        CarbonTrace::new("valley", hourly)
    }

    #[test]
    fn schedules_into_the_valley() {
        let jobs = vec![job(0, 0, 2.0, 10.0, 1, 0.0)];
        let s = compute_schedule(&jobs, &valley_trace(24), 10, 24.0, 4);
        let plan = &s.plans[0];
        assert_eq!(plan.slots.len(), 2);
        for &(t, k) in &plan.slots {
            assert!((4..8).contains(&t), "slot {t} outside valley");
            assert_eq!(k, 1);
        }
    }

    #[test]
    fn elastic_job_scales_in_valley() {
        // 4 base-hours of work but the valley is only 2 slots wide (4..6):
        // the oracle must burst with k > 1 inside the valley rather than
        // spill into dirty slots.
        let hourly: Vec<f64> =
            (0..32).map(|t| if (4..6).contains(&t) { 50.0 } else { 400.0 }).collect();
        let trace = CarbonTrace::new("narrow-valley", hourly);
        let jobs = vec![job(0, 0, 4.0, 20.0, 4, 0.01)];
        let s = compute_schedule(&jobs, &trace, 10, 24.0, 4);
        let plan = &s.plans[0];
        assert!(plan.slots.iter().all(|&(t, _)| (4..6).contains(&t)), "{:?}", plan.slots);
        assert!(plan.slots.iter().any(|&(_, k)| k > 1), "never scaled: {:?}", plan.slots);
        assert!(s.planned_work[0] >= 4.0 - 1e-9);
    }

    #[test]
    fn capacity_limit_respected() {
        let jobs: Vec<Job> = (0..6).map(|i| job(i, 0, 2.0, 10.0, 4, 0.01)).collect();
        let s = compute_schedule(&jobs, &valley_trace(24), 3, 24.0, 4);
        for (t, &c) in s.capacity_curve.iter().enumerate() {
            assert!(c <= 3, "slot {t} used {c}");
        }
        // All jobs complete.
        for (j, &w) in s.planned_work.iter().enumerate() {
            assert!(w >= jobs[j].length_hours - 1e-9, "job {j} unfinished");
        }
    }

    #[test]
    fn infeasible_gets_extended() {
        // One slot of capacity per hour, 3 jobs of 4 h each arriving at 0
        // with tiny slack → must extend.
        let jobs: Vec<Job> = (0..3).map(|i| job(i, 0, 4.0, 0.0, 1, 0.0)).collect();
        let flat = CarbonTrace::new("flat", vec![100.0; 64]);
        let s = compute_schedule(&jobs, &flat, 1, 24.0, 8);
        assert!(!s.extended_jobs.is_empty());
        for (j, &w) in s.planned_work.iter().enumerate() {
            assert!(w >= jobs[j].length_hours - 1e-9, "job {j} unfinished after extension");
        }
    }

    #[test]
    fn all_jobs_get_base_before_scaling() {
        // Two identical jobs, capacity 2, valley 2 slots wide: greedy must
        // give each a base server (p=1) before scaling either (p<1).
        let hourly: Vec<f64> =
            (0..16).map(|t| if (2..4).contains(&t) { 50.0 } else { 400.0 }).collect();
        let trace = CarbonTrace::new("v", hourly);
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 2.0, 8.0, 4, 0.1)).collect();
        let s = compute_schedule(&jobs, &trace, 2, 24.0, 4);
        for t in 2..4 {
            let a0 = s.plans[0].allocation_at(t);
            let a1 = s.plans[1].allocation_at(t);
            assert_eq!(a0, 1, "job0 at t={t}: {a0}");
            assert_eq!(a1, 1, "job1 at t={t}: {a1}");
        }
    }

    #[test]
    fn greedy_is_optimal_vs_brute_force_tiny() {
        // Tiny instance: 1 job, T=4 slots, k_max=2 — compare carbon of the
        // greedy plan against exhaustive enumeration of all valid schedules.
        let trace = CarbonTrace::new("t", vec![100.0, 300.0, 50.0, 200.0]);
        let j = job(0, 0, 2.0, 2.0, 2, 0.1);
        let jobs = vec![j.clone()];
        let s = compute_schedule(&jobs, &trace, 2, 24.0, 1);

        // Carbon of a plan: Σ_t k_t · CI_t weighted by... energy model is
        // linear in servers, so server-hours·CI is the right proxy.
        let plan_carbon = |slots: &[(usize, usize)]| -> f64 {
            slots.iter().map(|&(t, k)| k as f64 * trace.at(t)).sum()
        };
        let greedy_carbon = plan_carbon(&s.plans[0].slots);

        // Brute force: k_t ∈ {0,1,2} for t=0..4 with Σ S(k_t) ≥ 2.0.
        let mut best = f64::INFINITY;
        for a in 0..3usize {
            for b in 0..3usize {
                for c in 0..3usize {
                    for d in 0..3usize {
                        let ks = [a, b, c, d];
                        let work: f64 = ks.iter().map(|&k| j.profile.throughput(k)).sum();
                        if work + 1e-9 >= 2.0 {
                            let slots: Vec<(usize, usize)> = ks
                                .iter()
                                .enumerate()
                                .filter(|(_, &k)| k > 0)
                                .map(|(t, &k)| (t, k))
                                .collect();
                            best = best.min(plan_carbon(&slots));
                        }
                    }
                }
            }
        }
        // Greedy may overshoot work slightly; allow tolerance of one
        // marginal server at the cheapest slot.
        assert!(
            greedy_carbon <= best + 50.0 + 1e-9,
            "greedy {greedy_carbon} vs brute-force {best}"
        );
    }

    /// The pre-optimization repair loop: rebuild and re-sort the FULL
    /// candidate list every round. The incremental merge must reproduce it
    /// bitwise (entry keys are unique, so sorted-merge == full re-sort).
    fn compute_schedule_reference(
        jobs: &[Job],
        ci: &CarbonTrace,
        max_capacity: usize,
        extension_step: f64,
        max_rounds: usize,
    ) -> OracleSchedule {
        let mut extra_slack = vec![0.0f64; jobs.len()];
        let mut extended: Vec<usize> = Vec::new();
        let has_deps = jobs.iter().any(|j| !j.deps.is_empty());
        let mut earliest: Vec<usize> = jobs.iter().map(|j| j.arrival).collect();
        for round in 0..max_rounds.max(1) {
            let mut entries: Vec<u128> = Vec::new();
            for j in 0..jobs.len() {
                push_job_entries(&mut entries, jobs, ci, j, extra_slack[j], earliest[j]);
            }
            entries.sort_unstable();
            let result = greedy_pass(jobs, &entries, max_capacity, &extra_slack);
            let unfinished: Vec<usize> = result
                .iter()
                .enumerate()
                .filter(|(j, (_, work))| *work < jobs[*j].length_hours - 1e-9)
                .map(|(j, _)| j)
                .collect();
            let mut displaced: Vec<usize> = Vec::new();
            if has_deps {
                for j in 0..jobs.len() {
                    let mut lb = earliest[j];
                    for &p in &jobs[j].deps {
                        if let Some(last) = result[p].0.last_slot() {
                            lb = lb.max(last + 1);
                        }
                    }
                    if lb > earliest[j]
                        && result[j].0.slots.first().map_or(false, |&(t, _)| t < lb)
                    {
                        earliest[j] = lb;
                        displaced.push(j);
                    }
                }
            }
            if (unfinished.is_empty() && displaced.is_empty()) || round + 1 == max_rounds {
                let mut result = result;
                if has_deps {
                    clamp_precedence(jobs, &mut result);
                }
                let horizon = result
                    .iter()
                    .flat_map(|(p, _)| p.last_slot())
                    .max()
                    .map(|m| m + 1)
                    .unwrap_or(0);
                let mut capacity_curve = vec![0usize; horizon];
                for (plan, _) in &result {
                    for &(t, k) in &plan.slots {
                        capacity_curve[t] += k;
                    }
                }
                return OracleSchedule {
                    planned_work: result.iter().map(|(_, w)| *w).collect(),
                    plans: result.into_iter().map(|(p, _)| p).collect(),
                    extended_jobs: extended,
                    capacity_curve,
                };
            }
            for j in unfinished {
                extra_slack[j] += extension_step;
                if !extended.contains(&j) {
                    extended.push(j);
                }
            }
        }
        unreachable!("loop always returns on the final round");
    }

    #[test]
    fn incremental_repair_matches_full_rebuild() {
        // Instances chosen to force one, several, and max-capped repair
        // rounds, on both flat and valley traces.
        let flat = CarbonTrace::new("flat", vec![100.0; 96]);
        let scarce: Vec<Job> = (0..3).map(|i| job(i, 0, 4.0, 0.0, 1, 0.0)).collect();
        let valley = valley_trace(48);
        let contended: Vec<Job> = (0..6).map(|i| job(i, i % 3, 3.0, 1.0, 4, 0.05)).collect();
        // Chained DAG over the same valley: precedence repair rounds (and
        // the final clamp) must also match the full rebuild.
        let mut chained: Vec<Job> = (0..6).map(|i| job(i, 0, 2.0, 6.0, 2, 0.05)).collect();
        for i in 1..6 {
            if i % 3 != 0 {
                chained[i].deps.push(i - 1);
            }
        }
        let cases: Vec<(&[Job], &CarbonTrace, usize, usize)> = vec![
            (&scarce[..], &flat, 1, 8),      // repeated extensions, capacity 1
            (&scarce[..], &flat, 1, 2),      // hits the round cap while infeasible
            (&contended[..], &valley, 2, 6), // elastic jobs under contention
            (&contended[..], &valley, 10, 4), // feasible round 0 (no repair)
            (&chained[..], &valley, 2, 6),   // precedence repair rounds
            (&chained[..], &valley, 4, 2),   // precedence clamp at the round cap
        ];
        for (i, (jobs, trace, cap, rounds)) in cases.into_iter().enumerate() {
            let fast = compute_schedule(jobs, trace, cap, 24.0, rounds);
            let slow = compute_schedule_reference(jobs, trace, cap, 24.0, rounds);
            assert_eq!(fast.extended_jobs, slow.extended_jobs, "case {i}: extended diverged");
            assert_eq!(fast.capacity_curve, slow.capacity_curve, "case {i}: curve diverged");
            assert_eq!(fast.plans, slow.plans, "case {i}: plans diverged");
            for (j, (a, b)) in fast.planned_work.iter().zip(&slow.planned_work).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "case {i}: work[{j}] diverged");
            }
        }
    }

    #[test]
    fn pack_entry_round_trips_at_field_boundaries() {
        let cases = [
            (0.0f32, 0usize, 0usize, 0usize, 1usize),
            (1.0, (1 << 24) - 1, (1 << 32) - 1, (1 << 24) - 1, (1 << 16) - 1),
            (f32::MAX, 12, 7, 3, 2),
        ];
        for &(score, deadline, jb, t, k) in &cases {
            let e = pack_entry(score, deadline, jb, t, k);
            assert_eq!(unpack_entry(e), (jb, t, k), "fields corrupted in {e:#034x}");
        }
        // Higher score ⇒ smaller key (descending-score greedy realized as an
        // ascending integer sort) …
        let hi = pack_entry(2.0, 5, 1, 1, 1);
        let lo = pack_entry(1.0, 5, 1, 1, 1);
        assert!(hi < lo);
        // … and at equal scores the earlier deadline sorts first.
        let near = pack_entry(1.0, 4, 1, 1, 1);
        assert!(near < lo);
    }

    #[test]
    fn oracle_never_plans_a_child_before_its_parent() {
        // Both jobs covet the same 4-slot valley; flat greedy overlaps them
        // there. With an edge 0 → 1 the child's plan must start strictly
        // after the parent's last planned slot (here: pushed to the back
        // half of the valley), and both must still finish.
        let parent = job(0, 0, 2.0, 10.0, 1, 0.0);
        let mut child = job(1, 0, 2.0, 10.0, 1, 0.0);
        child.deps = vec![0];
        let jobs = vec![parent, child];
        let s = compute_schedule(&jobs, &valley_trace(24), 10, 24.0, 8);
        let p_last = s.plans[0].last_slot().expect("parent planned");
        let c_first = s.plans[1].slots.first().expect("child planned").0;
        assert!(c_first > p_last, "child starts at {c_first}, parent ends at {p_last}");
        for (j, &w) in s.planned_work.iter().enumerate() {
            assert!(w >= jobs[j].length_hours - 1e-9, "job {j} unfinished");
        }
    }

    #[test]
    fn round_capped_repair_still_never_violates_precedence() {
        // With a single round no repair ever runs; the final clamp alone
        // must strip the child's premature slots — leaving it short of
        // work, but never scheduled before its parent's last hour.
        let parent = job(0, 0, 2.0, 10.0, 1, 0.0);
        let mut child = job(1, 0, 2.0, 10.0, 1, 0.0);
        child.deps = vec![0];
        let jobs = vec![parent, child];
        let s = compute_schedule(&jobs, &valley_trace(24), 10, 24.0, 1);
        let p_last = s.plans[0].last_slot().expect("parent planned");
        for &(t, _) in &s.plans[1].slots {
            assert!(t > p_last, "child slot {t} not after parent end {p_last}");
        }
        assert!(s.planned_work[1] < 2.0 - 1e-9, "the clamp should have cost the child work");
    }

    #[test]
    fn property_oracle_plans_are_precedence_feasible() {
        use crate::util::proptest_lite::{check, Config};
        check(
            "oracle plans are precedence-feasible",
            Config { cases: 48, seed: 0x0AC1E },
            |rng| {
                let n = 2 + rng.below(7);
                let mut jobs: Vec<Job> = (0..n)
                    .map(|i| {
                        let k_max = 1 + rng.below(3);
                        job(
                            i,
                            rng.below(4),
                            1.0 + rng.range(0.0, 3.0),
                            rng.range(0.0, 8.0),
                            k_max,
                            rng.range(0.0, 0.2),
                        )
                    })
                    .collect();
                for i in 1..n {
                    if rng.chance(0.5) {
                        jobs[i].deps.push(rng.below(i));
                    }
                }
                let cap = 1 + rng.below(5);
                (jobs, cap)
            },
            |(jobs, cap)| {
                let s = compute_schedule(jobs, &valley_trace(64), *cap, 24.0, 6);
                for j in jobs {
                    for &p in &j.deps {
                        let Some(p_last) = s.plans[p].last_slot() else { continue };
                        if let Some(&(c_first, _)) = s.plans[j.id].slots.first() {
                            if c_first <= p_last {
                                return Err(format!(
                                    "job {} starts at {c_first}, parent {p} ends at {p_last}",
                                    j.id
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_sorted_equals_resort() {
        let a: Vec<u128> = vec![1, 5, 9, 12];
        let b: Vec<u128> = vec![0, 2, 5, 30];
        let mut out = Vec::new();
        merge_sorted(&a, &b, &mut out);
        let mut expect = [a, b].concat();
        expect.sort_unstable();
        assert_eq!(out, expect);
        merge_sorted(&[], &out, &mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn plan_lookup() {
        let p = JobPlan { slots: vec![(2, 1), (5, 3)] };
        assert_eq!(p.allocation_at(2), 1);
        assert_eq!(p.allocation_at(5), 3);
        assert_eq!(p.allocation_at(3), 0);
        assert_eq!(p.last_slot(), Some(5));
    }
}
