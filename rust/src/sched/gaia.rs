//! GAIA baseline (paper §6.1, [28]): the Lowest-Window policy.
//!
//! Each job, at arrival, picks the start time within its allowed delay that
//! minimizes the mean forecast carbon intensity over the job's *expected*
//! duration (the historical mean job length — GAIA does not know true
//! lengths). Execution is non-elastic and non-preemptive; when multiple jobs
//! contend for the same slot the policy falls back to FCFS within the
//! capacity limit.

use std::collections::HashMap;

use crate::sched::{Decision, Policy, SlotCtx};
use crate::workload::job::JobId;

/// Lowest-window start-time selection.
pub struct Gaia {
    /// Historical mean job length per queue (hours) — the expected duration
    /// estimate. Queues are length-based, so per-queue means are what a
    /// deployed GAIA would compute from its own history.
    mean_length_by_queue: Vec<f64>,
    /// Chosen start slot per job.
    starts: HashMap<JobId, usize>,
}

impl Gaia {
    pub fn new(mean_length_by_queue: Vec<f64>) -> Self {
        assert!(!mean_length_by_queue.is_empty());
        Gaia { mean_length_by_queue, starts: HashMap::new() }
    }

    fn expected_length(&self, queue: usize) -> f64 {
        self.mean_length_by_queue[queue.min(self.mean_length_by_queue.len() - 1)].max(1.0)
    }
}

impl Policy for Gaia {
    fn name(&self) -> &'static str {
        "GAIA"
    }

    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        // Choose start times for newly seen jobs.
        for v in ctx.jobs {
            let id = v.job.id;
            if self.starts.contains_key(&id) {
                continue;
            }
            let dur = self.expected_length(v.job.queue).ceil() as usize;
            let arrival = v.job.arrival;
            let latest = arrival + v.job.slack_hours.floor() as usize;
            let mut best = (f64::INFINITY, arrival);
            for s in arrival.max(ctx.t)..=latest.max(ctx.t) {
                let w = ctx.forecaster.predict_window(s, dur);
                let mean = w.iter().sum::<f64>() / w.len().max(1) as f64;
                if mean < best.0 {
                    best = (mean, s);
                }
            }
            self.starts.insert(id, best.1);
        }

        // FCFS among jobs whose start time has come; non-preemptive: once a
        // job has begun (prev_alloc > 0) it keeps its server.
        let mut alloc = Vec::new();
        let mut used = 0usize;
        let mut order: Vec<usize> = (0..ctx.jobs.len()).collect();
        order.sort_by_key(|&i| {
            let v = &ctx.jobs[i];
            // Running jobs first (non-preemptive), then by planned start.
            (v.prev_alloc == 0, *self.starts.get(&v.job.id).unwrap_or(&v.job.arrival), v.job.id)
        });
        for i in order {
            let v = &ctx.jobs[i];
            let start = *self.starts.get(&v.job.id).unwrap_or(&v.job.arrival);
            let should_run = v.prev_alloc > 0 || ctx.t >= start;
            if !should_run {
                continue;
            }
            let k = v.job.k_min;
            if used + k > ctx.max_capacity {
                continue;
            }
            used += k;
            alloc.push((v.job.id, k));
        }
        Decision { capacity: ctx.max_capacity, alloc }
    }

    fn on_complete(&mut self, job: JobId, _t: usize) {
        self.starts.remove(&job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::cluster::energy::EnergyModel;
    use crate::cluster::sim::Simulator;
    use crate::config::Hardware;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.05, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    #[test]
    fn starts_in_cheapest_window() {
        // Valley at slots 6..10.
        let hourly: Vec<f64> =
            (0..48).map(|t| if (6..10).contains(&t) { 50.0 } else { 400.0 }).collect();
        let f = Forecaster::perfect(CarbonTrace::new("v", hourly));
        let jobs = vec![job(0, 0, 2.0, 10.0)];
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 48);
        let r = sim.run(&jobs, &f, &mut Gaia::new(vec![2.0]));
        // Job should run within the valley.
        let run_slots: Vec<usize> =
            r.slots.iter().filter(|s| s.used > 0).map(|s| s.t).collect();
        assert!(run_slots.iter().all(|t| (6..10).contains(t)), "{run_slots:?}");
    }

    #[test]
    fn never_scales() {
        let f = Forecaster::perfect(CarbonTrace::new("f", vec![100.0; 48]));
        let jobs = vec![job(0, 0, 3.0, 6.0)];
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 48);
        let r = sim.run(&jobs, &f, &mut Gaia::new(vec![3.0]));
        assert!(r.slots.iter().all(|s| s.used <= 1));
        assert_eq!(r.metrics.completed, 1);
    }

    #[test]
    fn fcfs_under_contention() {
        let f = Forecaster::perfect(CarbonTrace::new("f", vec![100.0; 96]));
        let jobs: Vec<Job> = (0..4).map(|i| job(i, 0, 2.0, 0.0)).collect();
        let sim = Simulator::new(2, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut Gaia::new(vec![2.0]));
        assert_eq!(r.metrics.completed, 4);
        assert!(r.slots.iter().all(|s| s.used <= 2));
    }
}
