//! Google Variable Capacity Curve (VCC) baseline (paper §6.7, [59]).
//!
//! VCC is carbon-aware *provisioning without carbon-aware scheduling*: each
//! day it computes a time-varying capacity limit by water-filling the
//! expected daily demand into the forecast's cleanest hours (cheapest-first,
//! each hour up to M), then schedules jobs FCFS within that curve. The
//! `VccScaling` variant keeps the same capacity curve but fills it
//! elastically by marginal throughput — the paper's Fig. 14 shows this
//! hybrid improves both carbon and waiting time, demonstrating CarbonFlex's
//! provisioning/scheduling separation.

use crate::sched::{Decision, Policy, SlotCtx};

/// VCC provisioning + FCFS or elastic filling.
pub struct Vcc {
    /// Expected daily demand in server-hours (from historical utilization).
    daily_demand: f64,
    /// Fill the curve elastically (VCC (Scaling)) instead of FCFS.
    scaling: bool,
    /// Capacity curve for the current day (index = hour of day).
    curve: Vec<usize>,
    /// Day the curve was computed for.
    curve_day: Option<usize>,
}

impl Vcc {
    pub fn new(daily_demand: f64, scaling: bool) -> Self {
        Vcc { daily_demand, scaling, curve: vec![], curve_day: None }
    }

    /// Water-fill the day's demand into the cleanest forecast hours.
    fn compute_curve(&self, ctx: &SlotCtx, day_start: usize) -> Vec<usize> {
        let forecast = ctx.forecaster.predict_window(day_start, 24);
        let mut order: Vec<usize> = (0..forecast.len()).collect();
        order.sort_by(|&a, &b| forecast[a].partial_cmp(&forecast[b]).unwrap());
        let mut curve = vec![0usize; 24];
        let mut remaining = self.daily_demand;
        for h in order {
            if remaining <= 0.0 {
                break;
            }
            let cap = (remaining.ceil() as usize).min(ctx.max_capacity);
            curve[h] = cap;
            remaining -= cap as f64;
        }
        curve
    }
}

impl Policy for Vcc {
    fn name(&self) -> &'static str {
        if self.scaling {
            "VCC (Scaling)"
        } else {
            "VCC"
        }
    }

    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let day = ctx.t / 24;
        if self.curve_day != Some(day) {
            self.curve = self.compute_curve(ctx, day * 24);
            self.curve_day = Some(day);
        }
        let m_t = self.curve[ctx.t % 24];

        let mut alloc = Vec::new();
        let mut used = 0usize;
        if self.scaling {
            // Elastic fill, Alg. 3-style with no threshold: base servers for
            // everyone first (EDF tie-break), then scale by marginal.
            let mut entries: Vec<(f64, usize, usize, usize)> = Vec::new(); // (−p, slack, idx, k)
            for (i, v) in ctx.jobs.iter().enumerate() {
                for k in v.job.k_min..=v.job.k_max {
                    entries.push((
                        -v.job.marginal(k),
                        v.slack_left(ctx.t).max(0.0) as usize,
                        i,
                        k,
                    ));
                }
            }
            entries.sort_by(|a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap()
                    .then(a.1.cmp(&b.1))
                    .then(a.2.cmp(&b.2))
                    .then(a.3.cmp(&b.3))
            });
            let mut granted = vec![0usize; ctx.jobs.len()];
            for (_, _, i, k) in entries {
                if used >= m_t {
                    break;
                }
                if granted[i] == k - 1 {
                    granted[i] = k;
                    used += 1;
                }
            }
            for (i, &k) in granted.iter().enumerate() {
                if k > 0 {
                    alloc.push((ctx.jobs[i].job.id, k));
                }
            }
        } else {
            // FCFS at base scale within the curve.
            for v in ctx.jobs {
                let k = v.job.k_min;
                if used + k > m_t {
                    continue;
                }
                used += k;
                alloc.push((v.job.id, k));
            }
        }
        Decision { capacity: m_t, alloc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::cluster::energy::EnergyModel;
    use crate::cluster::sim::Simulator;
    use crate::config::Hardware;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.02, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn diurnal(hours: usize) -> CarbonTrace {
        CarbonTrace::new(
            "d",
            (0..hours).map(|t| if t % 24 < 8 { 60.0 } else { 300.0 }).collect(),
        )
    }

    #[test]
    fn capacity_concentrates_in_clean_hours() {
        let f = Forecaster::perfect(diurnal(96));
        let jobs: Vec<Job> = (0..6).map(|i| job(i, i, 3.0, 24.0)).collect();
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut Vcc::new(20.0, false));
        // Provisioned capacity in dirty hours should be mostly zero.
        let dirty_cap: usize =
            r.slots.iter().filter(|s| s.ci > 100.0).map(|s| s.provisioned).sum();
        let clean_cap: usize =
            r.slots.iter().filter(|s| s.ci <= 100.0).map(|s| s.provisioned).sum();
        assert!(clean_cap > dirty_cap, "clean {clean_cap} dirty {dirty_cap}");
        assert_eq!(r.metrics.completed, 6);
    }

    #[test]
    fn scaling_variant_uses_elasticity() {
        let f = Forecaster::perfect(diurnal(96));
        let jobs: Vec<Job> = (0..3).map(|i| job(i, i, 4.0, 24.0)).collect();
        let sim = Simulator::new(12, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut Vcc::new(14.0, true));
        assert!(r.slots.iter().any(|s| s.rho < 1.0), "never scaled");
        assert_eq!(r.metrics.completed, 3);
    }

    #[test]
    fn scaling_variant_improves_waiting() {
        let f = Forecaster::perfect(diurnal(300));
        let jobs: Vec<Job> = (0..10).map(|i| job(i, i * 2, 4.0, 24.0)).collect();
        let sim = Simulator::new(12, EnergyModel::for_hardware(Hardware::Cpu), 3, 300);
        let plain = sim.run(&jobs, &f, &mut Vcc::new(40.0, false));
        let scal = sim.run(&jobs, &f, &mut Vcc::new(40.0, true));
        assert!(
            scal.metrics.mean_delay_hours <= plain.metrics.mean_delay_hours + 1e-9,
            "scaling {} vs plain {}",
            scal.metrics.mean_delay_hours,
            plain.metrics.mean_delay_hours
        );
    }
}
