//! CarbonScaler baseline (paper §6.1, [27]), adapted to a multi-job cluster.
//!
//! CarbonScaler computes a *per-job* carbon-optimal elastic schedule at
//! arrival, assuming the job's length equals the historical mean (it needs a
//! length estimate — the paper's Table 1 marks it "requires known job
//! length"). The per-job plan is Algorithm 1 restricted to one job. Under
//! cluster contention higher-marginal-throughput allocations win (the
//! simulator trims lowest-marginal servers first). If a job outlives its
//! plan (its true length exceeded the mean), CarbonScaler re-plans the
//! residual work over the remaining slack window; once slack is exhausted
//! the SLO force-run applies (paper: "when the job surpasses its allowed
//! delay, it runs until completion").

use std::collections::HashMap;

use crate::sched::oracle::{compute_schedule, JobPlan};
use crate::sched::{Decision, Policy, SlotCtx};
use crate::workload::job::{Job, JobId};

/// Per-job elastic scaling with estimated lengths.
pub struct CarbonScaler {
    /// Historical mean job length per queue, used as the assumed length of
    /// every job submitted to that queue.
    mean_length_by_queue: Vec<f64>,
    plans: HashMap<JobId, JobPlan>,
}

impl CarbonScaler {
    pub fn new(mean_length_by_queue: Vec<f64>) -> Self {
        assert!(!mean_length_by_queue.is_empty());
        CarbonScaler { mean_length_by_queue, plans: HashMap::new() }
    }

    fn expected_length(&self, queue: usize) -> f64 {
        self.mean_length_by_queue[queue.min(self.mean_length_by_queue.len() - 1)].max(1.0)
    }
}

impl Policy for CarbonScaler {
    fn name(&self) -> &'static str {
        "CarbonScaler"
    }

    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        // Plan newly arrived jobs against the day-ahead forecast; re-plan
        // jobs that outlived their plan but still have slack.
        for v in ctx.jobs {
            let id = v.job.id;
            let needs_replan = match self.plans.get(&id) {
                None => true,
                Some(plan) => {
                    let past = plan.last_slot().map(|l| ctx.t > l).unwrap_or(true);
                    past && v.remaining > 0.0 && !v.overdue
                }
            };
            if !needs_replan {
                continue;
            }
            // The residual job as CarbonScaler believes it to be: the queue
            // mean (fresh arrival) or the remaining work estimate (re-plan),
            // starting now, same deadline.
            let is_replan = self.plans.contains_key(&id);
            let assumed_len = if is_replan {
                // Residual estimate: at least the remaining work floor of
                // one more mean; the true residual is unknown.
                self.expected_length(v.job.queue).min(v.remaining.max(1.0))
            } else {
                self.expected_length(v.job.queue)
            };
            let start = if is_replan { ctx.t } else { v.job.arrival };
            let slack_left = (v.job.deadline_slot() as f64 - start as f64 - assumed_len).max(0.0);
            let assumed = Job {
                length_hours: assumed_len,
                arrival: start,
                slack_hours: slack_left,
                ..v.job.clone()
            };
            let window = assumed.deadline_slot() + 2;
            let forecast = crate::carbon::trace::CarbonTrace::new(
                "forecast",
                ctx.forecaster.predict_window(0, window),
            );
            // Single-job plan: cluster capacity is irrelevant (k_max caps it).
            let sched = compute_schedule(
                std::slice::from_ref(&assumed),
                &forecast,
                assumed.k_max,
                24.0,
                4,
            );
            self.plans.insert(id, sched.plans.into_iter().next().unwrap());
        }

        let mut alloc = Vec::new();
        let mut used = 0usize;
        // Prioritize higher-marginal-throughput jobs for the capacity budget
        // (the paper's multi-job adaptation).
        let mut order: Vec<(usize, f64)> = ctx
            .jobs
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let planned = self.plans[&v.job.id].allocation_at(ctx.t);
                let m = if planned > 0 { v.job.marginal(planned) } else { 0.0 };
                (i, m)
            })
            .collect();
        order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

        for (i, _) in order {
            let v = &ctx.jobs[i];
            let plan = &self.plans[&v.job.id];
            let past_plan = plan.last_slot().map(|l| ctx.t > l).unwrap_or(true);
            let planned = plan.allocation_at(ctx.t);
            let k = if planned > 0 {
                planned
            } else if past_plan && v.remaining > 0.0 {
                // True length exceeded the estimate: run to completion.
                v.job.k_min
            } else {
                0
            };
            if k == 0 {
                continue;
            }
            let k = k.min(ctx.max_capacity.saturating_sub(used)).max(0);
            if k < v.job.k_min {
                continue;
            }
            used += k;
            alloc.push((v.job.id, k));
        }
        Decision { capacity: ctx.max_capacity, alloc }
    }

    fn on_complete(&mut self, job: JobId, _t: usize) {
        self.plans.remove(&job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::cluster::energy::EnergyModel;
    use crate::cluster::sim::Simulator;
    use crate::config::Hardware;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.02, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn valley(hours: usize) -> CarbonTrace {
        CarbonTrace::new(
            "v",
            (0..hours).map(|t| if t % 24 < 6 { 50.0 } else { 350.0 }).collect(),
        )
    }

    #[test]
    fn completes_and_scales_into_valley() {
        let f = Forecaster::perfect(valley(96));
        let jobs = vec![job(0, 8, 4.0, 24.0)];
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 96);
        let r = sim.run(&jobs, &f, &mut CarbonScaler::new(vec![4.0]));
        assert_eq!(r.metrics.completed, 1);
        // Most energy should be spent in clean slots.
        let clean: f64 =
            r.slots.iter().filter(|s| s.ci <= 50.0).map(|s| s.energy_kwh).sum();
        let total: f64 = r.slots.iter().map(|s| s.energy_kwh).sum();
        assert!(clean / total > 0.9, "clean share {}", clean / total);
    }

    #[test]
    fn underestimated_length_runs_to_completion() {
        // True length 8 h, mean estimate 2 h: plan covers only ~2 base-hours;
        // the job must still finish (run-to-completion fallback).
        let f = Forecaster::perfect(valley(200));
        let jobs = vec![job(0, 0, 8.0, 12.0)];
        let sim = Simulator::new(10, EnergyModel::for_hardware(Hardware::Cpu), 3, 200);
        let r = sim.run(&jobs, &f, &mut CarbonScaler::new(vec![2.0]));
        assert_eq!(r.metrics.completed, 1);
        assert_eq!(r.metrics.unfinished, 0);
    }

    #[test]
    fn beats_agnostic_on_variable_trace() {
        let f = Forecaster::perfect(valley(400));
        let jobs: Vec<Job> = (0..8).map(|i| job(i, i * 7, 4.0, 24.0)).collect();
        let sim = Simulator::new(20, EnergyModel::for_hardware(Hardware::Cpu), 3, 400);
        let cs = sim.run(&jobs, &f, &mut CarbonScaler::new(vec![4.0]));
        let ag = sim.run(&jobs, &f, &mut crate::sched::carbon_agnostic::CarbonAgnostic);
        assert!(cs.metrics.carbon_g < ag.metrics.carbon_g * 0.6);
        assert_eq!(cs.metrics.completed, 8);
    }
}
