//! Scheduling policies.
//!
//! A [`Policy`] makes the paper's two per-slot decisions (§3): the
//! provisioning decision (cluster capacity `m_t ≤ M`) and the scheduling
//! decision (how many servers each active job gets). The simulator invokes
//! `decide` once per slot with a [`SlotCtx`] view of the system; online
//! policies must only read the forecaster (never ground truth beyond `t`),
//! while the offline oracle is explicitly constructed with full knowledge.

pub mod carbon_agnostic;
pub mod carbon_scaler;
pub mod carbonflex;
pub mod gaia;
pub mod oracle;
pub mod vcc;
pub mod wait_awhile;

use crate::carbon::forecast::Forecaster;
use crate::workload::job::{Job, JobId};

/// Upper bound on submission queues, so per-slot queue-length features live
/// in fixed-size inline arrays instead of one heap `Vec` per slot (§Perf:
/// the engine records one [`crate::cluster::sim::SlotRecord`] per slot; the
/// paper's setup uses 3 length-based queues). [`crate::cluster::sim::Simulator`]
/// asserts `num_queues ≤ MAX_QUEUES`.
pub const MAX_QUEUES: usize = 8;

/// Per-job view the policy sees at slot `t`.
#[derive(Debug, Clone)]
pub struct JobView<'a> {
    pub job: &'a Job,
    /// Remaining work in base-hours.
    pub remaining: f64,
    /// Allocation in the previous slot (0 = suspended/queued).
    pub prev_alloc: usize,
    /// True once the job has exhausted its slack and must run to completion.
    pub overdue: bool,
}

impl JobView<'_> {
    /// Slack still available before the job becomes overdue, hours. The
    /// remaining window is (deadline − t) and the job still needs
    /// `remaining` base-hours at minimum scale.
    pub fn slack_left(&self, t: usize) -> f64 {
        self.job.deadline_slot() as f64 - t as f64 - self.remaining
    }
}

/// A policy's decision for one slot.
#[derive(Debug, Clone, Default)]
pub struct Decision {
    /// Provisioned cluster capacity m_t for this slot (will be clamped to M).
    pub capacity: usize,
    /// Server allocation per job (absent = suspended). Scales are clamped to
    /// each job's [k_min, k_max] by the simulator.
    pub alloc: Vec<(JobId, usize)>,
}

/// Immutable system view handed to `Policy::decide` each slot.
pub struct SlotCtx<'a> {
    /// Current slot (hours since trace start).
    pub t: usize,
    /// Active (queued + running) jobs, in arrival order.
    pub jobs: &'a [JobView<'a>],
    /// Day-ahead forecast service (the only carbon signal online policies
    /// may consult).
    pub forecaster: &'a Forecaster,
    /// Maximum cluster capacity M.
    pub max_capacity: usize,
    /// Number of submission queues.
    pub num_queues: usize,
    /// Capacity provisioned in the previous slot.
    pub prev_capacity: usize,
    /// Servers actually allocated in the previous slot (utilization feature).
    pub prev_used: usize,
    /// Fraction of jobs completed in the trailing 24 h that violated their
    /// slack (Alg. 2's `v`).
    pub recent_violation_rate: f64,
}

impl SlotCtx<'_> {
    /// Number of active jobs per queue — the Table 2 "queue length" feature.
    /// Entries past `num_queues` are zero (inline array, no heap).
    pub fn queue_lengths(&self) -> [usize; MAX_QUEUES] {
        let mut lens = [0usize; MAX_QUEUES];
        let top = self.num_queues.max(1).min(MAX_QUEUES) - 1;
        for jv in self.jobs {
            let q = jv.job.queue.min(top);
            lens[q] += 1;
        }
        lens
    }

    /// Mean elasticity across active jobs (Table 2 feature); 0 when idle.
    pub fn mean_elasticity(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        self.jobs.iter().map(|j| j.job.elasticity()).sum::<f64>() / self.jobs.len() as f64
    }
}

/// A provisioning + scheduling policy.
///
/// Implementations must provide at least one of [`decide`](Policy::decide)
/// and [`decide_into`](Policy::decide_into) (each has a default in terms of
/// the other; implementing neither recurses). Simple policies implement
/// `decide`; hot-path policies implement `decide_into` and reuse the output
/// buffer so steady-state slots allocate nothing.
pub trait Policy {
    /// Human-readable policy name used in reports.
    fn name(&self) -> &'static str;

    /// Decide capacity and allocations for slot `ctx.t`.
    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let mut out = Decision::default();
        self.decide_into(ctx, &mut out);
        out
    }

    /// Buffer-reusing variant of [`decide`](Policy::decide): the engine
    /// hands back the same `Decision` every slot. `out` still holds the
    /// previous slot's entries — implementations must overwrite `capacity`
    /// and clear/refill `alloc` (keeping its capacity).
    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        *out = self.decide(ctx);
    }

    /// Hook: called once when a job completes (policies with internal
    /// schedules can garbage-collect).
    fn on_complete(&mut self, _job: JobId, _t: usize) {}
}

/// Identifier for constructing policies by name (CLI / experiment grids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    CarbonAgnostic,
    Gaia,
    WaitAwhile,
    CarbonScaler,
    Vcc,
    VccScaling,
    CarbonFlex,
    Oracle,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 8] = [
        PolicyKind::CarbonAgnostic,
        PolicyKind::Gaia,
        PolicyKind::WaitAwhile,
        PolicyKind::CarbonScaler,
        PolicyKind::Vcc,
        PolicyKind::VccScaling,
        PolicyKind::CarbonFlex,
        PolicyKind::Oracle,
    ];

    /// The six policies of the paper's headline comparison (Fig. 6/7).
    pub const HEADLINE: [PolicyKind; 6] = [
        PolicyKind::CarbonAgnostic,
        PolicyKind::Gaia,
        PolicyKind::WaitAwhile,
        PolicyKind::CarbonScaler,
        PolicyKind::CarbonFlex,
        PolicyKind::Oracle,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::CarbonAgnostic => "Carbon-Agnostic",
            PolicyKind::Gaia => "GAIA",
            PolicyKind::WaitAwhile => "Wait Awhile",
            PolicyKind::CarbonScaler => "CarbonScaler",
            PolicyKind::Vcc => "VCC",
            PolicyKind::VccScaling => "VCC (Scaling)",
            PolicyKind::CarbonFlex => "CarbonFlex",
            PolicyKind::Oracle => "CarbonFlex(Oracle)",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        let norm = s.to_ascii_lowercase().replace([' ', '-', '_', '(', ')'], "");
        Some(match norm.as_str() {
            "carbonagnostic" | "agnostic" | "fcfs" => PolicyKind::CarbonAgnostic,
            "gaia" => PolicyKind::Gaia,
            "waitawhile" | "wait" => PolicyKind::WaitAwhile,
            "carbonscaler" | "scaler" => PolicyKind::CarbonScaler,
            "vcc" => PolicyKind::Vcc,
            "vccscaling" => PolicyKind::VccScaling,
            "carbonflex" | "flex" => PolicyKind::CarbonFlex,
            "carbonflexoracle" | "oracle" => PolicyKind::Oracle,
            _ => return None,
        })
    }

    /// Canonical CLI key for this policy; always round-trips through
    /// [`PolicyKind::parse`].
    pub fn key(&self) -> &'static str {
        match self {
            PolicyKind::CarbonAgnostic => "agnostic",
            PolicyKind::Gaia => "gaia",
            PolicyKind::WaitAwhile => "wait-awhile",
            PolicyKind::CarbonScaler => "carbon-scaler",
            PolicyKind::Vcc => "vcc",
            PolicyKind::VccScaling => "vcc-scaling",
            PolicyKind::CarbonFlex => "carbonflex",
            PolicyKind::Oracle => "oracle",
        }
    }

    /// Comma-joined list of all canonical CLI keys (for error messages).
    pub fn valid_keys() -> String {
        PolicyKind::ALL.map(|k| k.key()).join(", ")
    }

    /// Like [`PolicyKind::parse`] but with an error message listing the
    /// valid names — the single parser every subcommand's `--policy` /
    /// `--policies` flag goes through.
    pub fn parse_or_err(s: &str) -> Result<PolicyKind, String> {
        PolicyKind::parse(s)
            .ok_or_else(|| format!("unknown policy '{s}' (valid: {})", PolicyKind::valid_keys()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.as_str()), Some(k), "{}", k.as_str());
        }
        assert_eq!(PolicyKind::parse("oracle"), Some(PolicyKind::Oracle));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn policy_kind_keys_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.key()), Some(k), "{}", k.key());
            assert_eq!(PolicyKind::parse_or_err(k.key()), Ok(k));
        }
        let err = PolicyKind::parse_or_err("warp-drive").unwrap_err();
        assert!(err.contains("valid:"), "{err}");
        assert!(err.contains("carbonflex"), "{err}");
    }
}
