//! Scheduling policies.
//!
//! A [`Policy`] makes the paper's two per-slot decisions (§3): the
//! provisioning decision (cluster capacity `m_t ≤ M`) and the scheduling
//! decision (how many servers each active job gets). The simulator invokes
//! `decide` once per slot with a [`SlotCtx`] view of the system; online
//! policies must only read the forecaster (never ground truth beyond `t`),
//! while the offline oracle is explicitly constructed with full knowledge.

pub mod carbon_agnostic;
pub mod carbon_scaler;
pub mod carbonflex;
pub mod gaia;
pub mod oracle;
pub mod vcc;
pub mod wait_awhile;

use crate::carbon::forecast::Forecaster;
use crate::workload::job::{Job, JobId};

/// Upper bound on submission queues, so per-slot queue-length features live
/// in fixed-size inline arrays instead of one heap `Vec` per slot (§Perf:
/// the engine records one [`crate::cluster::sim::SlotRecord`] per slot; the
/// paper's setup uses 3 length-based queues). [`crate::cluster::sim::Simulator`]
/// asserts `num_queues ≤ MAX_QUEUES`.
pub const MAX_QUEUES: usize = 8;

/// Per-job view the policy sees at slot `t`.
///
/// Policies see **eligibility, not raw arrival**: a job with unfinished
/// dependency parents (`Job::deps`) never appears in `SlotCtx::jobs` — the
/// engine holds it back until every parent completes, and stamps the slot
/// it was released in `eligible_since`. For flat (zero-edge) workloads
/// `eligible_since == job.arrival`, so precedence-unaware policies behave
/// bitwise identically to the pre-DAG interface.
#[derive(Debug, Clone)]
pub struct JobView<'a> {
    pub job: &'a Job,
    /// Remaining work in base-hours.
    pub remaining: f64,
    /// Allocation in the previous slot (0 = suspended/queued).
    pub prev_alloc: usize,
    /// True once the job has exhausted its slack and must run to completion.
    pub overdue: bool,
    /// Slot this job became eligible to run: its arrival for jobs with no
    /// (remaining) parents, else the slot after its last parent completed.
    pub eligible_since: usize,
}

impl JobView<'_> {
    /// Slack still available before the job becomes overdue, hours. The
    /// remaining window is (deadline − t) and the job still needs
    /// `remaining` base-hours at minimum scale.
    pub fn slack_left(&self, t: usize) -> f64 {
        self.job.deadline_slot() as f64 - t as f64 - self.remaining
    }
}

/// Columnar (structure-of-arrays) mirror of the active [`JobView`] slice.
///
/// §Perf: the engine fills one entry per active job, in the same order as
/// `SlotCtx::jobs`, so policies and the Table 2 feature extraction can run
/// branch-light index loops over contiguous `f64`/`u32` slices instead of
/// pointer-chasing `&Job` structs. Column `i` always describes
/// `ctx.jobs[i]`. All buffers are clear+refill, so steady-state slots
/// allocate nothing once warm.
#[derive(Debug, Clone, Default)]
pub struct JobViewCols {
    /// Job id (dense engine index).
    pub id: Vec<JobId>,
    /// Remaining work in base-hours.
    pub remaining: Vec<f64>,
    /// Allocation in the previous slot (0 = suspended/queued).
    pub prev_alloc: Vec<u32>,
    /// True once the job has exhausted its slack.
    pub overdue: Vec<bool>,
    /// Slot the job became eligible (see [`JobView::eligible_since`]).
    pub eligible_since: Vec<u32>,
    /// Submission queue index.
    pub queue: Vec<u32>,
    /// `Job::elasticity()` captured at fill time.
    pub elasticity: Vec<f64>,
    /// Minimum allocation k_min.
    pub k_min: Vec<u32>,
    /// Maximum allocation k_max.
    pub k_max: Vec<u32>,
}

impl JobViewCols {
    pub fn clear(&mut self) {
        self.id.clear();
        self.remaining.clear();
        self.prev_alloc.clear();
        self.overdue.clear();
        self.eligible_since.clear();
        self.queue.clear();
        self.elasticity.clear();
        self.k_min.clear();
        self.k_max.clear();
    }

    /// Append one job's columns (same field values a [`JobView`] would carry).
    pub fn push(
        &mut self,
        job: &Job,
        remaining: f64,
        prev_alloc: usize,
        overdue: bool,
        eligible_since: usize,
    ) {
        self.id.push(job.id);
        self.remaining.push(remaining);
        self.prev_alloc.push(prev_alloc as u32);
        self.overdue.push(overdue);
        self.eligible_since.push(eligible_since as u32);
        self.queue.push(job.queue as u32);
        self.elasticity.push(job.elasticity());
        self.k_min.push(job.k_min as u32);
        self.k_max.push(job.k_max as u32);
    }

    /// Pre-size every column (the engine calls this from its own
    /// `reserve`, so steady-state slots never grow the buffers).
    pub fn reserve(&mut self, additional: usize) {
        self.id.reserve(additional);
        self.remaining.reserve(additional);
        self.prev_alloc.reserve(additional);
        self.overdue.reserve(additional);
        self.eligible_since.reserve(additional);
        self.queue.reserve(additional);
        self.elasticity.reserve(additional);
        self.k_min.reserve(additional);
        self.k_max.reserve(additional);
    }

    pub fn len(&self) -> usize {
        self.id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id.is_empty()
    }

    /// Build from an existing view slice (tests and one-shot callers; the
    /// engine fills incrementally instead).
    pub fn from_views(views: &[JobView]) -> JobViewCols {
        let mut cols = JobViewCols::default();
        for v in views {
            cols.push(v.job, v.remaining, v.prev_alloc, v.overdue, v.eligible_since);
        }
        cols
    }
}

/// A policy's decision for one slot.
#[derive(Debug, Clone, Default)]
pub struct Decision {
    /// Provisioned cluster capacity m_t for this slot (will be clamped to M).
    pub capacity: usize,
    /// Server allocation per job (absent = suspended). Scales are clamped to
    /// each job's [k_min, k_max] by the simulator.
    pub alloc: Vec<(JobId, usize)>,
}

/// Immutable system view handed to `Policy::decide` each slot.
pub struct SlotCtx<'a> {
    /// Current slot (hours since trace start).
    pub t: usize,
    /// Active (queued + running) jobs, in arrival order.
    pub jobs: &'a [JobView<'a>],
    /// Columnar mirror of `jobs` (entry `i` ↔ `jobs[i]`): policies that
    /// only need scalar per-job fields read these contiguous slices.
    pub cols: &'a JobViewCols,
    /// Day-ahead forecast service (the only carbon signal online policies
    /// may consult).
    pub forecaster: &'a Forecaster,
    /// Maximum cluster capacity M.
    pub max_capacity: usize,
    /// Number of submission queues.
    pub num_queues: usize,
    /// Capacity provisioned in the previous slot.
    pub prev_capacity: usize,
    /// Servers actually allocated in the previous slot (utilization feature).
    pub prev_used: usize,
    /// Fraction of jobs completed in the trailing 24 h that violated their
    /// slack (Alg. 2's `v`).
    pub recent_violation_rate: f64,
}

impl SlotCtx<'_> {
    /// Number of active jobs per queue — the Table 2 "queue length" feature.
    /// Entries past `num_queues` are zero (inline array, no heap). Runs
    /// over the contiguous queue column; bitwise-identical to the old
    /// per-struct walk (same iteration order, same clamping).
    pub fn queue_lengths(&self) -> [usize; MAX_QUEUES] {
        let mut lens = [0usize; MAX_QUEUES];
        let top = self.num_queues.max(1).min(MAX_QUEUES) - 1;
        for &q in &self.cols.queue {
            lens[(q as usize).min(top)] += 1;
        }
        lens
    }

    /// Mean elasticity across active jobs (Table 2 feature); 0 when idle.
    /// Sums the elasticity column in fill order — the same operation
    /// sequence as the old `jobs.iter()` walk, so the result is bitwise
    /// identical.
    pub fn mean_elasticity(&self) -> f64 {
        if self.cols.is_empty() {
            return 0.0;
        }
        self.cols.elasticity.iter().sum::<f64>() / self.cols.len() as f64
    }
}

/// Degradation-ladder counters a policy accumulates over a run (see
/// `crate::faults`): slots decided on a stale last-known-good forecast and
/// slots handed to the carbon-agnostic fallback because the signal was dark.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DegradationCounters {
    pub stale: u64,
    pub fallback: u64,
}

/// A provisioning + scheduling policy.
///
/// Implementations must provide at least one of [`decide`](Policy::decide)
/// and [`decide_into`](Policy::decide_into) (each has a default in terms of
/// the other; implementing neither recurses). Simple policies implement
/// `decide`; hot-path policies implement `decide_into` and reuse the output
/// buffer so steady-state slots allocate nothing.
pub trait Policy {
    /// Human-readable policy name used in reports.
    fn name(&self) -> &'static str;

    /// Decide capacity and allocations for slot `ctx.t`.
    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let mut out = Decision::default();
        self.decide_into(ctx, &mut out);
        out
    }

    /// Buffer-reusing variant of [`decide`](Policy::decide): the engine
    /// hands back the same `Decision` every slot. `out` still holds the
    /// previous slot's entries — implementations must overwrite `capacity`
    /// and clear/refill `alloc` (keeping its capacity).
    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        *out = self.decide(ctx);
    }

    /// Hook: called once when a job completes (policies with internal
    /// schedules can garbage-collect).
    fn on_complete(&mut self, _job: JobId, _t: usize) {}

    /// Degradation-ladder counters accumulated so far (zero for policies
    /// that never degrade; CarbonFlex overrides this during signal outages).
    fn degradation(&self) -> DegradationCounters {
        DegradationCounters::default()
    }
}

/// Identifier for constructing policies by name (CLI / experiment grids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    CarbonAgnostic,
    Gaia,
    WaitAwhile,
    CarbonScaler,
    Vcc,
    VccScaling,
    CarbonFlex,
    Oracle,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 8] = [
        PolicyKind::CarbonAgnostic,
        PolicyKind::Gaia,
        PolicyKind::WaitAwhile,
        PolicyKind::CarbonScaler,
        PolicyKind::Vcc,
        PolicyKind::VccScaling,
        PolicyKind::CarbonFlex,
        PolicyKind::Oracle,
    ];

    /// The six policies of the paper's headline comparison (Fig. 6/7).
    pub const HEADLINE: [PolicyKind; 6] = [
        PolicyKind::CarbonAgnostic,
        PolicyKind::Gaia,
        PolicyKind::WaitAwhile,
        PolicyKind::CarbonScaler,
        PolicyKind::CarbonFlex,
        PolicyKind::Oracle,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::CarbonAgnostic => "Carbon-Agnostic",
            PolicyKind::Gaia => "GAIA",
            PolicyKind::WaitAwhile => "Wait Awhile",
            PolicyKind::CarbonScaler => "CarbonScaler",
            PolicyKind::Vcc => "VCC",
            PolicyKind::VccScaling => "VCC (Scaling)",
            PolicyKind::CarbonFlex => "CarbonFlex",
            PolicyKind::Oracle => "CarbonFlex(Oracle)",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        let norm = s.to_ascii_lowercase().replace([' ', '-', '_', '(', ')'], "");
        Some(match norm.as_str() {
            "carbonagnostic" | "agnostic" | "fcfs" => PolicyKind::CarbonAgnostic,
            "gaia" => PolicyKind::Gaia,
            "waitawhile" | "wait" => PolicyKind::WaitAwhile,
            "carbonscaler" | "scaler" => PolicyKind::CarbonScaler,
            "vcc" => PolicyKind::Vcc,
            "vccscaling" => PolicyKind::VccScaling,
            "carbonflex" | "flex" => PolicyKind::CarbonFlex,
            "carbonflexoracle" | "oracle" => PolicyKind::Oracle,
            _ => return None,
        })
    }

    /// Canonical CLI key for this policy; always round-trips through
    /// [`PolicyKind::parse`].
    pub fn key(&self) -> &'static str {
        match self {
            PolicyKind::CarbonAgnostic => "agnostic",
            PolicyKind::Gaia => "gaia",
            PolicyKind::WaitAwhile => "wait-awhile",
            PolicyKind::CarbonScaler => "carbon-scaler",
            PolicyKind::Vcc => "vcc",
            PolicyKind::VccScaling => "vcc-scaling",
            PolicyKind::CarbonFlex => "carbonflex",
            PolicyKind::Oracle => "oracle",
        }
    }

    /// Comma-joined list of all canonical CLI keys (for error messages).
    pub fn valid_keys() -> String {
        PolicyKind::ALL.map(|k| k.key()).join(", ")
    }

    /// Like [`PolicyKind::parse`] but with an error message listing the
    /// valid names — the single parser every subcommand's `--policy` /
    /// `--policies` flag goes through.
    pub fn parse_or_err(s: &str) -> Result<PolicyKind, String> {
        PolicyKind::parse(s)
            .ok_or_else(|| format!("unknown policy '{s}' (valid: {})", PolicyKind::valid_keys()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.as_str()), Some(k), "{}", k.as_str());
        }
        assert_eq!(PolicyKind::parse("oracle"), Some(PolicyKind::Oracle));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn job_view_cols_mirror_views() {
        use crate::workload::profile::ScalingProfile;
        let jobs: Vec<Job> = (0..6)
            .map(|i| Job {
                id: i,
                workload: "t",
                workload_idx: 0,
                arrival: i,
                length_hours: 2.0 + i as f64,
                queue: i % 3,
                slack_hours: 6.0,
                k_min: 1,
                k_max: 4,
                profile: ScalingProfile::from_comm_ratio(0.05, 4),
                watts_per_unit: 40.0,
                deps: Vec::new(),
            })
            .collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView {
                job: j,
                remaining: j.length_hours,
                prev_alloc: j.id % 2,
                overdue: j.id == 5,
                eligible_since: j.arrival,
            })
            .collect();
        let cols = JobViewCols::from_views(&views);
        assert_eq!(cols.len(), views.len());
        for (i, v) in views.iter().enumerate() {
            assert_eq!(cols.id[i], v.job.id);
            assert_eq!(cols.remaining[i].to_bits(), v.remaining.to_bits());
            assert_eq!(cols.prev_alloc[i] as usize, v.prev_alloc);
            assert_eq!(cols.overdue[i], v.overdue);
            assert_eq!(cols.eligible_since[i] as usize, v.eligible_since);
            assert_eq!(cols.queue[i] as usize, v.job.queue);
            assert_eq!(cols.elasticity[i].to_bits(), v.job.elasticity().to_bits());
            assert_eq!(cols.k_min[i] as usize, v.job.k_min);
            assert_eq!(cols.k_max[i] as usize, v.job.k_max);
        }
        // The columnar Table 2 features match a per-struct recomputation.
        use crate::carbon::forecast::Forecaster;
        use crate::carbon::trace::CarbonTrace;
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 4]));
        let ctx = SlotCtx {
            t: 0,
            jobs: &views,
            cols: &cols,
            forecaster: &f,
            max_capacity: 8,
            num_queues: 3,
            prev_capacity: 8,
            prev_used: 0,
            recent_violation_rate: 0.0,
        };
        let mut want = [0usize; MAX_QUEUES];
        for v in &views {
            want[v.job.queue.min(2)] += 1;
        }
        assert_eq!(ctx.queue_lengths(), want);
        let mean = views.iter().map(|v| v.job.elasticity()).sum::<f64>() / views.len() as f64;
        assert_eq!(ctx.mean_elasticity().to_bits(), mean.to_bits());
    }

    #[test]
    fn policy_kind_keys_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.key()), Some(k), "{}", k.key());
            assert_eq!(PolicyKind::parse_or_err(k.key()), Ok(k));
        }
        let err = PolicyKind::parse_or_err("warp-drive").unwrap_err();
        assert!(err.contains("valid:"), "{err}");
        assert!(err.contains("carbonflex"), "{err}");
    }
}
