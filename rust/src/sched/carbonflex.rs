//! CarbonFlex runtime provisioning (Algorithm 2) and scheduling
//! (Algorithm 3).
//!
//! At each slot the policy computes the Table 2 state, queries the knowledge
//! base for the top-k closest historical oracle decisions (case-based
//! reasoning), and mimics them:
//!
//! - **Provisioning φ (Alg. 2)**: the capacity is the mean of the matched
//!   capacities; if recent delay violations exceed the tolerance ε, fall
//!   back to the max of the matches (and, when matches are also distant
//!   — dist > δ — provision full M, i.e. carbon-agnostic).
//! - **Scheduling ψ (Alg. 3)**: allocate server increments whose marginal
//!   throughput `p_j(k)` meets the learned threshold ρ, ordered by marginal
//!   throughput with remaining-slack tie-breaks, until m_t is filled. Base
//!   allocations (`p = 1`) sort first, so no job is starved before any job
//!   scales, exactly as in Algorithm 1.
//!
//! The matcher backend is pluggable: the native KD-tree, or the AOT-compiled
//! Pallas kernel executed via PJRT (`runtime::matcher`) — Python stays off
//! the request path either way.

use crate::carbon::forecast::SignalState;
use crate::learning::kb::{Matcher, Neighbor};
use crate::learning::state::StateVector;
use crate::sched::carbon_agnostic::CarbonAgnostic;
use crate::sched::{Decision, DegradationCounters, Policy, SlotCtx};

/// Aggregator over the matched capacities (Alg. 2 line "mimic"). Selectable
/// for the ablation bench via the `CARBONFLEX_AGG` environment variable,
/// which is resolved **once at policy construction** (§Perf: the per-slot
/// `std::env::var` lookup used to sit on the decide hot path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapacityAgg {
    /// Inverse-distance-weighted mean (the default).
    WeightedMean,
    Min,
    Max,
    Median,
}

impl CapacityAgg {
    /// Resolve from a `CARBONFLEX_AGG` value (`None`/unknown → default).
    pub fn from_key(key: Option<&str>) -> CapacityAgg {
        match key {
            Some("min") => CapacityAgg::Min,
            Some("max") => CapacityAgg::Max,
            Some("median") => CapacityAgg::Median,
            _ => CapacityAgg::WeightedMean,
        }
    }

    /// Read `CARBONFLEX_AGG` (done once, at params construction).
    pub fn from_env() -> CapacityAgg {
        Self::from_key(std::env::var("CARBONFLEX_AGG").ok().as_deref())
    }
}

/// Aggregator over the matched thresholds ρ, resolved from `CARBONFLEX_RHO`
/// once at policy construction (see [`CapacityAgg`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RhoAgg {
    /// The most permissive matched threshold (the default): the oracle's
    /// recorded ρ is the marginal of the LAST server it granted; taking the
    /// neighbourhood minimum lets leftover clean capacity be used for
    /// scaling instead of idling (fewer forced dirty runs, see the fig6
    /// ablation bench).
    Min,
    /// Robust to the RHO_IDLE sentinel mixing with real marginals.
    Median,
    Max,
}

impl RhoAgg {
    /// Resolve from a `CARBONFLEX_RHO` value (`None`/unknown → default).
    pub fn from_key(key: Option<&str>) -> RhoAgg {
        match key {
            Some("median") => RhoAgg::Median,
            Some("max") => RhoAgg::Max,
            _ => RhoAgg::Min,
        }
    }

    /// Read `CARBONFLEX_RHO` (done once, at params construction).
    pub fn from_env() -> RhoAgg {
        Self::from_key(std::env::var("CARBONFLEX_RHO").ok().as_deref())
    }
}

/// Tunables for Algorithm 2.
#[derive(Debug, Clone, Copy)]
pub struct CarbonFlexParams {
    /// Neighbours to match (paper: k = 5).
    pub knn_k: usize,
    /// Violation tolerance ε on the recent delay-violation rate.
    pub violation_tolerance: f64,
    /// Expected-distance bound δ: matches farther than this are distrusted.
    pub distance_bound: f64,
    /// Urgency look-ahead (hours): provisioning never drops below the base
    /// allocation of jobs whose remaining slack is within this window. This
    /// is the feedback the paper describes as "considering the utility of
    /// these decisions in previous time slots" — without it, a mimicked
    /// low-capacity decision can push a cohort over its deadline cliff and
    /// force dirty-slot runs.
    pub urgency_window: f64,
    /// Capacity aggregation over the matches (env-resolved at construction).
    pub capacity_agg: CapacityAgg,
    /// Threshold aggregation over the matches (env-resolved at construction).
    pub rho_agg: RhoAgg,
}

impl Default for CarbonFlexParams {
    fn default() -> Self {
        CarbonFlexParams {
            knn_k: 5,
            violation_tolerance: 0.2,
            distance_bound: 1.5,
            urgency_window: 2.0,
            capacity_agg: CapacityAgg::from_env(),
            rho_agg: RhoAgg::from_env(),
        }
    }
}

/// The CarbonFlex online policy, generic over the matcher backend (native
/// KD-tree knowledge base, or the PJRT-executed Pallas kernel).
///
/// §Perf: the per-slot working sets (matched neighbours, the Alg. 3
/// candidate list, the granted-server table, the ρ sample) live in reusable
/// buffers, so a steady-state `decide_into` call allocates nothing.
pub struct CarbonFlex<M: Matcher> {
    matcher: M,
    params: CarbonFlexParams,
    /// Critical-path tail per job id (longest chain of `length_hours`
    /// strictly downstream of the job, see
    /// [`crate::workload::job::critical_path_downstream`]). Empty for flat
    /// workloads — every slack read then takes the exact pre-DAG
    /// instruction path, so flat runs stay bitwise identical.
    downstream: Vec<f64>,
    /// Matched neighbours for the current slot.
    neighbors: Vec<Neighbor>,
    /// Alg. 3 candidate entries: (marginal, slack, view index, k).
    entries: Vec<(f64, f64, usize, usize)>,
    /// Per-view granted servers.
    granted: Vec<usize>,
    /// Matched thresholds, sorted for aggregation.
    rhos: Vec<f64>,
    /// Degradation-ladder bookkeeping (see `crate::faults`): counts of
    /// stale-forecast slots and carbon-agnostic fallback slots.
    degraded: DegradationCounters,
    /// Bottom rung of the ladder: the carbon-agnostic baseline decides the
    /// slot when the signal is dark.
    fallback: CarbonAgnostic,
}

impl<M: Matcher> CarbonFlex<M> {
    pub fn new(matcher: M, params: CarbonFlexParams) -> Self {
        Self::with_critical_path(matcher, params, Vec::new())
    }

    /// DAG-aware variant: urgency and the Alg. 3 ordering use
    /// **critical-path slack** — per-queue slack minus the longest chain of
    /// work strictly downstream of the job — instead of the flat per-queue
    /// slack. A parent whose completion unblocks a deep chain is treated as
    /// urgent long before its own deadline is. `downstream` is indexed by
    /// dense job id; pass an empty vector for flat workloads.
    pub fn with_critical_path(
        matcher: M,
        params: CarbonFlexParams,
        downstream: Vec<f64>,
    ) -> Self {
        CarbonFlex {
            matcher,
            params,
            downstream,
            neighbors: Vec::new(),
            entries: Vec::new(),
            granted: Vec::new(),
            rhos: Vec::new(),
            degraded: DegradationCounters::default(),
            fallback: CarbonAgnostic,
        }
    }

    /// Effective slack of a job for urgency and scheduling order: flat
    /// per-queue slack, less the critical-path tail that cannot start until
    /// this job completes. Never larger than the flat slack (tails are
    /// non-negative).
    fn cp_slack(&self, v: &crate::sched::JobView<'_>, t: usize) -> f64 {
        if self.downstream.is_empty() {
            v.slack_left(t)
        } else {
            v.slack_left(t) - self.downstream.get(v.job.id).copied().unwrap_or(0.0)
        }
    }

    /// Match a batch of states against the knowledge base in one call
    /// (`knn_k` neighbours each): neighbours for state `i` land in
    /// `out[offsets[i]..offsets[i + 1]]`. One scratch set serves the whole
    /// batch (`Matcher::top_k_batch_into`); the per-slot decide path issues
    /// the same queries one at a time through `Matcher::top_k_into`.
    pub fn match_batch(
        &mut self,
        states: &[StateVector],
        out: &mut Vec<Neighbor>,
        offsets: &mut Vec<usize>,
    ) {
        self.matcher.top_k_batch_into(states, self.params.knn_k, out, offsets);
    }

    /// Build the Table 2 state for the current slot, reading the carbon
    /// signal as of slot `q` (`q == ctx.t` when fresh; an earlier
    /// last-known-good slot on the stale rung of the degradation ladder).
    /// Cluster-observable features (queue lengths, elasticity) always come
    /// from the live slot — only the carbon signal can go stale.
    fn state_at(ctx: &SlotCtx, q: usize) -> StateVector {
        let ci = ctx.forecaster.predict(q);
        let ci_prev = if q == 0 { ci } else { ctx.forecaster.predict(q - 1) };
        StateVector::from_raw(
            ci,
            ci - ci_prev,
            ctx.forecaster.day_ahead_rank(q),
            &ctx.queue_lengths(),
            ctx.mean_elasticity(),
        )
    }

    /// Base servers needed by jobs about to exhaust their (critical-path)
    /// slack.
    fn urgent_floor(&self, ctx: &SlotCtx) -> usize {
        ctx.jobs
            .iter()
            .filter(|v| self.cp_slack(v, ctx.t) <= self.params.urgency_window)
            .map(|v| v.job.k_min)
            .sum()
    }

    /// Algorithm 2: the provisioning decision m_t over `self.neighbors`.
    fn provision(&self, ctx: &SlotCtx) -> usize {
        let matches = &self.neighbors;
        let floor = self.urgent_floor(ctx).min(ctx.max_capacity);
        if matches.is_empty() {
            return ctx.max_capacity; // no knowledge → carbon-agnostic
        }
        let v = ctx.recent_violation_rate;
        let eps = self.params.violation_tolerance;
        let min_dist = matches[0].dist;
        if min_dist > self.params.distance_bound && v > eps {
            // Far from anything we have seen AND hurting SLOs: full capacity.
            return ctx.max_capacity;
        }
        if v > eps {
            // Violating: take the most generous of the matched capacities
            // (not the previous provisioning — that would ratchet the
            // cluster up permanently through dirty periods).
            return matches
                .iter()
                .map(|m| m.capacity)
                .max()
                .unwrap_or(ctx.max_capacity)
                .max(floor)
                .min(ctx.max_capacity);
        }
        // Nominal aggregation over the matched capacities (default:
        // inverse-distance-weighted mean; variants for the ablation bench).
        let agg = match self.params.capacity_agg {
            CapacityAgg::Min => matches.iter().map(|m| m.capacity).min().unwrap_or(0) as f64,
            CapacityAgg::Max => matches.iter().map(|m| m.capacity).max().unwrap_or(0) as f64,
            CapacityAgg::Median => {
                // Ablation-only path; the small sort buffer is off the
                // default hot path.
                let mut caps: Vec<usize> = matches.iter().map(|m| m.capacity).collect();
                caps.sort_unstable();
                caps[caps.len() / 2] as f64
            }
            CapacityAgg::WeightedMean => {
                let mut num = 0.0;
                let mut den = 0.0;
                for m in matches {
                    let w = 1.0 / (m.dist + 1e-3);
                    num += w * m.capacity as f64;
                    den += w;
                }
                num / den
            }
        };
        (agg.round() as usize).max(floor).min(ctx.max_capacity)
    }

    /// Aggregate the matched thresholds per `params.rho_agg`.
    fn threshold(&mut self) -> f64 {
        if self.neighbors.is_empty() {
            return 0.0; // schedule anything
        }
        let rhos = &mut self.rhos;
        rhos.clear();
        rhos.extend(self.neighbors.iter().map(|m| m.rho));
        // Unstable sort: equal thresholds are interchangeable, and
        // `sort_by`'s merge buffer would allocate on the hot path.
        rhos.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        match self.params.rho_agg {
            RhoAgg::Median => rhos[rhos.len() / 2],
            RhoAgg::Max => rhos[rhos.len() - 1],
            RhoAgg::Min => rhos[0],
        }
    }

    /// Algorithm 3: fill m_t with the highest-marginal server increments at
    /// or above the threshold ρ, written into `out`.
    fn schedule(&mut self, ctx: &SlotCtx, m_t: usize, rho: f64, out: &mut Decision) {
        // Candidate server increments (j, k) with p_j(k) ≥ ρ.
        // Sort key: marginal desc, remaining (critical-path) slack asc
        // (EDF), id. Split field borrow: `entries` is taken mutably, so the
        // cp_slack logic is inlined over the `downstream` field here.
        let downstream: &[f64] = &self.downstream;
        let entries = &mut self.entries;
        entries.clear();
        for (i, v) in ctx.jobs.iter().enumerate() {
            for k in v.job.k_min..=v.job.k_max {
                let p = v.job.marginal(k);
                let qualifies = p + 1e-9 >= rho || v.overdue;
                if !qualifies {
                    break; // marginals decrease in k
                }
                let slack = if downstream.is_empty() {
                    v.slack_left(ctx.t)
                } else {
                    v.slack_left(ctx.t) - downstream.get(v.job.id).copied().unwrap_or(0.0)
                };
                entries.push((p, slack, i, k));
            }
        }
        // Unstable sort is order-identical here — the (view index, k) tail
        // of the key makes every entry distinct — and keeps the steady-state
        // decide loop allocation-free (`sort_by` allocates a merge buffer).
        entries.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap()
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(a.2.cmp(&b.2))
                .then(a.3.cmp(&b.3))
        });
        let granted = &mut self.granted;
        granted.clear();
        granted.resize(ctx.jobs.len(), 0);
        let mut used = 0usize;
        for &(_, _, i, k) in entries.iter() {
            if used >= m_t {
                break;
            }
            if granted[i] == k - 1 {
                granted[i] = k;
                used += 1;
            }
        }
        out.capacity = m_t;
        out.alloc.clear();
        for (i, &k) in granted.iter().enumerate() {
            if k > 0 {
                out.alloc.push((ctx.jobs[i].job.id, k));
            }
        }
    }
}

impl<M: Matcher> Policy for CarbonFlex<M> {
    fn name(&self) -> &'static str {
        "CarbonFlex"
    }

    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        // Degradation ladder (see `crate::faults`): fresh signal → normal
        // CBR decision; bounded-stale signal → decide on the last-known-good
        // forecast slot; dark signal → carbon-agnostic fallback.
        let q = match ctx.forecaster.signal_state(ctx.t) {
            SignalState::Fresh => ctx.t,
            SignalState::Stale { last_good } => {
                self.degraded.stale += 1;
                last_good
            }
            SignalState::Dark => {
                self.degraded.fallback += 1;
                self.fallback.decide_into(ctx, out);
                return;
            }
        };
        let state = Self::state_at(ctx, q);
        let k = self.params.knn_k;
        self.matcher.top_k_into(&state, k, &mut self.neighbors);
        let m_t = self.provision(ctx);
        let rho = self.threshold();
        self.schedule(ctx, m_t, rho, out);
    }

    fn degradation(&self) -> DegradationCounters {
        self.degraded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::learning::kb::{Case, KnowledgeBase};
    use crate::sched::JobView;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.03, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    fn kb_with(cap_low: usize, cap_high: usize) -> KnowledgeBase {
        // Cases: at low CI provision high, at high CI provision low.
        let mut kb = KnowledgeBase::new();
        for i in 0..20 {
            let (ci, cap, rho) = if i % 2 == 0 {
                (60.0, cap_high, 0.5) // clean: scale out
            } else {
                (500.0, cap_low, 1.01) // dirty: idle
            };
            kb.push(Case {
                recorded_at: i,
                state: StateVector::from_raw(ci, 0.0, 0.0, &[2, 0, 0], 0.7),
                capacity: cap,
                rho,
            });
        }
        kb.rebuild();
        kb
    }

    fn ctx_at<'a>(
        t: usize,
        views: &'a [JobView<'a>],
        f: &'a Forecaster,
        violations: f64,
    ) -> SlotCtx<'a> {
        // Leaked so the columnar mirror outlives the returned ctx; a few
        // dozen bytes per test call.
        let cols: &'static crate::sched::JobViewCols =
            Box::leak(Box::new(crate::sched::JobViewCols::from_views(views)));
        SlotCtx {
            t,
            jobs: views,
            cols,
            forecaster: f,
            max_capacity: 20,
            num_queues: 3,
            prev_capacity: 10,
            prev_used: 6,
            recent_violation_rate: violations,
        }
    }

    #[test]
    fn mimics_clean_vs_dirty_decisions() {
        // Trace: slot 0 clean, slot 12 dirty.
        let mut hourly = vec![500.0; 24];
        hourly[0] = 60.0;
        let f = Forecaster::perfect(CarbonTrace::new("x", hourly));
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let mut cf = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        // Clean slot → high capacity, scheduling happens.
        let d0 = cf.decide(&ctx_at(0, &views, &f, 0.0));
        assert!(d0.capacity >= 4, "clean capacity {}", d0.capacity);
        assert!(!d0.alloc.is_empty());
        // Dirty slot → low capacity, idle.
        let d1 = cf.decide(&ctx_at(12, &views, &f, 0.0));
        assert!(d1.capacity <= 4, "dirty capacity {}", d1.capacity);
        assert!(d1.alloc.is_empty(), "scheduled {:?} in dirty slot", d1.alloc);
    }

    #[test]
    fn violation_fallback_provisions_max_when_far() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![250.0; 24]));
        let jobs = vec![job(0, 0, 4.0, 24.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        // KB with states far away from the query (extreme queue lengths).
        let mut kb = KnowledgeBase::new();
        kb.push(Case {
            recorded_at: 0,
            state: StateVector::from_raw(700.0, 200.0, 1.0, &[100, 100, 100], 0.0),
            capacity: 1,
            rho: 1.01,
        });
        kb.rebuild();
        let mut cf = CarbonFlex::new(
            kb,
            CarbonFlexParams {
                knn_k: 5,
                violation_tolerance: 0.1,
                distance_bound: 0.5,
                ..Default::default()
            },
        );
        // Violations high + far matches → full M.
        let d = cf.decide(&ctx_at(0, &views, &f, 0.5));
        assert_eq!(d.capacity, 20);
    }

    #[test]
    fn violation_fallback_takes_max_of_matches() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![60.0; 24]));
        let jobs = vec![job(0, 0, 4.0, 24.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let mut cf = CarbonFlex::new(kb_with(2, 8), CarbonFlexParams::default());
        let d = cf.decide(&ctx_at(0, &views, &f, 0.9));
        // max of the matched capacities (no prev-capacity ratchet) = 8.
        assert_eq!(d.capacity, 8);
    }

    #[test]
    fn empty_kb_falls_back_to_agnostic() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 2.0, 6.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 2.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let mut cf = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        let d = cf.decide(&ctx_at(0, &views, &f, 0.0));
        assert_eq!(d.capacity, 20);
        assert_eq!(d.alloc.len(), 1);
    }

    #[test]
    fn schedule_gives_base_before_scaling() {
        // m_t = 3, two jobs: both must get k=1 before either gets k=2.
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);
        let mut cf = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        let mut d = Decision::default();
        cf.schedule(&ctx, 3, 0.0, &mut d);
        let ks: std::collections::HashMap<usize, usize> = d.alloc.into_iter().collect();
        assert!(ks[&0] >= 1 && ks[&1] >= 1);
        assert_eq!(ks[&0] + ks[&1], 3);
    }

    #[test]
    fn overdue_jobs_bypass_threshold() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 2.0, 0.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 2.0, prev_alloc: 0, overdue: true, eligible_since: j.arrival })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);
        // Threshold above 1 normally blocks everything; overdue must pass.
        let mut cf = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        let mut d = Decision::default();
        cf.schedule(&ctx, 5, 1.01, &mut d);
        assert!(!d.alloc.is_empty());
    }

    #[test]
    fn critical_path_reorders_schedule_toward_deep_parents() {
        // Two jobs with one shared profile, so every marginal ties and the
        // slack key decides the order. Flat slack: job 1 (20h) is tighter
        // than job 0 (24h). Critical-path mode knows job 0 gates a 6-hour
        // downstream chain → effective slack 18h < 20h, so it wins the
        // single granted server instead.
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 4.0, 24.0), job(1, 0, 4.0, 20.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);

        let mut flat = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        let mut d = Decision::default();
        flat.schedule(&ctx, 1, 0.0, &mut d);
        assert_eq!(d.alloc, vec![(1, 1)], "flat EDF must pick the tighter deadline");

        let mut dag = CarbonFlex::with_critical_path(
            KnowledgeBase::new(),
            CarbonFlexParams::default(),
            vec![6.0, 0.0],
        );
        dag.schedule(&ctx, 1, 0.0, &mut d);
        assert_eq!(d.alloc, vec![(0, 1)], "deep parent must outrank the tighter leaf");
    }

    #[test]
    fn critical_path_slack_widens_the_urgency_floor() {
        // slack_left(0) = 5h: outside the 2h urgency window in flat mode,
        // inside it once a 4-hour downstream tail is charged to the job.
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 2.0, 5.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 2.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);
        let flat = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        assert_eq!(flat.urgent_floor(&ctx), 0);
        let dag = CarbonFlex::with_critical_path(
            KnowledgeBase::new(),
            CarbonFlexParams::default(),
            vec![4.0],
        );
        assert_eq!(dag.urgent_floor(&ctx), 1);
        // And cp_slack never exceeds the flat slack (tails are ≥ 0).
        for v in &views {
            assert!(dag.cp_slack(v, 0) <= v.slack_left(0));
            assert_eq!(flat.cp_slack(v, 0).to_bits(), v.slack_left(0).to_bits());
        }
    }

    #[test]
    fn aggregators_resolve_from_keys() {
        // Pure key resolution (no process-global env mutation in tests).
        assert_eq!(CapacityAgg::from_key(None), CapacityAgg::WeightedMean);
        assert_eq!(CapacityAgg::from_key(Some("wmean")), CapacityAgg::WeightedMean);
        assert_eq!(CapacityAgg::from_key(Some("min")), CapacityAgg::Min);
        assert_eq!(CapacityAgg::from_key(Some("max")), CapacityAgg::Max);
        assert_eq!(CapacityAgg::from_key(Some("median")), CapacityAgg::Median);
        assert_eq!(RhoAgg::from_key(None), RhoAgg::Min);
        assert_eq!(RhoAgg::from_key(Some("median")), RhoAgg::Median);
        assert_eq!(RhoAgg::from_key(Some("max")), RhoAgg::Max);
        assert_eq!(RhoAgg::from_key(Some("nonsense")), RhoAgg::Min);
    }

    #[test]
    fn match_batch_segments_equal_per_slot_queries() {
        let mut cf = CarbonFlex::new(kb_with(2, 9), CarbonFlexParams::default());
        let states: Vec<StateVector> = [60.0, 500.0, 250.0, 60.0]
            .iter()
            .map(|&ci| StateVector::from_raw(ci, 0.0, 0.0, &[2, 0, 0], 0.7))
            .collect();
        let mut out = Vec::new();
        let mut offsets = Vec::new();
        cf.match_batch(&states, &mut out, &mut offsets);
        assert_eq!(offsets.len(), states.len() + 1);
        let mut single = Vec::new();
        for (i, s) in states.iter().enumerate() {
            cf.matcher.top_k_into(s, cf.params.knn_k, &mut single);
            let seg = &out[offsets[i]..offsets[i + 1]];
            assert_eq!(seg.len(), single.len(), "state {i}");
            for (a, b) in seg.iter().zip(&single) {
                assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "state {i}");
                assert_eq!(a.capacity, b.capacity);
                assert_eq!(a.rho.to_bits(), b.rho.to_bits());
            }
        }
    }

    #[test]
    fn degradation_ladder_stale_then_fallback() {
        use crate::faults::SignalOutage;
        // Slot 0 clean, everything after dirty; outage covers [1, 20).
        let mut hourly = vec![500.0; 24];
        hourly[0] = 60.0;
        let trace = CarbonTrace::new("x", hourly);
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let masked = Forecaster::perfect(trace.clone())
            .with_outages(&[SignalOutage { start: 1, len: 19 }], 3, 24);
        let mut cf = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        assert_eq!(cf.degradation(), crate::sched::DegradationCounters::default());
        // t=2 is stale (last good = 0, 2 back ≤ 3): decides on slot 0's
        // clean signal → scale-out capacity, and the stale counter ticks.
        let d_stale = cf.decide(&ctx_at(2, &views, &masked, 0.0));
        assert!(d_stale.capacity >= 4, "stale capacity {}", d_stale.capacity);
        assert_eq!(cf.degradation().stale, 1);
        assert_eq!(cf.degradation().fallback, 0);
        // t=10 is dark (last good 10 slots back > 3): carbon-agnostic
        // fallback — full capacity, FCFS base allocations.
        let d_dark = cf.decide(&ctx_at(10, &views, &masked, 0.0));
        let mut agnostic = CarbonAgnostic;
        let want = agnostic.decide(&ctx_at(10, &views, &masked, 0.0));
        assert_eq!(d_dark.capacity, want.capacity);
        assert_eq!(d_dark.alloc, want.alloc);
        assert_eq!(cf.degradation().fallback, 1);
        // A fresh slot after the outage behaves exactly as without a mask.
        let mut clean_cf = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        let clean_f = Forecaster::perfect(trace);
        let d_after = cf.decide(&ctx_at(21, &views, &masked, 0.0));
        let d_clean = clean_cf.decide(&ctx_at(21, &views, &clean_f, 0.0));
        assert_eq!(d_after.capacity, d_clean.capacity);
        assert_eq!(d_after.alloc, d_clean.alloc);
        assert_eq!(cf.degradation().stale, 1);
    }

    #[test]
    fn decide_into_reuses_buffers_and_matches_decide() {
        // The buffer-reusing entry point must return the same decision as
        // the allocating convenience wrapper, slot after slot.
        let mut hourly = vec![500.0; 24];
        hourly[0] = 60.0;
        let f = Forecaster::perfect(CarbonTrace::new("x", hourly));
        let jobs: Vec<Job> = (0..3).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false, eligible_since: j.arrival })
            .collect();
        let mut a = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        let mut b = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        let mut out = Decision::default();
        for t in [0usize, 5, 12, 0, 12] {
            let ctx = ctx_at(t, &views, &f, 0.0);
            out.capacity = usize::MAX; // stale garbage the impl must overwrite
            a.decide_into(&ctx, &mut out);
            let fresh = b.decide(&ctx);
            assert_eq!(out.capacity, fresh.capacity, "t={t}");
            assert_eq!(out.alloc, fresh.alloc, "t={t}");
        }
    }
}
