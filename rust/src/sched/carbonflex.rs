//! CarbonFlex runtime provisioning (Algorithm 2) and scheduling
//! (Algorithm 3).
//!
//! At each slot the policy computes the Table 2 state, queries the knowledge
//! base for the top-k closest historical oracle decisions (case-based
//! reasoning), and mimics them:
//!
//! - **Provisioning φ (Alg. 2)**: the capacity is the mean of the matched
//!   capacities; if recent delay violations exceed the tolerance ε, fall
//!   back to the max of the matches (and, when matches are also distant
//!   — dist > δ — provision full M, i.e. carbon-agnostic).
//! - **Scheduling ψ (Alg. 3)**: allocate server increments whose marginal
//!   throughput `p_j(k)` meets the learned threshold ρ, ordered by marginal
//!   throughput with remaining-slack tie-breaks, until m_t is filled. Base
//!   allocations (`p = 1`) sort first, so no job is starved before any job
//!   scales, exactly as in Algorithm 1.
//!
//! The matcher backend is pluggable: the native KD-tree, or the AOT-compiled
//! Pallas kernel executed via PJRT (`runtime::matcher`) — Python stays off
//! the request path either way.

use crate::learning::kb::{Matcher, Neighbor};
use crate::learning::state::StateVector;
use crate::sched::{Decision, Policy, SlotCtx};

/// Tunables for Algorithm 2.
#[derive(Debug, Clone, Copy)]
pub struct CarbonFlexParams {
    /// Neighbours to match (paper: k = 5).
    pub knn_k: usize,
    /// Violation tolerance ε on the recent delay-violation rate.
    pub violation_tolerance: f64,
    /// Expected-distance bound δ: matches farther than this are distrusted.
    pub distance_bound: f64,
    /// Urgency look-ahead (hours): provisioning never drops below the base
    /// allocation of jobs whose remaining slack is within this window. This
    /// is the feedback the paper describes as "considering the utility of
    /// these decisions in previous time slots" — without it, a mimicked
    /// low-capacity decision can push a cohort over its deadline cliff and
    /// force dirty-slot runs.
    pub urgency_window: f64,
}

impl Default for CarbonFlexParams {
    fn default() -> Self {
        CarbonFlexParams {
            knn_k: 5,
            violation_tolerance: 0.2,
            distance_bound: 1.5,
            urgency_window: 2.0,
        }
    }
}

/// The CarbonFlex online policy, generic over the matcher backend (native
/// KD-tree knowledge base, or the PJRT-executed Pallas kernel).
pub struct CarbonFlex<M: Matcher> {
    matcher: M,
    params: CarbonFlexParams,
}

impl<M: Matcher> CarbonFlex<M> {
    pub fn new(matcher: M, params: CarbonFlexParams) -> Self {
        CarbonFlex { matcher, params }
    }

    /// Build the Table 2 state for the current slot.
    fn state_of(ctx: &SlotCtx) -> StateVector {
        let ci = ctx.forecaster.predict(ctx.t);
        let ci_prev = if ctx.t == 0 { ci } else { ctx.forecaster.predict(ctx.t - 1) };
        StateVector::from_raw(
            ci,
            ci - ci_prev,
            ctx.forecaster.day_ahead_rank(ctx.t),
            &ctx.queue_lengths(),
            ctx.mean_elasticity(),
        )
    }

    /// Base servers needed by jobs about to exhaust their slack.
    fn urgent_floor(&self, ctx: &SlotCtx) -> usize {
        ctx.jobs
            .iter()
            .filter(|v| v.slack_left(ctx.t) <= self.params.urgency_window)
            .map(|v| v.job.k_min)
            .sum()
    }

    /// Algorithm 2: the provisioning decision m_t.
    fn provision(&self, ctx: &SlotCtx, matches: &[Neighbor]) -> usize {
        let floor = self.urgent_floor(ctx).min(ctx.max_capacity);
        if matches.is_empty() {
            return ctx.max_capacity; // no knowledge → carbon-agnostic
        }
        let v = ctx.recent_violation_rate;
        let eps = self.params.violation_tolerance;
        let min_dist = matches[0].dist;
        if min_dist > self.params.distance_bound && v > eps {
            // Far from anything we have seen AND hurting SLOs: full capacity.
            return ctx.max_capacity;
        }
        if v > eps {
            // Violating: take the most generous of the matched capacities
            // (not the previous provisioning — that would ratchet the
            // cluster up permanently through dirty periods).
            return matches
                .iter()
                .map(|m| m.capacity)
                .max()
                .unwrap_or(ctx.max_capacity)
                .max(floor)
                .min(ctx.max_capacity);
        }
        // Nominal aggregation over the matched capacities, selectable for
        // the ablation bench (default: inverse-distance-weighted mean).
        let agg = match std::env::var("CARBONFLEX_AGG").as_deref() {
            Ok("min") => matches.iter().map(|m| m.capacity).min().unwrap_or(0) as f64,
            Ok("max") => matches.iter().map(|m| m.capacity).max().unwrap_or(0) as f64,
            Ok("median") => {
                let mut caps: Vec<usize> = matches.iter().map(|m| m.capacity).collect();
                caps.sort_unstable();
                caps[caps.len() / 2] as f64
            }
            _ => {
                let mut num = 0.0;
                let mut den = 0.0;
                for m in matches {
                    let w = 1.0 / (m.dist + 1e-3);
                    num += w * m.capacity as f64;
                    den += w;
                }
                num / den
            }
        };
        (agg.round() as usize).max(floor).min(ctx.max_capacity)
    }

    /// Aggregate the matched thresholds (selectable for the ablation bench;
    /// default: median, robust to the RHO_IDLE sentinel mixing with real
    /// marginals).
    fn threshold(matches: &[Neighbor]) -> f64 {
        if matches.is_empty() {
            return 0.0; // schedule anything
        }
        let mut rhos: Vec<f64> = matches.iter().map(|m| m.rho).collect();
        rhos.sort_by(|a, b| a.partial_cmp(b).unwrap());
        match std::env::var("CARBONFLEX_RHO").as_deref() {
            Ok("median") => rhos[rhos.len() / 2],
            Ok("max") => rhos[rhos.len() - 1],
            // Default: min — the most permissive matched threshold. The
            // oracle's recorded ρ is the marginal of the LAST server it
            // granted; taking the neighbourhood minimum lets leftover clean
            // capacity be used for scaling instead of idling (fewer forced
            // dirty runs, see the fig6 ablation bench).
            _ => rhos[0],
        }
    }

    /// Algorithm 3: fill m_t with the highest-marginal server increments at
    /// or above the threshold ρ.
    fn schedule(ctx: &SlotCtx, m_t: usize, rho: f64) -> Vec<(usize, usize)> {
        // Candidate server increments (j, k) with p_j(k) ≥ ρ.
        // Sort key: marginal desc, remaining slack asc (EDF), id.
        let mut entries: Vec<(f64, f64, usize, usize)> = Vec::new();
        for (i, v) in ctx.jobs.iter().enumerate() {
            for k in v.job.k_min..=v.job.k_max {
                let p = v.job.marginal(k);
                let qualifies = p + 1e-9 >= rho || v.overdue;
                if !qualifies {
                    break; // marginals decrease in k
                }
                entries.push((p, v.slack_left(ctx.t), i, k));
            }
        }
        entries.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap()
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(a.2.cmp(&b.2))
                .then(a.3.cmp(&b.3))
        });
        let mut granted = vec![0usize; ctx.jobs.len()];
        let mut used = 0usize;
        for (_, _, i, k) in entries {
            if used >= m_t {
                break;
            }
            if granted[i] == k - 1 {
                granted[i] = k;
                used += 1;
            }
        }
        granted
            .iter()
            .enumerate()
            .filter(|(_, &k)| k > 0)
            .map(|(i, &k)| (ctx.jobs[i].job.id, k))
            .collect()
    }
}

impl<M: Matcher> Policy for CarbonFlex<M> {
    fn name(&self) -> &'static str {
        "CarbonFlex"
    }

    fn decide(&mut self, ctx: &SlotCtx) -> Decision {
        let state = Self::state_of(ctx);
        let matches = self.matcher.top_k(&state, self.params.knn_k);
        let m_t = self.provision(ctx, &matches);
        let rho = Self::threshold(&matches);
        let alloc = Self::schedule(ctx, m_t, rho);
        Decision { capacity: m_t, alloc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::learning::kb::{Case, KnowledgeBase};
    use crate::sched::JobView;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize, length: f64, slack: f64) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.03, 4),
            watts_per_unit: 40.0,
        }
    }

    fn kb_with(cap_low: usize, cap_high: usize) -> KnowledgeBase {
        // Cases: at low CI provision high, at high CI provision low.
        let mut kb = KnowledgeBase::new();
        for i in 0..20 {
            let (ci, cap, rho) = if i % 2 == 0 {
                (60.0, cap_high, 0.5) // clean: scale out
            } else {
                (500.0, cap_low, 1.01) // dirty: idle
            };
            kb.push(Case {
                recorded_at: i,
                state: StateVector::from_raw(ci, 0.0, 0.0, &[2, 0, 0], 0.7),
                capacity: cap,
                rho,
            });
        }
        kb.rebuild();
        kb
    }

    fn ctx_at<'a>(
        t: usize,
        views: &'a [JobView<'a>],
        f: &'a Forecaster,
        violations: f64,
    ) -> SlotCtx<'a> {
        SlotCtx {
            t,
            jobs: views,
            forecaster: f,
            max_capacity: 20,
            num_queues: 3,
            prev_capacity: 10,
            prev_used: 6,
            recent_violation_rate: violations,
        }
    }

    #[test]
    fn mimics_clean_vs_dirty_decisions() {
        // Trace: slot 0 clean, slot 12 dirty.
        let mut hourly = vec![500.0; 24];
        hourly[0] = 60.0;
        let f = Forecaster::perfect(CarbonTrace::new("x", hourly));
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false })
            .collect();
        let mut cf = CarbonFlex::new(kb_with(0, 8), CarbonFlexParams::default());
        // Clean slot → high capacity, scheduling happens.
        let d0 = cf.decide(&ctx_at(0, &views, &f, 0.0));
        assert!(d0.capacity >= 4, "clean capacity {}", d0.capacity);
        assert!(!d0.alloc.is_empty());
        // Dirty slot → low capacity, idle.
        let d1 = cf.decide(&ctx_at(12, &views, &f, 0.0));
        assert!(d1.capacity <= 4, "dirty capacity {}", d1.capacity);
        assert!(d1.alloc.is_empty(), "scheduled {:?} in dirty slot", d1.alloc);
    }

    #[test]
    fn violation_fallback_provisions_max_when_far() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![250.0; 24]));
        let jobs = vec![job(0, 0, 4.0, 24.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false })
            .collect();
        // KB with states far away from the query (extreme queue lengths).
        let mut kb = KnowledgeBase::new();
        kb.push(Case {
            recorded_at: 0,
            state: StateVector::from_raw(700.0, 200.0, 1.0, &[100, 100, 100], 0.0),
            capacity: 1,
            rho: 1.01,
        });
        kb.rebuild();
        let mut cf = CarbonFlex::new(
            kb,
            CarbonFlexParams {
                knn_k: 5,
                violation_tolerance: 0.1,
                distance_bound: 0.5,
                ..Default::default()
            },
        );
        // Violations high + far matches → full M.
        let d = cf.decide(&ctx_at(0, &views, &f, 0.5));
        assert_eq!(d.capacity, 20);
    }

    #[test]
    fn violation_fallback_takes_max_of_matches() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![60.0; 24]));
        let jobs = vec![job(0, 0, 4.0, 24.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false })
            .collect();
        let mut cf = CarbonFlex::new(kb_with(2, 8), CarbonFlexParams::default());
        let d = cf.decide(&ctx_at(0, &views, &f, 0.9));
        // max of the matched capacities (no prev-capacity ratchet) = 8.
        assert_eq!(d.capacity, 8);
    }

    #[test]
    fn empty_kb_falls_back_to_agnostic() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 2.0, 6.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 2.0, prev_alloc: 0, overdue: false })
            .collect();
        let mut cf = CarbonFlex::new(KnowledgeBase::new(), CarbonFlexParams::default());
        let d = cf.decide(&ctx_at(0, &views, &f, 0.0));
        assert_eq!(d.capacity, 20);
        assert_eq!(d.alloc.len(), 1);
    }

    #[test]
    fn schedule_gives_base_before_scaling() {
        // m_t = 3, two jobs: both must get k=1 before either gets k=2.
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs: Vec<Job> = (0..2).map(|i| job(i, 0, 4.0, 24.0)).collect();
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 4.0, prev_alloc: 0, overdue: false })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);
        let alloc = CarbonFlex::<KnowledgeBase>::schedule(&ctx, 3, 0.0);
        let ks: std::collections::HashMap<usize, usize> = alloc.into_iter().collect();
        assert!(ks[&0] >= 1 && ks[&1] >= 1);
        assert_eq!(ks[&0] + ks[&1], 3);
    }

    #[test]
    fn overdue_jobs_bypass_threshold() {
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 24]));
        let jobs = vec![job(0, 0, 2.0, 0.0)];
        let views: Vec<JobView> = jobs
            .iter()
            .map(|j| JobView { job: j, remaining: 2.0, prev_alloc: 0, overdue: true })
            .collect();
        let ctx = ctx_at(0, &views, &f, 0.0);
        // Threshold above 1 normally blocks everything; overdue must pass.
        let alloc = CarbonFlex::<KnowledgeBase>::schedule(&ctx, 5, 1.01);
        assert!(!alloc.is_empty());
    }
}
