//! Carbon-Agnostic baseline (paper §6.1): the status quo — FCFS at full
//! cluster capacity, no elastic scaling, no carbon awareness. Every figure's
//! savings percentages are computed against this policy's emissions.

use crate::sched::{Decision, Policy, SlotCtx};

/// FCFS, base-scale, full-capacity scheduler.
#[derive(Debug, Default)]
pub struct CarbonAgnostic;

impl Policy for CarbonAgnostic {
    fn name(&self) -> &'static str {
        "Carbon-Agnostic"
    }

    fn decide_into(&mut self, ctx: &SlotCtx, out: &mut Decision) {
        out.capacity = ctx.max_capacity;
        out.alloc.clear();
        let mut used = 0usize;
        // Jobs arrive sorted by arrival time; FCFS = take them in order.
        // §Perf: only ids and k_min matter here, so the loop reads the two
        // contiguous columns instead of dereferencing each `&Job`.
        for (&id, &k_min) in ctx.cols.id.iter().zip(&ctx.cols.k_min) {
            let k = k_min as usize;
            if used + k > ctx.max_capacity {
                continue; // queue (FCFS head-of-line within capacity)
            }
            used += k;
            out.alloc.push((id, k));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::forecast::Forecaster;
    use crate::carbon::trace::CarbonTrace;
    use crate::workload::job::Job;
    use crate::workload::profile::ScalingProfile;

    fn job(id: usize, arrival: usize) -> Job {
        Job {
            id,
            workload: "t",
            workload_idx: 0,
            arrival,
            length_hours: 2.0,
            queue: 0,
            slack_hours: 6.0,
            k_min: 1,
            k_max: 4,
            profile: ScalingProfile::from_comm_ratio(0.05, 4),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    #[test]
    fn fcfs_respects_capacity_and_never_scales() {
        let jobs: Vec<Job> = (0..5).map(|i| job(i, 0)).collect();
        let views: Vec<crate::sched::JobView> = jobs
            .iter()
            .map(|j| {
                crate::sched::JobView {
                    job: j,
                    remaining: 2.0,
                    prev_alloc: 0,
                    overdue: false,
                    eligible_since: j.arrival,
                }
            })
            .collect();
        let f = Forecaster::perfect(CarbonTrace::new("x", vec![100.0; 10]));
        let cols = crate::sched::JobViewCols::from_views(&views);
        let ctx = SlotCtx {
            t: 0,
            jobs: &views,
            cols: &cols,
            forecaster: &f,
            max_capacity: 3,
            num_queues: 3,
            prev_capacity: 3,
            prev_used: 0,
            recent_violation_rate: 0.0,
        };
        let d = CarbonAgnostic.decide(&ctx);
        assert_eq!(d.alloc.len(), 3);
        assert!(d.alloc.iter().all(|&(_, k)| k == 1));
        // FCFS: earliest ids win.
        assert_eq!(d.alloc.iter().map(|&(id, _)| id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
