//! Elastic scaling profiles and the Table 3 workload catalog.
//!
//! A job's scaling behaviour is driven by its communication-per-unit-compute
//! ratio (paper §2.3): with ring-allreduce traffic `2(k−1)/k · Mem` per step
//! and per-step compute `C/k`, normalized throughput is
//!
//! `S(k) = k / (1 + 2r(k−1))`, with `r ∝ Mem / GFLOPs`
//!
//! which is concave with monotonically decreasing marginal throughput
//! `p(k) = S(k) − S(k−1)`, `p(1) = 1` — exactly the profile class for which
//! the paper's Theorem 4.1 guarantees oracle optimality. The catalog below
//! reproduces the paper's 13 workloads with their published communication
//! sizes (Table 3) and scalability classes (Fig. 2).

use crate::config::Hardware;

/// Scalability class from Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scalability {
    High,
    Moderate,
    Low,
}

impl Scalability {
    pub fn as_str(&self) -> &'static str {
        match self {
            Scalability::High => "High",
            Scalability::Moderate => "Moderate",
            Scalability::Low => "Low",
        }
    }
}

/// A normalized elastic scaling profile: marginal throughput per added
/// server, `p[0] = p(k_min) = 1`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingProfile {
    /// Marginal throughput of the (k_min+i)-th server, i = 0..len.
    marginal: Vec<f64>,
}

impl ScalingProfile {
    /// Build from a communication ratio `r` over scales `1..=k_max`.
    /// `r = 0` gives a perfectly linear profile.
    pub fn from_comm_ratio(r: f64, k_max: usize) -> Self {
        assert!(k_max >= 1);
        assert!(r >= 0.0);
        let s = |k: usize| -> f64 { k as f64 / (1.0 + 2.0 * r * (k as f64 - 1.0)) };
        let mut marginal = Vec::with_capacity(k_max);
        let mut prev = 0.0;
        for k in 1..=k_max {
            let cur = s(k);
            // Guard: numerical monotonicity (the analytic form can flatten
            // to ~0 for very large r; clamp at a tiny positive epsilon so
            // profiles stay strictly decreasing and positive).
            let m = (cur - prev).max(1e-6);
            marginal.push(m);
            prev = prev + m;
        }
        // Normalize so p(1) == 1 exactly.
        let p1 = marginal[0];
        for m in marginal.iter_mut() {
            *m /= p1;
        }
        ScalingProfile { marginal }
    }

    /// Explicit marginal vector (must start at 1.0 and be non-increasing).
    pub fn from_marginals(marginal: Vec<f64>) -> Self {
        assert!(!marginal.is_empty());
        assert!((marginal[0] - 1.0).abs() < 1e-9, "p(k_min) must be 1");
        for w in marginal.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "marginal throughput must be non-increasing");
            assert!(w[1] > 0.0);
        }
        ScalingProfile { marginal }
    }

    /// A perfectly inelastic profile (k_min == k_max == 1).
    pub fn inelastic() -> Self {
        ScalingProfile { marginal: vec![1.0] }
    }

    /// Maximum scale this profile supports.
    pub fn k_max(&self) -> usize {
        self.marginal.len()
    }

    /// Marginal throughput of the k-th server (1-based, k ≤ k_max).
    pub fn marginal(&self, k: usize) -> f64 {
        assert!(k >= 1 && k <= self.marginal.len(), "scale {k} out of range");
        self.marginal[k - 1]
    }

    /// Total normalized throughput at scale k (S(k) = Σ_{i≤k} p(i)); S(0)=0.
    pub fn throughput(&self, k: usize) -> f64 {
        assert!(k <= self.marginal.len());
        self.marginal[..k].iter().sum()
    }

    /// Mean elasticity metric used as a Table 2 state feature: the average
    /// marginal throughput across the profile (1.0 = perfectly linear).
    pub fn elasticity(&self) -> f64 {
        self.marginal.iter().sum::<f64>() / self.marginal.len() as f64
    }

    /// Truncate to a smaller maximum scale.
    pub fn truncated(&self, k_max: usize) -> ScalingProfile {
        assert!(k_max >= 1);
        let k = k_max.min(self.marginal.len());
        ScalingProfile { marginal: self.marginal[..k].to_vec() }
    }
}

/// One catalog entry: a named workload with its communication footprint,
/// compute intensity, and power draw.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub hardware: Hardware,
    /// Communication size per step, MB (Table 3).
    pub comm_mb: f64,
    /// Compute per step, GFLOPs (drives the comm ratio; §2.3's example:
    /// EffNet-S 8.37 GFLOPs / 82.7 MB, ResNet18 1.81 GFLOPs / 44.7 MB).
    pub gflops: f64,
    /// Scalability class (Table 3).
    pub scalability: Scalability,
    /// Active power per allocated server/accelerator, watts. GPU workloads
    /// are heterogeneous (§6.2: compute-dense jobs draw more).
    pub watts_per_unit: f64,
}

impl WorkloadSpec {
    /// Communication ratio r for the throughput model. κ converts MB/GFLOPs
    /// into the dimensionless ratio; calibrated so Table 3's High/Moderate/
    /// Low classes reproduce Fig. 2's curve shapes at k ≤ 16.
    pub fn comm_ratio(&self) -> f64 {
        const KAPPA: f64 = 0.018; // dimensionless per (MB/GFLOP)
        KAPPA * self.comm_mb / self.gflops
    }

    /// Build this workload's scaling profile up to `k_max`.
    pub fn profile(&self, k_max: usize) -> ScalingProfile {
        ScalingProfile::from_comm_ratio(self.comm_ratio(), k_max)
    }

    /// Ring-allreduce bytes moved per *hour* at scale k, in gigabits, used by
    /// the network-energy model (Eq. 3). Steps/hour is derived from compute:
    /// a fixed per-hardware step rate scaled by 1/GFLOPs.
    pub fn network_gbit_per_hour(&self, k: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let steps_per_hour = match self.hardware {
            Hardware::Cpu => 3.6e3 / self.gflops.max(0.05), // ~1 GFLOP/s/core budget
            Hardware::Gpu => 3.6e5 / self.gflops.max(0.05), // ~100 GFLOP/s/GPU budget
        };
        let bytes_per_step = 2.0 * (k as f64 - 1.0) / k as f64 * self.comm_mb * 1e6;
        bytes_per_step * steps_per_hour * 8.0 / 1e9 // gigabits
    }
}

/// The 13 workloads of Table 3. MPI workloads run on the CPU cluster
/// (profiled to k_max = 16), PyTorch workloads on the GPU cluster
/// (k_max = 8), matching §6.1.
#[rustfmt::skip] // keep the catalog one row per workload
pub fn catalog() -> Vec<WorkloadSpec> {
    use Hardware::*;
    use Scalability::*;
    vec![
        // --- MPI / CPU (comm sizes from Table 3) ---
        WorkloadSpec { name: "N-body(N=100k)", hardware: Cpu, comm_mb: 5.3, gflops: 50.0, scalability: High, watts_per_unit: 45.0 },
        WorkloadSpec { name: "N-body(N=10k)", hardware: Cpu, comm_mb: 0.53, gflops: 5.0, scalability: High, watts_per_unit: 42.0 },
        WorkloadSpec { name: "N-body(N=2k)", hardware: Cpu, comm_mb: 0.16, gflops: 0.4, scalability: Moderate, watts_per_unit: 40.0 },
        WorkloadSpec { name: "Heat(N=1k)", hardware: Cpu, comm_mb: 0.1, gflops: 0.25, scalability: Moderate, watts_per_unit: 38.0 },
        WorkloadSpec { name: "Jacobi(N=4k)", hardware: Cpu, comm_mb: 51.2, gflops: 8.0, scalability: Low, watts_per_unit: 36.0 },
        WorkloadSpec { name: "Jacobi(N=2k)", hardware: Cpu, comm_mb: 28.6, gflops: 4.0, scalability: Low, watts_per_unit: 35.0 },
        WorkloadSpec { name: "Jacobi(N=1k)", hardware: Cpu, comm_mb: 7.16, gflops: 1.0, scalability: Low, watts_per_unit: 34.0 },
        // --- PyTorch / GPU (model sizes from torchvision, §2.3 & Table 3) ---
        WorkloadSpec { name: "AlexNet", hardware: Gpu, comm_mb: 233.1, gflops: 0.71, scalability: Low, watts_per_unit: 150.0 },
        WorkloadSpec { name: "ResNet18", hardware: Gpu, comm_mb: 44.7, gflops: 1.81, scalability: Low, watts_per_unit: 180.0 },
        WorkloadSpec { name: "ResNet50", hardware: Gpu, comm_mb: 97.8, gflops: 4.09, scalability: Moderate, watts_per_unit: 230.0 },
        WorkloadSpec { name: "EffNetV2-M", hardware: Gpu, comm_mb: 170.5, gflops: 24.6, scalability: High, watts_per_unit: 290.0 },
        WorkloadSpec { name: "EffNet-S", hardware: Gpu, comm_mb: 82.7, gflops: 8.37, scalability: High, watts_per_unit: 270.0 },
        WorkloadSpec { name: "ViT-B/32", hardware: Gpu, comm_mb: 336.6, gflops: 4.41, scalability: Moderate, watts_per_unit: 250.0 },
    ]
}

/// Catalog filtered to one hardware class.
pub fn catalog_for(hardware: Hardware) -> Vec<WorkloadSpec> {
    catalog().into_iter().filter(|w| w.hardware == hardware).collect()
}

/// Default maximum profiled scale per hardware (§6.1: CPU 16, GPU 8).
pub fn default_k_max(hardware: Hardware) -> usize {
    match hardware {
        Hardware::Cpu => 16,
        Hardware::Gpu => 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_starts_at_one_and_decreases() {
        for w in catalog() {
            let p = w.profile(16);
            assert!((p.marginal(1) - 1.0).abs() < 1e-9, "{}", w.name);
            for k in 2..=16 {
                assert!(
                    p.marginal(k) <= p.marginal(k - 1) + 1e-9,
                    "{} not decreasing at k={k}",
                    w.name
                );
                assert!(p.marginal(k) > 0.0);
            }
        }
    }

    #[test]
    fn throughput_is_cumulative() {
        let p = ScalingProfile::from_comm_ratio(0.05, 8);
        assert_eq!(p.throughput(0), 0.0);
        let manual: f64 = (1..=5).map(|k| p.marginal(k)).sum();
        assert!((p.throughput(5) - manual).abs() < 1e-12);
    }

    #[test]
    fn linear_profile_when_no_comm() {
        let p = ScalingProfile::from_comm_ratio(0.0, 8);
        for k in 1..=8 {
            assert!((p.marginal(k) - 1.0).abs() < 1e-9);
        }
        assert!((p.elasticity() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scalability_classes_order_elasticity() {
        // Class averages must be ordered High > Moderate > Low at k=8.
        let avg = |class: Scalability| {
            let ws: Vec<_> = catalog().into_iter().filter(|w| w.scalability == class).collect();
            ws.iter().map(|w| w.profile(8).elasticity()).sum::<f64>() / ws.len() as f64
        };
        let (h, m, l) = (avg(Scalability::High), avg(Scalability::Moderate), avg(Scalability::Low));
        assert!(h > m && m > l, "elasticity ordering violated: H={h} M={m} L={l}");
        assert!(h > 0.55, "High class should stay fairly scalable: {h}");
        assert!(l < 0.45, "Low class should saturate: {l}");
    }

    #[test]
    fn effnet_scales_better_than_resnet18() {
        // §2.3's worked example: 9.8 MB/GFLOP vs 24.6 MB/GFLOP.
        let cat = catalog();
        let eff = cat.iter().find(|w| w.name == "EffNet-S").unwrap();
        let res = cat.iter().find(|w| w.name == "ResNet18").unwrap();
        assert!(eff.comm_ratio() < res.comm_ratio());
        assert!(eff.profile(8).throughput(8) > res.profile(8).throughput(8));
    }

    #[test]
    fn catalog_matches_table3() {
        let cat = catalog();
        assert_eq!(cat.len(), 13);
        assert_eq!(cat.iter().filter(|w| w.hardware == Hardware::Cpu).count(), 7);
        assert_eq!(cat.iter().filter(|w| w.hardware == Hardware::Gpu).count(), 6);
        let vit = cat.iter().find(|w| w.name == "ViT-B/32").unwrap();
        assert_eq!(vit.comm_mb, 336.6);
    }

    #[test]
    fn gpu_power_correlates_with_scalability() {
        // §6.2: scaling approaches win on GPU because high-marginal-throughput
        // jobs draw more power. Verify the catalog encodes that correlation.
        let gpus = catalog_for(Hardware::Gpu);
        let avg_w = |class: Scalability| {
            let ws: Vec<_> = gpus.iter().filter(|w| w.scalability == class).collect();
            ws.iter().map(|w| w.watts_per_unit).sum::<f64>() / ws.len() as f64
        };
        assert!(avg_w(Scalability::High) > avg_w(Scalability::Low));
    }

    #[test]
    fn network_traffic_zero_at_one_server() {
        for w in catalog() {
            assert_eq!(w.network_gbit_per_hour(1), 0.0);
            assert!(w.network_gbit_per_hour(4) > 0.0);
            // More servers → more total traffic.
            assert!(w.network_gbit_per_hour(8) > w.network_gbit_per_hour(2));
        }
    }

    #[test]
    fn truncation() {
        let p = ScalingProfile::from_comm_ratio(0.1, 16).truncated(4);
        assert_eq!(p.k_max(), 4);
    }

    #[test]
    #[should_panic]
    fn marginal_out_of_range_panics() {
        ScalingProfile::from_comm_ratio(0.1, 4).marginal(5);
    }

    #[test]
    fn explicit_marginals_validated() {
        let ok = ScalingProfile::from_marginals(vec![1.0, 0.8, 0.5]);
        assert_eq!(ok.k_max(), 3);
    }

    #[test]
    #[should_panic]
    fn increasing_marginals_rejected() {
        ScalingProfile::from_marginals(vec![1.0, 0.5, 0.8]);
    }
}
