//! The job model (paper §3): elastic distributed batch jobs with an arrival
//! time, a base-scale length, a queue-derived slack, and a scaling profile.

use crate::workload::profile::ScalingProfile;

/// Unique job identifier within a trace.
pub type JobId = usize;

/// An elastic batch job as submitted to the cluster.
///
/// `length_hours` is the job's execution time at its minimum scale `k_min`
/// (progress accrues at `S(k) = Σ p(i)` "base-hours per hour" when running at
/// scale k). `slack_hours` is the queue's maximum delay d_i: the job must
/// finish by `arrival + length + slack` (after which every policy force-runs
/// it to completion).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Catalog workload name (for power/network models and reporting).
    pub workload: &'static str,
    /// Index into the workload catalog.
    pub workload_idx: usize,
    /// Arrival slot (hours from trace start).
    pub arrival: usize,
    /// Base-scale execution length, hours.
    pub length_hours: f64,
    /// Queue index the job was submitted to.
    pub queue: usize,
    /// Maximum delay d_i from the queue config, hours.
    pub slack_hours: f64,
    /// Minimum servers (k_min ≥ 1).
    pub k_min: usize,
    /// Maximum servers (k_max ≥ k_min); k_min == k_max means non-elastic.
    pub k_max: usize,
    /// Normalized marginal-throughput profile over [1, k_max].
    pub profile: ScalingProfile,
    /// Active power per allocated server, watts.
    pub watts_per_unit: f64,
    /// Parent job ids: this job becomes eligible only once every parent has
    /// completed. Every parent id is strictly smaller than `id` (tracegen
    /// emits edges in submission order), so any trace is topologically
    /// sorted by construction. Empty for flat (independent) workloads —
    /// `Vec::new()` does not allocate, so flat jobs stay heap-identical to
    /// the pre-DAG model.
    pub deps: Vec<JobId>,
}

impl Job {
    /// Deadline slot: latest slot (inclusive) the job may still be running in
    /// if it respects its slack: arrival + ceil(length) + slack − 1.
    pub fn deadline_slot(&self) -> usize {
        self.arrival + (self.length_hours + self.slack_hours).ceil() as usize
    }

    /// Total work to complete, in base-hours.
    pub fn work(&self) -> f64 {
        self.length_hours
    }

    /// Progress rate (base-hours per hour) at scale k; 0 when suspended.
    pub fn rate(&self, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        assert!(
            k >= self.k_min && k <= self.k_max,
            "job {} scale {k} outside [{}, {}]",
            self.id,
            self.k_min,
            self.k_max
        );
        self.profile.throughput(k)
    }

    /// Marginal throughput of the k-th server.
    pub fn marginal(&self, k: usize) -> f64 {
        self.profile.marginal(k)
    }

    /// Is this job elastic at all?
    pub fn is_elastic(&self) -> bool {
        self.k_max > self.k_min
    }

    /// Mean elasticity (Table 2 state feature).
    pub fn elasticity(&self) -> f64 {
        self.profile.truncated(self.k_max).elasticity()
    }

    /// Minimum slots needed to finish if run at k_min continuously.
    pub fn min_slots(&self) -> usize {
        self.length_hours.ceil().max(1.0) as usize
    }
}

/// Longest downstream chain of `length_hours` below each job — the
/// critical-path tail the DAG-aware policies subtract from flat slack
/// (a job whose descendants still need `downstream[j]` base-hours has that
/// much less real slack than its own deadline suggests).
///
/// `downstream[j] = max over children c of (length_hours[c] + downstream[c])`
/// and `0.0` for sinks, computed in one reverse pass over the submission
/// order (valid because every edge points from a smaller id to a larger
/// one). For flat traces the result is all zeros, so
/// `cp_slack = slack − downstream` degenerates to flat slack exactly.
pub fn critical_path_downstream(jobs: &[Job]) -> Vec<f64> {
    let mut down = vec![0.0f64; jobs.len()];
    for j in (0..jobs.len()).rev() {
        debug_assert_eq!(jobs[j].id, j, "jobs must be in dense id order");
        let tail = jobs[j].length_hours + down[j];
        for &p in &jobs[j].deps {
            debug_assert!(p < j, "dep {p} of job {j} is not an earlier job");
            if tail > down[p] {
                down[p] = tail;
            }
        }
    }
    down
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profile::ScalingProfile;

    pub fn test_job(id: usize, arrival: usize, length: f64, slack: f64, k_max: usize) -> Job {
        Job {
            id,
            workload: "test",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max,
            profile: ScalingProfile::from_comm_ratio(0.05, k_max),
            watts_per_unit: 40.0,
            deps: Vec::new(),
        }
    }

    #[test]
    fn deadline_math() {
        let j = test_job(0, 10, 4.0, 6.0, 4);
        assert_eq!(j.deadline_slot(), 20);
    }

    #[test]
    fn rate_zero_when_suspended() {
        let j = test_job(0, 0, 2.0, 0.0, 4);
        assert_eq!(j.rate(0), 0.0);
        assert!((j.rate(1) - 1.0).abs() < 1e-9);
        assert!(j.rate(4) > j.rate(1));
    }

    #[test]
    #[should_panic]
    fn rate_above_kmax_panics() {
        test_job(0, 0, 2.0, 0.0, 4).rate(5);
    }

    #[test]
    fn elastic_flag() {
        let mut j = test_job(0, 0, 2.0, 0.0, 4);
        assert!(j.is_elastic());
        j.k_max = 1;
        j.profile = ScalingProfile::inelastic();
        assert!(!j.is_elastic());
    }

    #[test]
    fn min_slots_rounds_up() {
        assert_eq!(test_job(0, 0, 2.2, 0.0, 2).min_slots(), 3);
        assert_eq!(test_job(0, 0, 0.4, 0.0, 2).min_slots(), 1);
    }

    #[test]
    fn critical_path_flat_trace_is_all_zeros() {
        let jobs: Vec<Job> = (0..5).map(|i| test_job(i, 0, 2.0, 6.0, 4)).collect();
        assert_eq!(critical_path_downstream(&jobs), vec![0.0; 5]);
    }

    #[test]
    fn critical_path_chain_accumulates_lengths() {
        // 0 ← 1 ← 2 (chain): downstream[0] = len(1)+len(2), downstream[1] =
        // len(2), downstream[2] = 0.
        let mut jobs: Vec<Job> = vec![
            test_job(0, 0, 3.0, 6.0, 4),
            test_job(1, 0, 2.0, 6.0, 4),
            test_job(2, 0, 5.0, 6.0, 4),
        ];
        jobs[1].deps = vec![0];
        jobs[2].deps = vec![1];
        let down = critical_path_downstream(&jobs);
        assert_eq!(down, vec![7.0, 5.0, 0.0]);
    }

    #[test]
    fn critical_path_takes_longest_branch() {
        // Fan-out 0 → {1, 2}; job 2 is the longer branch.
        let mut jobs: Vec<Job> = vec![
            test_job(0, 0, 1.0, 6.0, 4),
            test_job(1, 0, 2.0, 6.0, 4),
            test_job(2, 0, 4.0, 6.0, 4),
        ];
        jobs[1].deps = vec![0];
        jobs[2].deps = vec![0];
        let down = critical_path_downstream(&jobs);
        assert_eq!(down, vec![4.0, 0.0, 0.0]);
        // Diamond tail: a reduce depending on both branches extends the max.
        let mut reduce = test_job(3, 0, 1.5, 6.0, 4);
        reduce.deps = vec![1, 2];
        let mut jobs = jobs;
        jobs.push(reduce);
        let down = critical_path_downstream(&jobs);
        assert_eq!(down, vec![5.5, 1.5, 1.5, 0.0]);
    }

    #[test]
    fn critical_path_parent_dominates_child_tail() {
        // Structural invariant the policies rely on: for every edge p → c,
        // downstream[p] ≥ length[c] + downstream[c].
        let mut jobs: Vec<Job> =
            (0..6).map(|i| test_job(i, 0, 1.0 + i as f64 * 0.5, 6.0, 4)).collect();
        jobs[2].deps = vec![0, 1];
        jobs[3].deps = vec![2];
        jobs[4].deps = vec![2];
        jobs[5].deps = vec![3, 4];
        let down = critical_path_downstream(&jobs);
        for (c, job) in jobs.iter().enumerate() {
            for &p in &job.deps {
                assert!(
                    down[p] >= job.length_hours + down[c] - 1e-12,
                    "edge {p}->{c}: {} < {} + {}",
                    down[p],
                    job.length_hours,
                    down[c]
                );
            }
        }
    }
}
