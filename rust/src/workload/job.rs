//! The job model (paper §3): elastic distributed batch jobs with an arrival
//! time, a base-scale length, a queue-derived slack, and a scaling profile.

use crate::workload::profile::ScalingProfile;

/// Unique job identifier within a trace.
pub type JobId = usize;

/// An elastic batch job as submitted to the cluster.
///
/// `length_hours` is the job's execution time at its minimum scale `k_min`
/// (progress accrues at `S(k) = Σ p(i)` "base-hours per hour" when running at
/// scale k). `slack_hours` is the queue's maximum delay d_i: the job must
/// finish by `arrival + length + slack` (after which every policy force-runs
/// it to completion).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Catalog workload name (for power/network models and reporting).
    pub workload: &'static str,
    /// Index into the workload catalog.
    pub workload_idx: usize,
    /// Arrival slot (hours from trace start).
    pub arrival: usize,
    /// Base-scale execution length, hours.
    pub length_hours: f64,
    /// Queue index the job was submitted to.
    pub queue: usize,
    /// Maximum delay d_i from the queue config, hours.
    pub slack_hours: f64,
    /// Minimum servers (k_min ≥ 1).
    pub k_min: usize,
    /// Maximum servers (k_max ≥ k_min); k_min == k_max means non-elastic.
    pub k_max: usize,
    /// Normalized marginal-throughput profile over [1, k_max].
    pub profile: ScalingProfile,
    /// Active power per allocated server, watts.
    pub watts_per_unit: f64,
}

impl Job {
    /// Deadline slot: latest slot (inclusive) the job may still be running in
    /// if it respects its slack: arrival + ceil(length) + slack − 1.
    pub fn deadline_slot(&self) -> usize {
        self.arrival + (self.length_hours + self.slack_hours).ceil() as usize
    }

    /// Total work to complete, in base-hours.
    pub fn work(&self) -> f64 {
        self.length_hours
    }

    /// Progress rate (base-hours per hour) at scale k; 0 when suspended.
    pub fn rate(&self, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        assert!(
            k >= self.k_min && k <= self.k_max,
            "job {} scale {k} outside [{}, {}]",
            self.id,
            self.k_min,
            self.k_max
        );
        self.profile.throughput(k)
    }

    /// Marginal throughput of the k-th server.
    pub fn marginal(&self, k: usize) -> f64 {
        self.profile.marginal(k)
    }

    /// Is this job elastic at all?
    pub fn is_elastic(&self) -> bool {
        self.k_max > self.k_min
    }

    /// Mean elasticity (Table 2 state feature).
    pub fn elasticity(&self) -> f64 {
        self.profile.truncated(self.k_max).elasticity()
    }

    /// Minimum slots needed to finish if run at k_min continuously.
    pub fn min_slots(&self) -> usize {
        self.length_hours.ceil().max(1.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profile::ScalingProfile;

    pub fn test_job(id: usize, arrival: usize, length: f64, slack: f64, k_max: usize) -> Job {
        Job {
            id,
            workload: "test",
            workload_idx: 0,
            arrival,
            length_hours: length,
            queue: 0,
            slack_hours: slack,
            k_min: 1,
            k_max,
            profile: ScalingProfile::from_comm_ratio(0.05, k_max),
            watts_per_unit: 40.0,
        }
    }

    #[test]
    fn deadline_math() {
        let j = test_job(0, 10, 4.0, 6.0, 4);
        assert_eq!(j.deadline_slot(), 20);
    }

    #[test]
    fn rate_zero_when_suspended() {
        let j = test_job(0, 0, 2.0, 0.0, 4);
        assert_eq!(j.rate(0), 0.0);
        assert!((j.rate(1) - 1.0).abs() < 1e-9);
        assert!(j.rate(4) > j.rate(1));
    }

    #[test]
    #[should_panic]
    fn rate_above_kmax_panics() {
        test_job(0, 0, 2.0, 0.0, 4).rate(5);
    }

    #[test]
    fn elastic_flag() {
        let mut j = test_job(0, 0, 2.0, 0.0, 4);
        assert!(j.is_elastic());
        j.k_max = 1;
        j.profile = ScalingProfile::inelastic();
        assert!(!j.is_elastic());
    }

    #[test]
    fn min_slots_rounds_up() {
        assert_eq!(test_job(0, 0, 2.2, 0.0, 2).min_slots(), 3);
        assert_eq!(test_job(0, 0, 0.4, 0.0, 2).min_slots(), 1);
    }
}
