//! Workload substrate: the elastic-job model, the Table 3 scaling-profile
//! catalog, and the Azure/Alibaba/SURF-like trace generators.

pub mod io;
pub mod job;
pub mod profile;
pub mod tracegen;

pub use job::{Job, JobId};
pub use profile::{ScalingProfile, Scalability, WorkloadSpec};
