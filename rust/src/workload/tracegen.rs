//! Synthetic workload-trace generators.
//!
//! **Substitution note (DESIGN.md §3):** the paper samples the Azure 2017,
//! Alibaba-PAI 2022, and SURF Lisa traces. We generate traces from the same
//! statistical families those traces exhibit — nonhomogeneous Poisson
//! arrivals with diurnal/weekday shape, lognormal job lengths (clipped to
//! hour+ jobs like the paper), and per-family parameters chosen so the
//! cross-trace deltas of Fig. 11 (Azure = longest jobs, Alibaba = short
//! bursty jobs, SURF = weekday HPC) are reproduced. The arrival rate is
//! calibrated so the carbon-agnostic baseline yields the target mean
//! utilization (paper: ~50%).

use crate::config::{DagShape, ElasticityScenario, ExperimentConfig, Hardware, TraceFamily};
use crate::util::rng::Rng;
use crate::workload::job::Job;
use crate::workload::profile::{self, ScalingProfile, Scalability, WorkloadSpec};

/// Per-family arrival/length parameters.
#[derive(Debug, Clone, Copy)]
pub struct FamilyParams {
    /// Lognormal ln-mean of job length (hours).
    pub len_mu: f64,
    /// Lognormal ln-std of job length.
    pub len_sigma: f64,
    /// Diurnal arrival amplitude (0 = flat).
    pub diurnal_amp: f64,
    /// Weekday/weekend arrival modulation.
    pub weekday_amp: f64,
    /// Hour-of-day of the arrival peak.
    pub peak_hour: f64,
}

impl FamilyParams {
    pub fn for_family(family: TraceFamily) -> FamilyParams {
        match family {
            // Cloud VM/batch: long jobs (mean ≈ 8 h), mild diurnality.
            TraceFamily::AzureLike => FamilyParams {
                len_mu: 1.6,
                len_sigma: 1.0,
                diurnal_amp: 0.30,
                weekday_amp: 0.15,
                peak_hour: 14.0,
            },
            // MLaaS GPU jobs: short (mean ≈ 3 h), bursty office-hours.
            TraceFamily::AlibabaLike => FamilyParams {
                len_mu: 0.70,
                len_sigma: 0.90,
                diurnal_amp: 0.50,
                weekday_amp: 0.20,
                peak_hour: 15.0,
            },
            // HPC: medium-long (mean ≈ 7 h), strong weekday submission.
            TraceFamily::SurfLike => FamilyParams {
                len_mu: 1.2,
                len_sigma: 1.2,
                diurnal_amp: 0.25,
                weekday_amp: 0.40,
                peak_hour: 11.0,
            },
        }
    }

    /// Relative arrival intensity at slot `t`.
    pub fn intensity(&self, t: usize) -> f64 {
        let hod = (t % 24) as f64;
        let day = (t / 24) % 7;
        let diurnal =
            1.0 + self.diurnal_amp * (std::f64::consts::TAU * (hod - self.peak_hour) / 24.0).cos();
        let weekly = if day < 5 { 1.0 + self.weekday_amp } else { 1.0 - self.weekday_amp };
        (diurnal * weekly).max(0.01)
    }

    /// Draw one job length in hours, clipped to the paper's hour+ focus.
    pub fn draw_length(&self, rng: &mut Rng, scale: f64) -> f64 {
        (rng.lognormal(self.len_mu, self.len_sigma) * scale).clamp(1.0, 96.0)
    }

    /// Empirical mean of [`draw_length`] (clipping makes the analytic
    /// lognormal mean wrong; estimate by simulation, deterministic seed).
    pub fn mean_length(&self, scale: f64) -> f64 {
        let mut rng = Rng::new(0x11AD);
        let n = 4000;
        (0..n).map(|_| self.draw_length(&mut rng, scale)).sum::<f64>() / n as f64
    }
}

/// Pick the workload spec for a job under an elasticity scenario.
fn pick_workload(
    scenario: ElasticityScenario,
    hardware: Hardware,
    catalog: &[WorkloadSpec],
    rng: &mut Rng,
) -> usize {
    match scenario {
        ElasticityScenario::Mix | ElasticityScenario::NoScaling => rng.below(catalog.len()),
        ElasticityScenario::High | ElasticityScenario::Moderate | ElasticityScenario::Low => {
            let class = match scenario {
                ElasticityScenario::High => Scalability::High,
                ElasticityScenario::Moderate => Scalability::Moderate,
                _ => Scalability::Low,
            };
            let idx: Vec<usize> = catalog
                .iter()
                .enumerate()
                .filter(|(_, w)| w.scalability == class)
                .map(|(i, _)| i)
                .collect();
            debug_assert!(!idx.is_empty(), "no {class:?} workloads for {hardware:?}");
            *rng.choose(&idx)
        }
    }
}

/// Generate a job trace of `horizon` hours under `cfg`, deterministically
/// from `seed`.
///
/// The number of jobs is calibrated so base-scale demand
/// (`Σ length · k_min`) ≈ `capacity · horizon · target_utilization`,
/// then scaled by `cfg.arrival_scale`; lengths scale by `cfg.length_scale`
/// (the Fig. 13 distribution-shift knobs).
pub fn generate(cfg: &ExperimentConfig, horizon: usize, seed: u64) -> Vec<Job> {
    generate_with(cfg, horizon, seed, None)
}

/// Like [`generate`], but with an explicit job count instead of the
/// utilization-calibrated one — used by the serve load generator to pin an
/// exact submission volume. When `n` equals the calibrated count this is
/// bitwise identical to [`generate`] (the RNG sequence is untouched).
pub fn generate_n(cfg: &ExperimentConfig, horizon: usize, seed: u64, n: usize) -> Vec<Job> {
    generate_with(cfg, horizon, seed, Some(n))
}

fn generate_with(
    cfg: &ExperimentConfig,
    horizon: usize,
    seed: u64,
    jobs_override: Option<usize>,
) -> Vec<Job> {
    let params = FamilyParams::for_family(cfg.trace);
    let catalog = profile::catalog_for(cfg.hardware);
    let k_max_hw = profile::default_k_max(cfg.hardware);
    let mut rng = Rng::new(seed);

    let mean_len = params.mean_length(cfg.length_scale);
    let target_jobs = jobs_override.unwrap_or_else(|| {
        (cfg.capacity as f64 * cfg.target_utilization * horizon as f64 / mean_len
            * cfg.arrival_scale)
            .round()
            .max(1.0) as usize
    });
    let target_jobs = target_jobs.max(1);

    // Sample arrival slots from the normalized intensity.
    let weights: Vec<f64> = (0..horizon).map(|t| params.intensity(t)).collect();
    let mut arrivals: Vec<usize> = (0..target_jobs).map(|_| rng.weighted(&weights)).collect();
    arrivals.sort_unstable();

    let mut jobs = Vec::with_capacity(target_jobs);
    for (id, arrival) in arrivals.into_iter().enumerate() {
        let widx = pick_workload(cfg.elasticity, cfg.hardware, &catalog, &mut rng);
        let spec = &catalog[widx];
        let length = params.draw_length(&mut rng, cfg.length_scale);
        let (k_min, k_max, prof) = if cfg.elasticity == ElasticityScenario::NoScaling {
            (1, 1, ScalingProfile::inelastic())
        } else {
            (1, k_max_hw, spec.profile(k_max_hw))
        };
        jobs.push(Job {
            id,
            workload: spec.name,
            workload_idx: widx,
            arrival,
            length_hours: length,
            queue: cfg.queue_for_length(length),
            slack_hours: cfg.slack_for_length(length),
            k_min,
            k_max,
            profile: prof,
            watts_per_unit: spec.watts_per_unit,
            deps: Vec::new(),
        });
    }
    apply_dag_shape(&mut jobs, cfg.dag_shape, seed);
    jobs
}

/// Salt for the DAG-edge RNG: edges draw from their own stream, seeded off
/// the trace seed, so wiring a topology never perturbs the arrival/length
/// draws above — a `dag_shape` cell keeps the *same jobs* as its flat twin
/// and differs only in the edges.
const DAG_SALT: u64 = 0xDA61_57A7;

/// Wire `cfg.dag_shape` dependency edges into a generated trace, in place.
///
/// Every edge points from a strictly smaller id to a larger one (parents
/// precede children in submission order), so traces are topologically
/// sorted by construction. [`DagShape::None`] is a strict no-op — flat
/// traces stay bitwise identical to the pre-DAG generator.
fn apply_dag_shape(jobs: &mut [Job], shape: DagShape, seed: u64) {
    if shape == DagShape::None || jobs.len() < 2 {
        return;
    }
    let mut rng = Rng::new(seed ^ DAG_SALT);
    match shape {
        DagShape::None => unreachable!("handled above"),
        // Linear pipelines: consecutive submissions form chains of 2–5
        // stages, each stage depending on its predecessor.
        DagShape::Chains => {
            let mut i = 0;
            while i < jobs.len() {
                let len = 2 + rng.below(4);
                for j in i + 1..(i + len).min(jobs.len()) {
                    jobs[j].deps.push(j - 1);
                }
                i += len;
            }
        }
        // Fan-out trees: groups of 3–6, the first member is the root and
        // every other member depends on it.
        DagShape::Fanout => {
            let mut i = 0;
            while i < jobs.len() {
                let len = 3 + rng.below(4);
                for j in i + 1..(i + len).min(jobs.len()) {
                    jobs[j].deps.push(i);
                }
                i += len;
            }
        }
        // Map-reduce stages: groups of 4–7 where the last member is the
        // reduce, depending on every map before it.
        DagShape::MapReduce => {
            let mut i = 0;
            while i < jobs.len() {
                let len = 4 + rng.below(4);
                let end = (i + len).min(jobs.len());
                if end - i >= 2 {
                    for m in i..end - 1 {
                        jobs[end - 1].deps.push(m);
                    }
                }
                i += len;
            }
        }
        // Random DAGs: ~65% of jobs draw 1–2 distinct earlier parents; the
        // rest stay sources so the graph keeps parallel width.
        DagShape::Random => {
            for j in 1..jobs.len() {
                if rng.chance(0.35) {
                    continue;
                }
                let n_parents = 1 + rng.below(2);
                let mut deps: Vec<usize> = Vec::with_capacity(n_parents);
                for _ in 0..n_parents {
                    let p = rng.below(j);
                    if !deps.contains(&p) {
                        deps.push(p);
                    }
                }
                deps.sort_unstable();
                jobs[j].deps = deps;
            }
        }
    }
}

/// Base-scale demand of a trace in server-hours.
pub fn total_demand(jobs: &[Job]) -> f64 {
    jobs.iter().map(|j| j.length_hours * j.k_min as f64).sum()
}

/// Implied mean utilization of a trace against a capacity/horizon.
pub fn implied_utilization(jobs: &[Job], capacity: usize, horizon: usize) -> f64 {
    total_demand(jobs) / (capacity as f64 * horizon as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::default()
    }

    #[test]
    fn deterministic() {
        let a = generate(&cfg(), 168, 1);
        let b = generate(&cfg(), 168, 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.length_hours, y.length_hours);
            assert_eq!(x.workload, y.workload);
        }
    }

    #[test]
    fn generate_n_pins_count_and_preserves_sequence() {
        let c = cfg();
        let calibrated = generate(&c, 168, 11);
        let pinned = generate_n(&c, 168, 11, calibrated.len());
        assert_eq!(calibrated.len(), pinned.len());
        for (a, b) in calibrated.iter().zip(&pinned) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.length_hours.to_bits(), b.length_hours.to_bits());
            assert_eq!(a.workload, b.workload);
        }
        assert_eq!(generate_n(&c, 168, 11, 37).len(), 37);
        assert_eq!(generate_n(&c, 168, 11, 0).len(), 1); // clamped to ≥ 1
    }

    #[test]
    fn utilization_calibrated() {
        let c = cfg();
        let jobs = generate(&c, 336, 2);
        let u = implied_utilization(&jobs, c.capacity, 336);
        assert!((u - 0.5).abs() < 0.08, "utilization {u}");
    }

    #[test]
    fn arrival_scale_shifts_load() {
        let mut c = cfg();
        let base = generate(&c, 168, 3).len();
        c.arrival_scale = 1.2;
        let more = generate(&c, 168, 3).len();
        assert!((more as f64 / base as f64 - 1.2).abs() < 0.05);
    }

    #[test]
    fn length_scale_shifts_lengths() {
        let mut c = cfg();
        let jobs_base = generate(&c, 168, 4);
        c.length_scale = 1.2;
        let jobs_long = generate(&c, 168, 4);
        let mean = |js: &[Job]| js.iter().map(|j| j.length_hours).sum::<f64>() / js.len() as f64;
        assert!(mean(&jobs_long) > mean(&jobs_base) * 1.05);
    }

    #[test]
    fn arrivals_sorted_within_horizon() {
        let jobs = generate(&cfg(), 168, 5);
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(jobs.iter().all(|j| j.arrival < 168));
        assert!(jobs.iter().all(|j| j.length_hours >= 1.0));
    }

    #[test]
    fn queue_assignment_consistent() {
        let c = cfg();
        for j in generate(&c, 168, 6) {
            assert_eq!(j.queue, c.queue_for_length(j.length_hours));
            assert_eq!(j.slack_hours, c.slack_for_length(j.length_hours));
        }
    }

    #[test]
    fn azure_jobs_longer_than_alibaba() {
        let mut c = cfg();
        c.trace = TraceFamily::AzureLike;
        let az = generate(&c, 336, 7);
        c.trace = TraceFamily::AlibabaLike;
        let al = generate(&c, 336, 7);
        let mean = |js: &[Job]| js.iter().map(|j| j.length_hours).sum::<f64>() / js.len() as f64;
        assert!(mean(&az) > mean(&al) * 1.5, "azure {} alibaba {}", mean(&az), mean(&al));
    }

    #[test]
    fn noscaling_jobs_inelastic() {
        let mut c = cfg();
        c.elasticity = ElasticityScenario::NoScaling;
        for j in generate(&c, 168, 8) {
            assert_eq!(j.k_max, 1);
            assert!(!j.is_elastic());
        }
    }

    #[test]
    fn scenario_filters_catalog() {
        let mut c = cfg();
        c.elasticity = ElasticityScenario::High;
        for j in generate(&c, 168, 9) {
            assert!(j.workload.contains("N-body"), "unexpected workload {}", j.workload);
        }
    }

    #[test]
    fn gpu_uses_gpu_catalog() {
        let mut c = cfg();
        c.hardware = Hardware::Gpu;
        c.capacity = 15;
        for j in generate(&c, 168, 10) {
            assert!(j.k_max <= 8);
            assert!(j.watts_per_unit >= 100.0);
        }
    }

    #[test]
    fn weekday_intensity_higher() {
        let p = FamilyParams::for_family(TraceFamily::SurfLike);
        // Tuesday noon vs Sunday noon.
        assert!(p.intensity(24 + 12) > p.intensity(6 * 24 + 12));
    }

    /// Satellite guard for the calibrated job count: the target is
    /// `.round()`ed, never floor-truncated, so the generated trace mass
    /// tracks the utilization target from above *and* below. Pins the count
    /// against the formula recomputed from the public pieces.
    #[test]
    fn job_count_rounds_rather_than_truncates() {
        for (horizon, seed) in [(168usize, 12u64), (96, 13), (72, 14)] {
            let c = cfg();
            let params = FamilyParams::for_family(c.trace);
            let mean_len = params.mean_length(c.length_scale);
            let expect = (c.capacity as f64 * c.target_utilization * horizon as f64 / mean_len
                * c.arrival_scale)
                .round()
                .max(1.0) as usize;
            let jobs = generate(&c, horizon, seed);
            assert_eq!(jobs.len(), expect, "horizon {horizon}");
            // And the generated-hours mass is what those draws sum to —
            // identical across runs (no hidden truncation inside the loop).
            let mass: f64 = jobs.iter().map(|j| j.length_hours).sum();
            let mass2: f64 = generate(&c, horizon, seed).iter().map(|j| j.length_hours).sum();
            assert_eq!(mass.to_bits(), mass2.to_bits());
            assert!(jobs.iter().all(|j| (1.0..=96.0).contains(&j.length_hours)));
        }
    }

    fn all_shapes() -> [DagShape; 4] {
        [DagShape::Chains, DagShape::Fanout, DagShape::MapReduce, DagShape::Random]
    }

    #[test]
    fn dag_none_is_bitwise_identical_to_flat() {
        // The zero-edge case is the degenerate DAG: same arrivals, lengths
        // (bit for bit), workloads, and no edges — the pre-DAG generator.
        let flat = generate(&cfg(), 168, 21);
        let mut c = cfg();
        c.dag_shape = DagShape::None;
        let none = generate(&c, 168, 21);
        assert_eq!(flat.len(), none.len());
        for (a, b) in flat.iter().zip(&none) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.length_hours.to_bits(), b.length_hours.to_bits());
            assert_eq!(a.workload, b.workload);
            assert!(a.deps.is_empty() && b.deps.is_empty());
        }
    }

    #[test]
    fn dag_edges_do_not_perturb_the_job_stream() {
        // A shaped trace carries the *same jobs* as its flat twin — the
        // edge RNG is a separate salted stream.
        let flat = generate(&cfg(), 168, 22);
        for shape in all_shapes() {
            let mut c = cfg();
            c.dag_shape = shape;
            let shaped = generate(&c, 168, 22);
            assert_eq!(flat.len(), shaped.len(), "{shape:?}");
            let mut edges = 0usize;
            for (a, b) in flat.iter().zip(&shaped) {
                assert_eq!(a.arrival, b.arrival, "{shape:?}");
                assert_eq!(a.length_hours.to_bits(), b.length_hours.to_bits(), "{shape:?}");
                assert_eq!(a.workload_idx, b.workload_idx, "{shape:?}");
                edges += b.deps.len();
            }
            assert!(edges > 0, "{shape:?} wired no edges");
        }
    }

    #[test]
    fn dag_edges_are_topological_and_deterministic() {
        for shape in all_shapes() {
            let mut c = cfg();
            c.dag_shape = shape;
            let a = generate(&c, 168, 23);
            let b = generate(&c, 168, 23);
            for (j, job) in a.iter().enumerate() {
                assert_eq!(job.id, j);
                assert_eq!(job.deps, b[j].deps, "{shape:?} edges not deterministic");
                for &p in &job.deps {
                    assert!(p < j, "{shape:?}: dep {p} of job {j} not earlier");
                    // Parents never arrive after their children (arrivals
                    // are sorted before ids are assigned).
                    assert!(a[p].arrival <= job.arrival, "{shape:?}");
                }
                // No duplicate parents.
                let mut d = job.deps.clone();
                d.dedup();
                assert_eq!(d.len(), job.deps.len(), "{shape:?} duplicate parent");
            }
        }
    }

    #[test]
    fn dag_shape_structure() {
        let mk = |shape| {
            let mut c = cfg();
            c.dag_shape = shape;
            generate(&c, 168, 24)
        };
        // Chains: at most one parent, always the immediate predecessor.
        for (j, job) in mk(DagShape::Chains).iter().enumerate() {
            assert!(job.deps.len() <= 1);
            if let Some(&p) = job.deps.first() {
                assert_eq!(p, j - 1);
            }
        }
        // Fanout: at most one parent, and no node both has a parent and is
        // one (depth ≤ 1 trees).
        let fan = mk(DagShape::Fanout);
        let mut is_parent = vec![false; fan.len()];
        for job in &fan {
            assert!(job.deps.len() <= 1);
            for &p in &job.deps {
                is_parent[p] = true;
            }
        }
        for job in &fan {
            if !job.deps.is_empty() {
                assert!(!is_parent[job.id], "fanout child {} is also a root", job.id);
            }
        }
        // MapReduce: nodes are either sources or a reduce with ≥ 1 maps,
        // and every reduce's parents are contiguous predecessors.
        let mr = mk(DagShape::MapReduce);
        assert!(mr.iter().any(|j| j.deps.len() >= 3), "no wide reduce generated");
        for job in &mr {
            if !job.deps.is_empty() {
                let lo = job.deps[0];
                let expect: Vec<usize> = (lo..job.id).collect();
                assert_eq!(job.deps, expect, "reduce {} parents not contiguous", job.id);
            }
        }
        // Random: parents bounded at 2, and some sources survive.
        let rnd = mk(DagShape::Random);
        assert!(rnd.iter().all(|j| j.deps.len() <= 2));
        assert!(rnd.iter().filter(|j| j.deps.is_empty()).count() >= rnd.len() / 10);
    }
}
