//! CSV persistence for workload traces, so generated traces can be
//! inspected, archived with experiment results, or swapped for real
//! cluster-log exports of the same shape.
//!
//! Format: `id,workload,arrival,length_hours,queue,slack_hours,k_min,k_max`
//! — the scaling profile and power model are re-derived from the named
//! catalog workload at load time (profiles are functions of the catalog,
//! not free data).

use std::io::Write;
use std::path::Path;

use crate::config::Hardware;
use crate::workload::job::Job;
use crate::workload::profile::{self, ScalingProfile};

/// IO error for workload trace files.
#[derive(Debug)]
pub enum WorkloadIoError {
    Io(std::io::Error),
    Malformed(usize, String),
    UnknownWorkload(usize, String, Hardware),
}

impl std::fmt::Display for WorkloadIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkloadIoError::Io(e) => write!(f, "io: {e}"),
            WorkloadIoError::Malformed(line, msg) => write!(f, "csv line {line}: {msg}"),
            WorkloadIoError::UnknownWorkload(line, name, hw) => {
                write!(f, "csv line {line}: unknown workload '{name}' for {hw:?} catalog")
            }
        }
    }
}

impl std::error::Error for WorkloadIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WorkloadIoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WorkloadIoError {
    fn from(e: std::io::Error) -> Self {
        WorkloadIoError::Io(e)
    }
}

/// Save a job trace as CSV.
pub fn save_csv(jobs: &[Job], path: impl AsRef<Path>) -> Result<(), WorkloadIoError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "id,workload,arrival,length_hours,queue,slack_hours,k_min,k_max")?;
    for j in jobs {
        writeln!(
            f,
            "{},{},{},{:.4},{},{:.2},{},{}",
            j.id, j.workload, j.arrival, j.length_hours, j.queue, j.slack_hours, j.k_min, j.k_max
        )?;
    }
    Ok(())
}

/// Load a job trace saved by [`save_csv`], rebuilding profiles from the
/// `hardware` catalog.
pub fn load_csv(path: impl AsRef<Path>, hardware: Hardware) -> Result<Vec<Job>, WorkloadIoError> {
    let catalog = profile::catalog_for(hardware);
    let src = std::fs::read_to_string(path)?;
    let mut jobs = Vec::new();
    for (i, line) in src.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != 8 {
            return Err(WorkloadIoError::Malformed(lineno, format!("{} fields", parts.len())));
        }
        let field = |idx: usize| -> &str { parts[idx].trim() };
        let parse_err =
            |what: &str| WorkloadIoError::Malformed(lineno, format!("bad {what}: '{line}'"));
        let name = field(1);
        let widx = catalog
            .iter()
            .position(|w| w.name == name)
            .ok_or_else(|| WorkloadIoError::UnknownWorkload(lineno, name.into(), hardware))?;
        let k_min: usize = field(6).parse().map_err(|_| parse_err("k_min"))?;
        let k_max: usize = field(7).parse().map_err(|_| parse_err("k_max"))?;
        if k_min == 0 || k_min > k_max {
            return Err(WorkloadIoError::Malformed(
                lineno,
                format!("bad scale range {k_min}..{k_max}"),
            ));
        }
        let spec = &catalog[widx];
        let profile = if k_max == k_min {
            ScalingProfile::inelastic()
        } else {
            spec.profile(k_max)
        };
        jobs.push(Job {
            id: field(0).parse().map_err(|_| parse_err("id"))?,
            workload: spec.name,
            workload_idx: widx,
            arrival: field(2).parse().map_err(|_| parse_err("arrival"))?,
            length_hours: field(3).parse().map_err(|_| parse_err("length_hours"))?,
            queue: field(4).parse().map_err(|_| parse_err("queue"))?,
            slack_hours: field(5).parse().map_err(|_| parse_err("slack_hours"))?,
            k_min,
            k_max,
            profile,
            watts_per_unit: spec.watts_per_unit,
            deps: Vec::new(),
        });
    }
    // Re-id if the file was hand-edited out of order: the engine requires
    // dense submission ids sorted by arrival.
    jobs.sort_by_key(|j| (j.arrival, j.id));
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::workload::tracegen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("carbonflex_workload_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_jobs() {
        let mut cfg = ExperimentConfig::default();
        cfg.capacity = 20;
        let jobs = tracegen::generate(&cfg, 96, 5);
        let path = tmp("trace.csv");
        save_csv(&jobs, &path).unwrap();
        let loaded = load_csv(&path, cfg.hardware).unwrap();
        assert_eq!(loaded.len(), jobs.len());
        for (a, b) in jobs.iter().zip(&loaded) {
            assert_eq!(a.workload, b.workload);
            assert_eq!(a.arrival, b.arrival);
            assert!((a.length_hours - b.length_hours).abs() < 1e-3);
            assert_eq!(a.queue, b.queue);
            assert_eq!((a.k_min, a.k_max), (b.k_min, b.k_max));
            // Profile re-derived from the catalog must match.
            assert!((a.profile.throughput(a.k_max) - b.profile.throughput(b.k_max)).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_unknown_workload_and_bad_fields() {
        let path = tmp("bad.csv");
        std::fs::write(
            &path,
            "id,workload,arrival,length_hours,queue,slack_hours,k_min,k_max\n\
             0,NotAWorkload,0,2.0,0,6.0,1,4\n",
        )
        .unwrap();
        assert!(matches!(
            load_csv(&path, Hardware::Cpu),
            Err(WorkloadIoError::UnknownWorkload(2, _, _))
        ));
        std::fs::write(
            &path,
            "id,workload,arrival,length_hours,queue,slack_hours,k_min,k_max\n\
             0,Jacobi(N=1k),0,2.0,0,6.0,4,1\n",
        )
        .unwrap();
        assert!(load_csv(&path, Hardware::Cpu).is_err());
        std::fs::write(&path, "header\n1,2,3\n").unwrap();
        assert!(load_csv(&path, Hardware::Cpu).is_err());
    }

    #[test]
    fn out_of_order_files_are_reindexed() {
        let path = tmp("shuffled.csv");
        std::fs::write(
            &path,
            "id,workload,arrival,length_hours,queue,slack_hours,k_min,k_max\n\
             7,Jacobi(N=1k),10,2.0,0,6.0,1,4\n\
             3,Heat(N=1k),2,3.0,1,24.0,1,4\n",
        )
        .unwrap();
        let jobs = load_csv(&path, Hardware::Cpu).unwrap();
        assert_eq!(jobs[0].arrival, 2);
        assert_eq!(jobs[0].id, 0);
        assert_eq!(jobs[1].id, 1);
    }
}
