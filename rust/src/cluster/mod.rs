//! Cluster substrate: the slot-based simulator, energy/carbon accounting
//! (Eq. 1–3), and run metrics.

pub mod energy;
pub mod metrics;
pub mod sim;

pub use energy::EnergyModel;
pub use metrics::{JobOutcome, RunMetrics};
pub use sim::{ClusterEngine, SimResult, Simulator, SlotRecord, RHO_IDLE};
