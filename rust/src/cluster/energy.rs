//! Energy and carbon accounting (paper §5, Eq. 1–3).
//!
//! Per job j at scale s, per slot:
//!
//! `E_js = E_js^R + E_js^net`            (Eq. 2)
//! `E_js^net = η_net · Mem_js`           (Eq. 3)
//! `C_t = Σ_j E_js · CI_t`               (Eq. 1)
//!
//! Compute energy is `k · watts_per_unit` per hour (fixed per-resource CPU
//! draw, per-workload heterogeneous GPU draw, as in the paper). Network
//! energy uses η_net = 0.1 W/Gbps over ring-allreduce traffic.

use crate::workload::job::Job;
use crate::workload::profile::WorkloadSpec;

/// Network energy efficiency, W/Gbps (paper §5 picks 0.1 within the
/// three-orders-of-magnitude literature range).
pub const ETA_NET_W_PER_GBPS: f64 = 0.1;

/// Energy model for one cluster run.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Catalog specs indexed by `Job::workload_idx`.
    specs: Vec<WorkloadSpec>,
    /// W/Gbps network efficiency.
    pub eta_net: f64,
    /// Server boot energy overhead, Wh per booted server (provisioning lag:
    /// 3 min CPU / 5 min GPU at idle-ish draw, §6.8).
    pub boot_wh_per_server: f64,
    /// Checkpoint+restore wall time per rescale, hours (§6.8: ≤ 2.3 s).
    pub ckpt_hours: f64,
}

impl EnergyModel {
    pub fn new(specs: Vec<WorkloadSpec>, boot_minutes: f64, idle_watts: f64) -> Self {
        EnergyModel {
            specs,
            eta_net: ETA_NET_W_PER_GBPS,
            boot_wh_per_server: idle_watts * boot_minutes / 60.0,
            ckpt_hours: 2.3 / 3600.0,
        }
    }

    /// Standard model for a hardware class.
    pub fn for_hardware(hw: crate::config::Hardware) -> Self {
        use crate::config::Hardware;
        let specs = crate::workload::profile::catalog_for(hw);
        match hw {
            Hardware::Cpu => EnergyModel::new(specs, 3.0, 20.0),
            Hardware::Gpu => EnergyModel::new(specs, 5.0, 60.0),
        }
    }

    /// Energy (kWh) consumed by `job` running at scale `k` for `fraction` of
    /// one hour slot. Eq. 2: compute + network.
    pub fn job_energy_kwh(&self, job: &Job, k: usize, fraction: f64) -> f64 {
        if k == 0 || fraction <= 0.0 {
            return 0.0;
        }
        let compute_wh = k as f64 * job.watts_per_unit * fraction;
        let net_wh = self.network_wh(job, k, fraction);
        (compute_wh + net_wh) / 1000.0
    }

    /// Network energy in Wh for `fraction` hours at scale k (Eq. 3).
    pub fn network_wh(&self, job: &Job, k: usize, fraction: f64) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let spec = &self.specs[job.workload_idx];
        // Sustained link rate while running: gbit/hour ÷ 3600 s = Gbps.
        let rate_gbps = spec.network_gbit_per_hour(k) / 3600.0;
        // P_net = η (W/Gbps) · rate (Gbps); energy = P_net · fraction hours.
        self.eta_net * rate_gbps * fraction
    }

    /// Carbon (grams CO₂eq) for an energy draw at carbon intensity `ci`.
    pub fn carbon_g(&self, energy_kwh: f64, ci: f64) -> f64 {
        energy_kwh * ci
    }

    /// Boot energy (kWh) for acquiring `n` servers.
    pub fn boot_energy_kwh(&self, n: usize) -> f64 {
        n as f64 * self.boot_wh_per_server / 1000.0
    }

    /// Progress lost to one checkpoint/restore cycle, in base-hours, for a
    /// job running at rate `rate`.
    pub fn ckpt_progress_penalty(&self, rate: f64) -> f64 {
        self.ckpt_hours * rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Hardware;
    use crate::workload::profile::{catalog_for, ScalingProfile};

    fn job(widx: usize, watts: f64, k_max: usize) -> Job {
        Job {
            id: 0,
            workload: "t",
            workload_idx: widx,
            arrival: 0,
            length_hours: 4.0,
            queue: 0,
            slack_hours: 6.0,
            k_min: 1,
            k_max,
            profile: ScalingProfile::from_comm_ratio(0.05, k_max),
            watts_per_unit: watts,
            deps: Vec::new(),
        }
    }

    #[test]
    fn compute_energy_scales_with_k_and_fraction() {
        let m = EnergyModel::for_hardware(Hardware::Cpu);
        let j = job(0, 40.0, 16);
        let e1 = m.job_energy_kwh(&j, 1, 1.0);
        assert!((e1 - 0.040).abs() < 1e-6, "{e1}");
        let e2 = m.job_energy_kwh(&j, 2, 1.0);
        assert!(e2 > 2.0 * e1 * 0.99); // ≥ 2x (plus network)
        let eh = m.job_energy_kwh(&j, 1, 0.5);
        assert!((eh - e1 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_when_suspended() {
        let m = EnergyModel::for_hardware(Hardware::Cpu);
        let j = job(0, 40.0, 16);
        assert_eq!(m.job_energy_kwh(&j, 0, 1.0), 0.0);
        assert_eq!(m.job_energy_kwh(&j, 2, 0.0), 0.0);
    }

    #[test]
    fn network_energy_small_but_positive() {
        let m = EnergyModel::for_hardware(Hardware::Gpu);
        let specs = catalog_for(Hardware::Gpu);
        // ViT-B/32 = largest comm size → largest net energy.
        let vit_idx = specs.iter().position(|w| w.name == "ViT-B/32").unwrap();
        let alex_idx = specs.iter().position(|w| w.name == "AlexNet").unwrap();
        let jv = job(vit_idx, 250.0, 8);
        let ja = job(alex_idx, 150.0, 8);
        let nv = m.network_wh(&jv, 8, 1.0);
        let na = m.network_wh(&ja, 8, 1.0);
        assert!(nv > 0.0 && na > 0.0);
        // Network energy stays a small fraction of compute energy.
        let total = m.job_energy_kwh(&jv, 8, 1.0) * 1000.0;
        assert!(nv / total < 0.2, "net share {}", nv / total);
    }

    #[test]
    fn carbon_is_linear_in_ci() {
        let m = EnergyModel::for_hardware(Hardware::Cpu);
        assert_eq!(m.carbon_g(2.0, 100.0), 200.0);
        assert_eq!(m.carbon_g(2.0, 0.0), 0.0);
    }

    #[test]
    fn boot_energy() {
        let m = EnergyModel::for_hardware(Hardware::Cpu);
        // 20 W idle for 3 min = 1 Wh per server.
        assert!((m.boot_energy_kwh(10) - 0.010).abs() < 1e-9);
    }

    #[test]
    fn ckpt_penalty_proportional_to_rate() {
        let m = EnergyModel::for_hardware(Hardware::Gpu);
        let p1 = m.ckpt_progress_penalty(1.0);
        let p4 = m.ckpt_progress_penalty(4.0);
        assert!((p4 - 4.0 * p1).abs() < 1e-12);
        assert!(p1 < 0.01); // seconds-scale, not minutes
    }
}
