//! Run-level metrics: emissions, savings, delay, waiting, SLO violations,
//! utilization — the quantities every figure in the paper reports.

use crate::util::stats;

/// Outcome of one completed job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: usize,
    pub arrival: usize,
    /// Slot in which the job finished (inclusive).
    pub completion: usize,
    /// Base-scale length, hours.
    pub length_hours: f64,
    /// Queue slack, hours.
    pub slack_hours: f64,
    /// Total energy attributed to the job, kWh.
    pub energy_kwh: f64,
    /// Total carbon attributed to the job, grams.
    pub carbon_g: f64,
    /// Number of rescale (checkpoint/restore) events.
    pub rescales: usize,
}

impl JobOutcome {
    /// Delay beyond the job's ideal base-scale completion, hours (≥ 0).
    /// The paper's Fig. 6b/9b "delay"/"waiting time" metric.
    pub fn delay_hours(&self) -> f64 {
        let ideal = self.arrival as f64 + self.length_hours;
        ((self.completion + 1) as f64 - ideal).max(0.0)
    }

    /// Did the job exceed its allowed slack? Consistent with the slot
    /// window `[arrival, arrival + ceil(length + slack))` every policy
    /// (and the oracle) schedules within: completing in the window's last
    /// slot is on time.
    pub fn violated_slo(&self) -> bool {
        let deadline_slot = self.arrival + (self.length_hours + self.slack_hours).ceil() as usize;
        self.completion + 1 > deadline_slot
    }
}

/// Aggregate metrics for one policy run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub policy: String,
    /// Total operational carbon, grams CO₂eq.
    pub carbon_g: f64,
    /// Total energy, kWh.
    pub energy_kwh: f64,
    pub completed: usize,
    /// Jobs still unfinished at horizon end (simulator runs past the horizon
    /// until drain, so this is normally 0).
    pub unfinished: usize,
    pub mean_delay_hours: f64,
    pub p95_delay_hours: f64,
    pub violations: usize,
    /// Mean cluster utilization (allocated / max capacity) over the horizon.
    pub mean_utilization: f64,
    /// Peak allocated servers.
    pub peak_allocated: usize,
    /// Total rescale events (checkpoint/restore cycles).
    pub total_rescales: usize,
    /// Slot at which the last job completed.
    pub makespan: usize,
    /// Fault injection: jobs force-suspended by slot crashes (0 in
    /// fault-free runs; see `crate::faults`).
    pub restarts: u64,
    /// Fault injection: completed progress re-done after crashes, hours.
    pub lost_work_hours: f64,
    /// Fault injection: recovery-time percentiles across crashes, slots
    /// (0.0 when no crash fired).
    pub recovery_p50_slots: f64,
    pub recovery_p99_slots: f64,
    /// Degradation ladder: slots decided on a stale last-known-good
    /// forecast during a signal outage.
    pub degraded_stale: u64,
    /// Degradation ladder: slots decided by the carbon-agnostic fallback.
    pub degraded_fallback: u64,
}

impl RunMetrics {
    /// Build from job outcomes plus slot-level usage series.
    pub fn from_outcomes(
        policy: &str,
        outcomes: &[JobOutcome],
        unfinished: usize,
        usage_per_slot: &[usize],
        max_capacity: usize,
        horizon: usize,
    ) -> RunMetrics {
        let delays: Vec<f64> = outcomes.iter().map(|o| o.delay_hours()).collect();
        let carbon_g = outcomes.iter().map(|o| o.carbon_g).sum();
        let energy_kwh = outcomes.iter().map(|o| o.energy_kwh).sum();
        let violations = outcomes.iter().filter(|o| o.violated_slo()).count();
        let horizon_usage = &usage_per_slot[..usage_per_slot.len().min(horizon)];
        let mean_utilization = if horizon_usage.is_empty() || max_capacity == 0 {
            0.0
        } else {
            horizon_usage.iter().map(|&u| u as f64).sum::<f64>()
                / (max_capacity as f64 * horizon_usage.len() as f64)
        };
        RunMetrics {
            policy: policy.to_string(),
            carbon_g,
            energy_kwh,
            completed: outcomes.len(),
            unfinished,
            mean_delay_hours: stats::mean(&delays),
            p95_delay_hours: if delays.is_empty() { 0.0 } else { stats::percentile(&delays, 95.0) },
            violations,
            mean_utilization,
            peak_allocated: usage_per_slot.iter().copied().max().unwrap_or(0),
            total_rescales: outcomes.iter().map(|o| o.rescales).sum(),
            makespan: outcomes.iter().map(|o| o.completion).max().unwrap_or(0),
            restarts: 0,
            lost_work_hours: 0.0,
            recovery_p50_slots: 0.0,
            recovery_p99_slots: 0.0,
            degraded_stale: 0,
            degraded_fallback: 0,
        }
    }

    /// Carbon savings (%) relative to a baseline run (the carbon-agnostic
    /// policy in every paper figure).
    pub fn savings_vs(&self, baseline: &RunMetrics) -> f64 {
        if baseline.carbon_g <= 0.0 {
            return 0.0;
        }
        (1.0 - self.carbon_g / baseline.carbon_g) * 100.0
    }

    /// Carbon in kilograms (reporting convenience).
    pub fn carbon_kg(&self) -> f64 {
        self.carbon_g / 1000.0
    }

    /// SLO violation rate among completed jobs.
    pub fn violation_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.violations as f64 / self.completed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(arrival: usize, completion: usize, length: f64, slack: f64) -> JobOutcome {
        JobOutcome {
            id: 0,
            arrival,
            completion,
            length_hours: length,
            slack_hours: slack,
            energy_kwh: 1.0,
            carbon_g: 100.0,
            rescales: 1,
        }
    }

    #[test]
    fn delay_is_clamped_nonnegative() {
        // Completed faster than base scale (elastic speedup) → delay 0.
        let o = outcome(0, 1, 4.0, 6.0);
        assert_eq!(o.delay_hours(), 0.0);
    }

    #[test]
    fn delay_and_violation() {
        // arrival 0, length 2h → ideal end at t=2; completion slot 9 → end 10.
        let o = outcome(0, 9, 2.0, 6.0);
        assert!((o.delay_hours() - 8.0).abs() < 1e-9);
        assert!(o.violated_slo());
        let ok = outcome(0, 7, 2.0, 6.0);
        assert!(!ok.violated_slo());
    }

    #[test]
    fn aggregate_metrics() {
        let outcomes = vec![outcome(0, 3, 2.0, 6.0), outcome(1, 12, 2.0, 6.0)];
        let usage = vec![2, 2, 1, 1, 0, 0];
        let m = RunMetrics::from_outcomes("test", &outcomes, 0, &usage, 4, 6);
        assert_eq!(m.completed, 2);
        assert_eq!(m.violations, 1);
        assert!((m.mean_utilization - 0.25).abs() < 1e-9);
        assert_eq!(m.peak_allocated, 2);
        assert_eq!(m.makespan, 12);
        assert!((m.carbon_g - 200.0).abs() < 1e-9);
    }

    #[test]
    fn savings_math() {
        let mut a = RunMetrics::from_outcomes("base", &[outcome(0, 3, 2.0, 6.0)], 0, &[1], 1, 1);
        a.carbon_g = 1000.0;
        let mut b = a.clone();
        b.carbon_g = 425.0;
        assert!((b.savings_vs(&a) - 57.5).abs() < 1e-9);
        assert_eq!(a.savings_vs(&a), 0.0);
    }
}
